"""Collective-bytes benchmark — the paper's CGTrans mechanism, measured.

Lowers BOTH dataflows of ``repro.core.cgtrans`` (full-graph edge COO and
sampled GraphSAGE) on 1/2/4/8-way data meshes, extracts the interconnect
bytes from the compiled HLO via ``repro.launch.hlo_analysis``, sweeps the
sampling fan-out K and feature width F, and writes the trajectory to
``BENCH_collective_bytes.json``.

The headline: baseline (GCNAX-style raw transmission) ships O(B·K·F) bytes,
CGTrans ships O(B·F) — the ratio tracks the fan-out K, reproducing the
paper's fan-in compression at the paper's own operating point (K≈50, the
``paper_figure`` row, asserted ≥ 30×).

Two measurements per run:

* byte rows — compile-time only (HLO diffing), seconds on the 8-way
  fake-device CPU topology;
* ``agg_time`` rows — the per-shard aggregation wall time of the sharded
  cgtrans dataflow with ``impl="xla"`` vs ``impl="pallas"`` (the FAST-GAS
  kernel; interpret-mode on CPU, so treat the absolute numbers as a
  correctness-path comparison, not kernel speed);
* ``train_step_time`` rows — one full jitted GraphSAGE **train step**
  (forward + backward + AdamW) on the 8-way mesh, ``impl="xla"`` vs
  ``impl="pallas"`` — now that the kernel carries custom VJPs, the backward
  runs through FAST-GAS too; same interpret-mode caveat applies.

``benchmarks/run.py`` runs this script and folds both into its CSV output.

Run:  PYTHONPATH=src python benchmarks/collective_bytes.py [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cgtrans  # noqa: E402
from repro.graph import partition_by_src, uniform_graph  # noqa: E402
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402

FLOWS = ("baseline", "cgtrans")
PAPER_K = 50          # paper §4.2: GraphSAGE samples 50 neighbors
PAPER_MIN_RATIO = 30  # the ≈50× claim, with slack for collective overheads


def _collective_bytes(fn, *args) -> float:
    comp = jax.jit(fn).lower(*args).compile()
    return H.analyze(comp.as_text()).collective_bytes


def bench_sampled(ways: int, K: int, F: int, B_loc: int = 32,
                  part: int = 64) -> dict:
    """Sampled GraphSAGE aggregation: B_loc seeds/shard, fan-out K, width F."""
    mesh = make_data_mesh(ways) if ways > 1 else None
    feats = jnp.zeros((max(ways, 1), part, F))
    nbrs = jnp.zeros((max(ways, 1), B_loc, K), jnp.int32)
    mask = jnp.ones((max(ways, 1), B_loc, K), bool)
    row = {"mode": "sampled", "ways": ways, "K": K, "F": F,
           "B_loc": B_loc, "part": part}
    for flow in FLOWS:
        row[flow] = _collective_bytes(
            lambda f, n, m, fl=flow: cgtrans.aggregate_sampled(
                f, n, m, mesh=mesh, dataflow=fl), feats, nbrs, mask)
    row["ratio"] = row["baseline"] / row["cgtrans"] if row["cgtrans"] else 0.0
    return row


def bench_full_graph(ways: int, F: int, V: int = 256, E: int = 4096) -> dict:
    """Full-graph edge COO aggregation on a partitioned uniform graph."""
    mesh = make_data_mesh(ways) if ways > 1 else None
    g = uniform_graph(V, E, seed=1, n_features=F, weights=True)
    pg = partition_by_src(g, max(ways, 1))
    args = (jnp.asarray(pg.features), jnp.asarray(pg.src), jnp.asarray(pg.dst),
            jnp.asarray(pg.weights), jnp.asarray(pg.mask))
    row = {"mode": "full", "ways": ways, "V": V, "E": E, "F": F,
           "avg_fanin": E / V}
    for flow in FLOWS:
        row[flow] = _collective_bytes(
            lambda *a, fl=flow: cgtrans.aggregate_edges(
                *a, mesh=mesh, dataflow=fl), *args)
    row["ratio"] = row["baseline"] / row["cgtrans"] if row["cgtrans"] else 0.0
    return row


def bench_agg_time(ways: int = 8, V: int = 256, E: int = 4096, F: int = 16,
                   reps: int = 3) -> list:
    """Per-shard aggregation wall time of the sharded cgtrans dataflow,
    impl="xla" vs impl="pallas" (the FAST-GAS kernel) — actually executed,
    not just lowered."""
    mesh = make_data_mesh(ways)
    g = uniform_graph(V, E, seed=1, n_features=F, weights=True)
    pg = partition_by_src(g, ways)
    args = (jnp.asarray(pg.features), jnp.asarray(pg.src), jnp.asarray(pg.dst),
            jnp.asarray(pg.weights), jnp.asarray(pg.mask))
    rows = []
    for impl in ("xla", "pallas"):
        fn = jax.jit(lambda *a, i=impl: cgtrans.aggregate_edges(
            *a, mesh=mesh, dataflow="cgtrans", impl=i))
        jax.block_until_ready(fn(*args))             # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"mode": "agg_time", "ways": ways, "V": V, "E": E, "F": F,
                     "impl": impl, "us": us, "us_per_shard": us / ways})
    return rows


def bench_train_step_time(ways: int = 8, reps: int = 3) -> list:
    """Wall time of one jitted GraphSAGE+CGTrans TRAIN step on the sharded
    mesh, impl="xla" vs impl="pallas" — the differentiable-kernel path
    (forward and backward through FAST-GAS), actually executed."""
    import jax.random
    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema
    from repro.data import GraphBatchStream, synthetic_node_labels
    from repro.graph import partition_by_src, uniform_graph
    from repro.optim import adamw_init
    from repro.train import make_sage_train_step

    mesh = make_data_mesh(ways)
    g = uniform_graph(128, 1024, seed=0, n_features=8)
    labels = synthetic_node_labels(g.features, 4)
    pg = partition_by_src(g, ways)
    feats = jnp.asarray(pg.features)
    tc = TrainConfig(learning_rate=1e-3)
    stream = GraphBatchStream(g, labels, n_parts=ways, batch_per_part=4,
                              k1=4, k2=4)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    rows = []
    for impl in ("xla", "pallas"):
        cfg = GCNConfig(n_features=8, hidden=16, n_classes=4, fanout=4,
                        impl=impl)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw_init(params, tc),
                 "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_sage_train_step(cfg, tc, feats=feats, mesh=mesh))
        state, m = step(state, batch)            # compile + warm
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(reps):
            state, m = step(state, batch)
            jax.block_until_ready(state)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"mode": "train_step_time", "ways": ways, "impl": impl,
                     "us": us, "loss": float(m["total_loss"])})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_collective_bytes.json")
    ap.add_argument("--fast", action="store_true",
                    help="skip the K/F sweeps; mesh-scaling rows only")
    args = ap.parse_args(argv)

    n_dev = jax.device_count()
    if n_dev < 8:
        print(f"need 8 (fake) devices, have {n_dev} — set XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8 before importing jax",
              file=sys.stderr)
        return 2

    rows = []

    def emit(row):
        rows.append(row)
        tag = f"{row['mode']}/{row['ways']}-way K={row.get('K', '-')} F={row['F']}"
        print(f"{tag:34s} baseline={row['baseline']:>12.0f}B "
              f"cgtrans={row['cgtrans']:>12.0f}B ratio={row['ratio']:.1f}")

    # mesh scaling at the reference point (K=16, F=128)
    for ways in (1, 2, 4, 8):
        emit(bench_sampled(ways, K=16, F=128))
        emit(bench_full_graph(ways, F=16))

    # the paper figure: the operating point of the ≈50× claim (K≈50) —
    # always measured, even under --fast (benchmarks/run.py keys on it)
    paper_row = bench_sampled(8, K=PAPER_K, F=128)
    paper_row["paper_figure"] = f"50x_claim_at_K{PAPER_K}"
    emit(paper_row)

    if not args.fast:
        # fan-out sweep: the compression ratio should track K
        for K in (4, 16, 64):
            emit(bench_sampled(8, K=K, F=128))
        # feature-width sweep: the ratio is width-independent (both scale ∝ F)
        for F in (32, 128, 512):
            emit(bench_sampled(8, K=16, F=F))

    # per-shard aggregation time: the FAST-GAS kernel inside the sharded
    # dataflow vs the XLA oracle (executed on the 8-way fake mesh)
    for r in bench_agg_time(8):
        rows.append(r)
        print(f"agg_time/{r['ways']}-way impl={r['impl']:<6s} "
              f"{r['us']:>10.0f}us total  {r['us_per_shard']:>9.0f}us/shard")

    # one full train step (fwd + bwd + AdamW): the differentiable pallas
    # path vs the xla oracle — the backward also runs through the kernel
    for r in bench_train_step_time(8):
        rows.append(r)
        print(f"train_step/{r['ways']}-way impl={r['impl']:<6s} "
              f"{r['us']:>10.0f}us/step  loss={r['loss']:.3f}")

    # the paper's claim, asserted: sampled compression ≈ fan-out (same
    # threshold as tests/distributed_cases.py::case_cgtrans_collective_bytes),
    # plus the headline ≥30× at the paper's K≈50 operating point
    checked = [r for r in rows if r["mode"] == "sampled" and r["ways"] == 8]
    failures = []            # (row, threshold-it-missed) — one entry per row
    for r in checked:
        thresh = max(r["K"] / 4,
                     PAPER_MIN_RATIO if r.get("paper_figure") else 0.0)
        if r["ratio"] <= thresh:
            failures.append((r, thresh))
    summary = {
        "claim": "baseline/cgtrans collective bytes > K/4 on the 8-way mesh; "
                 f">= {PAPER_MIN_RATIO}x at the paper's K={PAPER_K}",
        "checked": len(checked),
        "failed": len(failures),
        "max_ratio": max((r["ratio"] for r in checked), default=0.0),
        "paper_figure_ratio": paper_row["ratio"],
    }
    out = {"jax_version": jax.__version__, "devices": n_dev,
           "rows": rows, "summary": summary}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}: {len(rows)} rows; "
          f"{summary['checked'] - summary['failed']}/{summary['checked']} "
          f"sampled rows beat their threshold "
          f"(max ratio {summary['max_ratio']:.1f}×)")
    if failures:
        for r, thresh in failures:
            print(f"FAIL: K={r['K']} F={r['F']} ratio={r['ratio']:.2f} "
                  f"≤ {thresh:.1f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
