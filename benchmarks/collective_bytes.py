"""Collective-bytes benchmark — the paper's CGTrans mechanism, measured.

Lowers BOTH dataflows of ``repro.core.cgtrans`` (full-graph edge COO and
sampled GraphSAGE) on 1/2/4/8-way data meshes, extracts the interconnect
bytes from the compiled HLO via ``repro.launch.hlo_analysis``, sweeps the
sampling fan-out K and feature width F, and writes the trajectory to
``BENCH_collective_bytes.json``.

The headline: baseline (GCNAX-style raw transmission) ships O(B·K·F) bytes,
CGTrans ships O(B·F) — the ratio tracks the fan-out K, reproducing the
paper's fan-in compression at the paper's own operating point (K≈50, the
``paper_figure`` row, asserted ≥ 30×).

Measurements per run:

* byte rows — compile-time only (HLO diffing), seconds on the 8-way
  fake-device CPU topology. The 1-way points are skipped: a single shard
  has zero collective bytes by construction, so their ``ratio=0`` rows were
  degenerate noise in the JSON.
* ``agg_time`` rows — the per-shard aggregation wall time of the sharded
  cgtrans dataflow: ``impl="xla"`` vs ``impl="pallas"`` unscheduled vs
  ``impl="pallas"`` with the destination-binned edge schedule
  (``build_edge_schedule`` hoisted, the multi-layer deployment — the
  counting sort is paid once per (partition, batch), which is what
  ``gcn_forward_full`` does). Timings are interleaved best-of-N: this box
  shares 2 cores across 8 fake devices and run-to-run noise exceeds the
  effect, so the minimum is the only stable estimator.
* ``sched_build`` row — the one-time cost of building that schedule.
* ``skip_rate`` rows — the idle-skip mechanism, counted not timed: live vs
  total (row-block × edge-tile) rounds on a clustered graph, scheduled
  (banded walk) vs unscheduled (dense occupancy). Paper Fig 11(c).
* ``train_step_time`` rows — one full jitted GraphSAGE **train step**
  (forward + backward + AdamW) on the 8-way mesh, ``impl="xla"`` vs
  ``impl="pallas"`` scheduled/unscheduled — the kernel carries custom VJPs,
  so the backward runs through FAST-GAS too.
* ``partition`` rows — islandized locality partitioning, counted: the same
  scrambled-id clustered graph split by the plain interval cut vs
  ``partition_graph(method="island")`` (``repro.graph.islandize``), with the
  remote all_to_all destination rows (``remote_destination_rows``, summed
  and worst-shard) and the dense (row-block × edge-tile) live rounds per
  layout. Asserted by the exit code via ``check_partition_rows``: the
  islandized layout must STRICTLY beat the interval cut on both counters,
  with total rounds unchanged (the relabeling is a pure permutation).
* ``coalesce``/``coalesce_grad`` rows — request coalescing, counted: the
  sage-shaped two-stream fetch (self-row lookup + 2-hop block) issued as
  ONE ``aggregate_multi`` command block vs two ``aggregate_sampled`` calls.
  Collectives-per-step (jaxpr-level all_gather/all_to_all counts,
  deterministic) go 2 → 1 on cgtrans and halve on baseline; kernel gathers
  go 2 → 1; pallas fwd+bwd kernel scatters go 3 → 2 (one backward cotangent
  scatter instead of two). Asserted by the exit code via
  ``check_coalesce_rows``.
* ``wire`` rows — the compressed wire format (``repro.core.wire``): the
  same cgtrans sampled dataflow lowered under ``wire="f32"/"bf16"/"int8"``
  at the paper's K=50, with per-collective bytes split out of the compiled
  HLO. The all_gather ships int16 delta-encoded ids (2×), the all_to_all
  ships bf16 (2×) or int8+bitcast scales (≈3.9×) partials. Asserted by the
  exit code via ``check_wire_rows``: per-collective floors at F=128 (the
  id stream's int16 floor caps the combined int8 total there — recorded,
  not hidden), total floors ≥1.9× (bf16) / ≥3.5× (int8) at F=512, and
  collective COUNTS identical to the f32 wire in every row.
* ``serving``/``serving_cache`` rows — the online serving engine, counted:
  a queue of N concurrent single-seed callers drains as ONE fused command
  block (finds-per-query 1/N, mesh collectives-per-query 2/N, bit-exact
  with the one-query-one-dispatch baseline) and the hot-vertex cache hit
  rate on a deterministic hot-set replay. Asserted by the exit code via
  ``check_serving_rows`` against the ``SERVE_FETCH_*`` contract tables.

Interpret-mode caveat: off-TPU the kernel runs in the Pallas interpreter,
which pays a fixed emulation cost per grid round and per dispatch; treat
absolute pallas-vs-xla times as a correctness-path comparison biased
AGAINST the kernel (native XLA scatters pay none of that), and read the
``skip_rate`` rows for the mechanism the schedule buys on hardware.

``benchmarks/run.py`` runs this script and folds the rows into its CSV.

Run:  PYTHONPATH=src python benchmarks/collective_bytes.py [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cgtrans  # noqa: E402
from repro.core import sparse as sparsefmt  # noqa: E402
from repro.graph import partition_by_src, uniform_graph  # noqa: E402
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402

FLOWS = ("baseline", "cgtrans")
PAPER_K = 50          # paper §4.2: GraphSAGE samples 50 neighbors
PAPER_MIN_RATIO = 30  # the ≈50× claim, with slack for collective overheads


def _collective_bytes(fn, *args) -> float:
    comp = jax.jit(fn).lower(*args).compile()
    return H.analyze(comp.as_text()).collective_bytes


def bench_sampled(ways: int, K: int, F: int, B_loc: int = 32,
                  part: int = 64) -> dict:
    """Sampled GraphSAGE aggregation: B_loc seeds/shard, fan-out K, width F."""
    mesh = make_data_mesh(ways) if ways > 1 else None
    feats = jnp.zeros((max(ways, 1), part, F))
    nbrs = jnp.zeros((max(ways, 1), B_loc, K), jnp.int32)
    mask = jnp.ones((max(ways, 1), B_loc, K), bool)
    row = {"mode": "sampled", "ways": ways, "K": K, "F": F,
           "B_loc": B_loc, "part": part}
    for flow in FLOWS:
        row[flow] = _collective_bytes(
            lambda f, n, m, fl=flow: cgtrans.aggregate_sampled(
                f, n, m, mesh=mesh, dataflow=fl), feats, nbrs, mask)
    row["ratio"] = row["baseline"] / row["cgtrans"] if row["cgtrans"] else 0.0
    return row


def bench_full_graph(ways: int, F: int, V: int = 256, E: int = 4096) -> dict:
    """Full-graph edge COO aggregation on a partitioned uniform graph."""
    mesh = make_data_mesh(ways) if ways > 1 else None
    g = uniform_graph(V, E, seed=1, n_features=F, weights=True)
    pg = partition_by_src(g, max(ways, 1))
    args = (jnp.asarray(pg.features), jnp.asarray(pg.src), jnp.asarray(pg.dst),
            jnp.asarray(pg.weights), jnp.asarray(pg.mask))
    row = {"mode": "full", "ways": ways, "V": V, "E": E, "F": F,
           "avg_fanin": E / V}
    for flow in FLOWS:
        row[flow] = _collective_bytes(
            lambda *a, fl=flow: cgtrans.aggregate_edges(
                *a, mesh=mesh, dataflow=fl), *args)
    row["ratio"] = row["baseline"] / row["cgtrans"] if row["cgtrans"] else 0.0
    return row


def _interleaved_min_us(fns: dict, run_one, trials: int = 9,
                        reps: int = 3) -> dict:
    """Best-of-N wall time per labelled fn, trials interleaved so machine
    drift (this box: 2 cores under 8 fake devices) hits every candidate
    equally. Returns label → best mean-of-reps in µs."""
    best = {k: float("inf") for k in fns}
    for _ in range(trials):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            for _ in range(reps):
                run_one(fn)
            best[k] = min(best[k], (time.perf_counter() - t0) / reps * 1e6)
    return best


def bench_agg_time(ways: int = 8, V: int = 256, E: int = 4096,
                   F: int = 16) -> list:
    """Per-shard aggregation wall time of the sharded cgtrans dataflow:
    impl="xla" vs impl="pallas" unscheduled vs scheduled (hoisted
    destination-binned schedule — the multi-layer deployment). Actually
    executed, not just lowered; interleaved best-of-N timing."""
    mesh = make_data_mesh(ways)
    g = uniform_graph(V, E, seed=1, n_features=F, weights=True)
    pg = partition_by_src(g, ways)
    args = (jnp.asarray(pg.features), jnp.asarray(pg.src), jnp.asarray(pg.dst),
            jnp.asarray(pg.weights), jnp.asarray(pg.mask))

    build = jax.jit(lambda d, m: cgtrans.build_edge_schedule(
        d, m, V, mesh=mesh))
    sched = jax.block_until_ready(build(args[2], args[4]))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(build(args[2], args[4]))
    sched_us = (time.perf_counter() - t0) / 5 * 1e6
    # the schedule is paid once per (partition, batch): the edge list is
    # restructured here (SGCN-style) and every timed call consumes it
    s_args = (args[0],) + cgtrans.apply_edge_schedule(sched, *args[1:])

    f_xla = jax.jit(lambda *a: cgtrans.aggregate_edges(
        *a, mesh=mesh, dataflow="cgtrans", impl="xla"))
    f_uns = jax.jit(lambda *a: cgtrans.aggregate_edges(
        *a, mesh=mesh, dataflow="cgtrans", impl="pallas", scheduled=False))
    f_sch = jax.jit(lambda *a: cgtrans.aggregate_edges(
        *a, mesh=mesh, dataflow="cgtrans", impl="pallas",
        schedule=sched, schedule_applied=True))
    fns = {
        ("xla", False): lambda: jax.block_until_ready(f_xla(*args)),
        ("pallas", False): lambda: jax.block_until_ready(f_uns(*args)),
        ("pallas", True): lambda: jax.block_until_ready(f_sch(*s_args)),
    }
    for fn in fns.values():
        fn()                                         # compile + warm
    best = _interleaved_min_us(fns, lambda fn: fn())
    rows = [{"mode": "agg_time", "ways": ways, "V": V, "E": E, "F": F,
             "impl": impl, "scheduled": scheduled, "us": us,
             "us_per_shard": us / ways}
            for (impl, scheduled), us in best.items()]
    rows.append({"mode": "sched_build", "ways": ways, "V": V, "E": E,
                 "us": sched_us})
    return rows


def bench_skip_rate(ways: int = 8, V: int = 1024, E: int = 16384) -> list:
    """The idle-skip mechanism, counted: live vs total (row-block ×
    edge-tile) rounds per shard on a CLUSTERED graph (paper Fig 11(c)'s
    favorable case), scheduled (banded walk) vs unscheduled (dense
    occupancy bitmap). Uniform graphs are the adversary — shown alongside."""
    from repro.graph import clustered_graph
    from repro.kernels.gas_scatter import kernel as K
    from repro.kernels.gas_scatter import (dense_skip_stats, schedule_edges,
                                           schedule_skip_stats)

    rows = []
    for graph_kind, g in (
            ("clustered", clustered_graph(V, E, n_clusters=V // K.ROW_BLOCK,
                                          p_intra=0.9, seed=3)),
            ("uniform", uniform_graph(V, E, seed=3))):
        pg = partition_by_src(g, ways)
        live_s = total_s = live_u = total_u = 0
        for p in range(ways):
            dst = jnp.asarray(pg.dst[p])
            mask = jnp.asarray(pg.mask[p])
            ls, ts = schedule_skip_stats(schedule_edges(dst, mask, V))
            live_s += ls
            total_s += ts
            lu, tu = dense_skip_stats(dst, mask, V)
            live_u += lu
            total_u += tu
        for scheduled, live, total in ((True, live_s, total_s),
                                       (False, live_u, total_u)):
            rows.append({
                "mode": "skip_rate", "ways": ways, "V": V, "E": E,
                "graph": graph_kind, "scheduled": scheduled,
                "live_rounds": live, "total_rounds": total,
                "skipped_rounds": total - live,
                "skip_rate": 1.0 - live / total,
            })
    return rows


def bench_partition(ways: int = 8, V: int = 1024, E: int = 8192,
                    n_clusters: int = 8, p_intra: float = 0.95) -> list:
    """Islandized locality partitioning, counted: the same scrambled-id
    clustered graph split two ways — the plain contiguous-id interval cut vs
    ``partition_graph(method="island")`` (BFS island growing + boundary
    refinement + aligned packing, host-side, once per graph). Two counters
    per layout, both deterministic:

    * ``remote_rows`` — distinct live destination rows each shard must ship
      through the all_to_all because another shard owns them (summed, plus
      the max shard for the tail), from ``remote_destination_rows``;
    * ``live_rounds`` — dense (row-block × edge-tile) occupancy of the raw
      per-shard edge streams (``dense_skip_stats``): the relabeling packs
      communities into contiguous row blocks, so occupancy goes near
      block-diagonal even before the destination-binned schedule runs.

    The ids are scrambled through a fixed permutation first — on id-ordered
    clusters the interval cut is already island-aligned and there is nothing
    to win; scrambled ids are the honest (and realistic) adversary.
    """
    from repro.graph import (COOGraph, clustered_graph, partition_graph,
                             remote_destination_rows)
    from repro.kernels.gas_scatter import dense_skip_stats

    g0 = clustered_graph(V, E, n_clusters=n_clusters, p_intra=p_intra, seed=3)
    perm = np.random.default_rng(1003).permutation(V).astype(np.int32)
    g = COOGraph(V, perm[g0.src], perm[g0.dst], g0.weights, None)

    rows = []
    for method in ("interval", "island"):
        pg, _ = partition_graph(g, ways, method=method)
        rr = remote_destination_rows(pg)
        live = total = 0
        for p in range(ways):
            lv, tt = dense_skip_stats(jnp.asarray(pg.dst[p]),
                                      jnp.asarray(pg.mask[p]), V)
            live += lv
            total += tt
        rows.append({
            "mode": "partition", "ways": ways, "V": V, "E": E,
            "n_clusters": n_clusters, "p_intra": p_intra, "method": method,
            "remote_rows": int(rr.sum()),
            "remote_rows_max_shard": int(rr.max()),
            "live_rounds": int(live), "total_rounds": int(total),
        })
    by = {r["method"]: r for r in rows}
    for r in rows:
        r["remote_rows_vs_interval"] = (
            r["remote_rows"] / max(by["interval"]["remote_rows"], 1))
        r["live_rounds_vs_interval"] = (
            r["live_rounds"] / max(by["interval"]["live_rounds"], 1))
    return rows


def check_partition_rows(rows) -> list:
    """The islandization mechanism, asserted deterministically: on the
    scrambled-id clustered graph the islandized layout must STRICTLY beat
    the interval cut on both counters — fewer remote destination rows
    (summed and on the worst shard) and fewer dense live rounds. Returns
    failure strings (empty = the claim holds)."""
    by = {r["method"]: r for r in rows if r["mode"] == "partition"}
    iv, isl = by["interval"], by["island"]
    failures = []
    if isl["remote_rows"] >= iv["remote_rows"]:
        failures.append(
            f"islandized remote destination rows ({isl['remote_rows']}) not "
            f"below the interval cut ({iv['remote_rows']})")
    if isl["remote_rows_max_shard"] >= iv["remote_rows_max_shard"]:
        failures.append(
            f"islandized worst-shard remote rows "
            f"({isl['remote_rows_max_shard']}) not below the interval cut "
            f"({iv['remote_rows_max_shard']})")
    if isl["live_rounds"] >= iv["live_rounds"]:
        failures.append(
            f"islandized dense live rounds ({isl['live_rounds']}) not below "
            f"the interval cut ({iv['live_rounds']})")
    if isl["total_rounds"] != iv["total_rounds"]:
        failures.append(
            f"total rounds changed under relabeling "
            f"({isl['total_rounds']} vs {iv['total_rounds']}) — the "
            f"relabeling must be a pure permutation")
    return failures


def bench_coalesce(ways: int = 8, B: int = 8, K1: int = 3, K2: int = 10,
                   F: int = 64, part: int = 32) -> list:
    """Request coalescing, measured the way it is claimed: DETERMINISTIC
    counters, not wall clock. For a sage-shaped request pair (the K=1
    self-row lookup + the fan-out-K2 2-hop block), count what the separate
    two-stream form issues vs the coalesced ``aggregate_multi`` command
    block:

    * collectives per step (jaxpr-level, immune to XLA combiner passes):
      all_gather (the request broadcast) and all_to_all (the result
      shipment) — cgtrans: 2 → 1 each;
    * GAS engine dispatches (trace-time counters): finds 2 → 1, and under
      pallas the fwd+bwd kernel scatters 3 → 2 (ONE backward cotangent
      scatter where the separate form pays two);
    * collective bytes from the compiled HLO, for the record (coalescing
      is about round-trips; bytes stay ≈ equal by construction).
    """
    from repro.core import gas
    from repro.launch.jaxpr_stats import collective_counts

    mesh = make_data_mesh(ways)
    R1 = B * (1 + K1)
    feats = jnp.zeros((ways, part, F))
    b1 = (jnp.zeros((ways, R1, 1), jnp.int32), jnp.ones((ways, R1, 1), bool))
    b2 = (jnp.zeros((ways, R1, K2), jnp.int32),
          jnp.ones((ways, R1, K2), bool))

    def sep(f, flow, impl="xla"):
        a = cgtrans.aggregate_sampled(f, *b1, mesh=mesh, dataflow=flow,
                                      impl=impl)
        b = cgtrans.aggregate_sampled(f, *b2, mesh=mesh, dataflow=flow,
                                      impl=impl)
        return a, b

    def coa(f, flow, impl="xla"):
        return cgtrans.aggregate_multi(f, (b1, b2), mesh=mesh, dataflow=flow,
                                       impl=impl)

    rows = []
    for flow in FLOWS:
        for form, fn in (("separate", sep), ("coalesced", coa)):
            with gas.count_dispatches() as disp:
                colls = collective_counts(lambda f: fn(f, flow), feats)
            rows.append({
                "mode": "coalesce", "ways": ways, "flow": flow, "form": form,
                "B": B, "K1": K1, "K2": K2, "F": F,
                "all_gather": int(colls["all_gather"]),
                "all_to_all": int(colls["all_to_all"]),
                "finds": int(disp["find"]), "reduces": int(disp["reduce"]),
                "bytes": _collective_bytes(lambda f: fn(f, flow), feats),
            })

    # the backward, counted on the pallas path: grad-of-sum traces the
    # custom VJPs, so the kernel_scatter count covers fwd + bwd dispatches
    for form, fn in (("separate", sep), ("coalesced", coa)):
        with gas.count_dispatches() as disp:
            jax.make_jaxpr(jax.grad(
                lambda f: sum(jnp.sum(o) for o in
                              fn(f, "cgtrans", "pallas"))))(feats)
        rows.append({
            "mode": "coalesce_grad", "ways": ways, "flow": "cgtrans",
            "form": form, "impl": "pallas",
            "finds": int(disp["find"]),
            "kernel_scatters": int(disp["kernel_scatter"]),
        })
    return rows


def check_coalesce_rows(rows) -> list:
    """The coalescing mechanism, asserted deterministically. Returns a list
    of failure strings (empty = the claim holds). Every expected count is
    imported from ``repro.analysis.contracts`` — the committed budget table
    the lint tier verifies against the abstract traces — so this bench, the
    coalesce test tier and the contracts can never disagree."""
    from repro.analysis.contracts import (SAGE_FETCH_COLLECTIVES,
                                          SAGE_FETCH_DISPATCH,
                                          SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD)

    by = {(r["flow"], r["form"]): r for r in rows if r["mode"] == "coalesce"}
    gby = {r["form"]: r for r in rows if r["mode"] == "coalesce_grad"}
    failures = []

    for form in ("separate", "coalesced"):
        r = by[("cgtrans", form)]
        budget = SAGE_FETCH_COLLECTIVES[form]
        if not all(r[c] == n for c, n in budget.items()):
            failures.append(f"{form} cgtrans must issue exactly {budget} "
                            f"collectives per step, saw {r}")
    bs, bc = by[("baseline", "separate")], by[("baseline", "coalesced")]
    if not (bc["all_gather"] * 2 == bs["all_gather"]
            and bc["all_to_all"] * 2 == bs["all_to_all"]):
        failures.append(f"coalescing must halve baseline collectives, saw "
                        f"sep={bs} coa={bc}")
    finds = {form: SAGE_FETCH_DISPATCH[form]["find"]
             for form in ("separate", "coalesced")}
    for flow in FLOWS:
        s, c = by[(flow, "separate")], by[(flow, "coalesced")]
        if not (s["finds"] == finds["separate"]
                and c["finds"] == finds["coalesced"]):
            failures.append(f"{flow}: kernel gathers must go "
                            f"{finds['separate']} → {finds['coalesced']}, "
                            f"saw sep={s['finds']} coa={c['finds']}")
    gs, gc = gby["separate"], gby["coalesced"]
    ks = SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD
    if not (gs["kernel_scatters"] == ks["separate"]
            and gc["kernel_scatters"] == ks["coalesced"]):
        failures.append(
            f"pallas fwd+bwd kernel scatters must go {ks['separate']} → "
            f"{ks['coalesced']} (one backward cotangent scatter instead of "
            f"two), saw sep={gs['kernel_scatters']} "
            f"coa={gc['kernel_scatters']}")
    return failures


def _collective_detail(fn, *args):
    """(total collective bytes, per-kind {count, bytes}) of the lowered HLO."""
    comp = jax.jit(fn).lower(*args).compile()
    s = H.analyze(comp.as_text())
    return s.collective_bytes, s.collectives


def bench_wire(ways: int = 8, B_loc: int = 32, part: int = 64) -> list:
    """The compressed wire format (``repro.core.wire``), measured at the
    paper's K=50 operating point: the SAME cgtrans dataflow lowered under
    ``wire="f32"/"bf16"/"int8"``, per-collective bytes split out of the
    compiled HLO.

    What moves: the all_gather ships int16 delta-encoded ids (2× under any
    narrow wire), the all_to_all ships bf16 (2×) or int8+scales (≈3.9×)
    partials. What the TOTAL shows depends on F — at F=128 the id stream's
    int16 floor caps the combined int8 win near 3×, so the per-collective
    ratios carry the claim there; at F=512 the payload dominates and the
    totals themselves clear 1.9×/3.5×. Both operating points are emitted so
    the JSON records the floor instead of hiding it.
    """
    mesh = make_data_mesh(ways)
    rows = []
    for K, F in ((PAPER_K, 128), (PAPER_K, 512)):
        feats = jnp.zeros((ways, part, F))
        nbrs = jnp.zeros((ways, B_loc, K), jnp.int32)
        mask = jnp.ones((ways, B_loc, K), bool)
        for w in ("f32", "bf16", "int8"):
            total, colls = _collective_detail(
                lambda f, n, m, ww=w: cgtrans.aggregate_sampled(
                    f, n, m, mesh=mesh, dataflow="cgtrans", wire=ww),
                feats, nbrs, mask)
            rows.append({
                "mode": "wire", "ways": ways, "K": K, "F": F,
                "B_loc": B_loc, "part": part, "wire": w, "bytes": total,
                "all_gather_bytes": colls["all-gather"]["bytes"],
                "all_to_all_bytes": colls["all-to-all"]["bytes"],
                "all_gather_count": colls["all-gather"]["count"],
                "all_to_all_count": colls["all-to-all"]["count"],
            })
    return rows


#: byte-ratio floors the wire rows must clear (vs the f32 wire, K=50):
#: nominal 2× (bf16/int16) and 4× (int8) minus slack for the scale columns
#: and lowering noise
WIRE_MIN_BF16 = 1.9
WIRE_MIN_INT8 = 3.5


def check_wire_rows(rows) -> list:
    """The wire-format mechanism, asserted deterministically (compiled-HLO
    bytes, never clocks). Returns failure strings (empty = the claims
    hold).

    * every narrow wire must keep the COLLECTIVE COUNTS of the f32 wire
      (compression that added a round-trip would be a regression);
    * F=128 (the paper-figure row): per-collective ratios — bf16 total
      ≥ 1.9×, int8 all_to_all ≥ 3.5×, int8 all_gather ≥ 1.9× (the id
      stream's int16 floor is declared, not asserted away);
    * F=512: the TOTALS clear the same floors — bf16 ≥ 1.9×, int8 ≥ 3.5×.
    """
    by = {(r["K"], r["F"], r["wire"]): r for r in rows
          if r["mode"] == "wire"}
    failures = []
    for (K, F) in sorted({(k, f) for k, f, _ in by}):
        f32, bf16, int8 = (by[(K, F, w)] for w in ("f32", "bf16", "int8"))
        for narrow in (bf16, int8):
            for c in ("all_gather_count", "all_to_all_count"):
                if narrow[c] != f32[c]:
                    failures.append(
                        f"wire={narrow['wire']} K={K} F={F} changed {c}: "
                        f"{f32[c]:.0f} → {narrow[c]:.0f} (bytes may shrink, "
                        f"counts must not)")
        bf16_total = f32["bytes"] / bf16["bytes"]
        int8_a2a = f32["all_to_all_bytes"] / int8["all_to_all_bytes"]
        int8_gather = f32["all_gather_bytes"] / int8["all_gather_bytes"]
        int8_total = f32["bytes"] / int8["bytes"]
        if bf16_total < WIRE_MIN_BF16:
            failures.append(f"bf16 wire K={K} F={F}: total ratio "
                            f"{bf16_total:.2f} < {WIRE_MIN_BF16}")
        if F >= 512:
            if int8_total < WIRE_MIN_INT8:
                failures.append(f"int8 wire K={K} F={F}: total ratio "
                                f"{int8_total:.2f} < {WIRE_MIN_INT8} (payload-"
                                f"dominated row must clear the full floor)")
        else:
            if int8_a2a < WIRE_MIN_INT8:
                failures.append(f"int8 wire K={K} F={F}: all_to_all ratio "
                                f"{int8_a2a:.2f} < {WIRE_MIN_INT8}")
            if int8_gather < WIRE_MIN_BF16:
                failures.append(f"int8 wire K={K} F={F}: all_gather ratio "
                                f"{int8_gather:.2f} < {WIRE_MIN_BF16} (int16 "
                                f"delta ids must halve the request bytes)")
    return failures


def bench_sparse(ways: int = 8, B_loc: int = 32, part: int = 64,
                 K: int = 10, F: int = 512) -> list:
    """Compressed-sparse features (``repro.core.sparse``): the baseline
    raw-row shipment lowered per measured density — synthetic tables at
    density 0.1 / 0.3 / 1.0, capacity MEASURED from each table
    (``table_capacity``, the entrypoints' own gate input), per-collective
    bytes split from the compiled HLO plus the analytic SSD→host bytes per
    gathered row (capacity + bitmap words vs F dense lanes — the codec is
    deterministic, so the per-row arithmetic IS the claim).

    Density 1.0 is the control: ``table_capacity`` returns F, the
    ``sparse_fits`` gate fails, and the path must ship the EXACT dense
    bytes — compression that couldn't win must cost nothing.
    """
    mesh = make_data_mesh(ways)
    rng = np.random.default_rng(0)
    rows = []
    nbrs = jnp.zeros((ways, B_loc, K), jnp.int32)
    mask = jnp.ones((ways, B_loc, K), bool)

    def lower(features, cap):
        return _collective_detail(
            lambda f, n, m: cgtrans.aggregate_sampled(
                f, n, m, mesh=mesh, dataflow="baseline", features=features,
                sparse_capacity=cap),
            jnp.zeros((ways, part, F)), nbrs, mask)

    dense_total, dense_colls = lower("dense", None)
    wpr = sparsefmt.bitmap_words(F)
    for density in (0.1, 0.3, 1.0):
        vals = np.round(rng.standard_normal((ways, part, F)) * 5.0)
        feats = np.where(rng.random(vals.shape) < density,
                         np.where(vals == 0, 1.0, vals), 0.0)
        cap = sparsefmt.table_capacity(feats)
        fits = sparsefmt.sparse_fits(cap, F)
        total, colls = lower("sparse", cap)
        ssd_dense = F * 4
        ssd_sparse = (cap + wpr) * 4 if fits else ssd_dense
        rows.append({
            "mode": "sparse", "ways": ways, "K": K, "F": F, "B_loc": B_loc,
            "part": part, "density": sparsefmt.density_stats(feats)["density"],
            "target_density": density, "capacity": cap, "fits": fits,
            "bytes": total, "dense_bytes": dense_total,
            "all_to_all_bytes": colls["all-to-all"]["bytes"],
            "dense_all_to_all_bytes": dense_colls["all-to-all"]["bytes"],
            "all_gather_count": colls["all-gather"]["count"],
            "all_to_all_count": colls["all-to-all"]["count"],
            "dense_all_gather_count": dense_colls["all-gather"]["count"],
            "dense_all_to_all_count": dense_colls["all-to-all"]["count"],
            "ssd_bytes_per_row": ssd_sparse,
            "dense_ssd_bytes_per_row": ssd_dense,
        })
    return rows


#: all_to_all byte-ratio floors the sparse rows must clear vs the dense
#: shipment: ≈3.5× nominal at density 0.1 (capacity 128 + 16 bitmap words
#: vs 512 lanes) asserted at 2×; ≈1.9× nominal at 0.3 asserted at 1.5×
SPARSE_MIN_D01 = 2.0
SPARSE_MIN_D03 = 1.5


def check_sparse_rows(rows) -> list:
    """The sparse-feature mechanism, asserted deterministically
    (compiled-HLO bytes + codec arithmetic, never clocks). Returns failure
    strings (empty = the claims hold).

    * collective COUNTS equal the dense twin's at every density;
    * density 0.1: all_to_all bytes ≥ 2× smaller AND SSD→host bytes per
      gathered row ≥ 2× smaller;
    * density 0.3: both ratios ≥ 1.5×;
    * density 1.0: the gate falls back — bytes EXACTLY the dense bytes.
    """
    failures = []
    floors = {0.1: SPARSE_MIN_D01, 0.3: SPARSE_MIN_D03}
    for r in (r for r in rows if r["mode"] == "sparse"):
        d = r["target_density"]
        for c in ("all_gather_count", "all_to_all_count"):
            if r[c] != r[f"dense_{c}"]:
                failures.append(
                    f"sparse density={d} changed {c}: {r[f'dense_{c}']:.0f} "
                    f"→ {r[c]:.0f} (bytes may shrink, counts must not)")
        if d in floors:
            a2a = r["dense_all_to_all_bytes"] / r["all_to_all_bytes"]
            ssd = r["dense_ssd_bytes_per_row"] / r["ssd_bytes_per_row"]
            if a2a < floors[d]:
                failures.append(f"sparse density={d}: all_to_all ratio "
                                f"{a2a:.2f} < {floors[d]}")
            if ssd < floors[d]:
                failures.append(f"sparse density={d}: SSD row ratio "
                                f"{ssd:.2f} < {floors[d]}")
        else:                    # density 1.0 — the gate-fallback control
            if r["fits"]:
                failures.append("sparse density=1.0 capacity cleared the "
                                "gate — table_capacity is broken")
            if r["bytes"] != r["dense_bytes"]:
                failures.append(
                    f"sparse density=1.0 gate fallback moved "
                    f"{r['bytes']:.0f}B ≠ dense {r['dense_bytes']:.0f}B — "
                    f"a losing compression must cost nothing")
    return failures


def bench_serving(ways: int = 8, V: int = 64, F: int = 16,
                  fanout: int = 10) -> list:
    """Online serving, counted the way it is claimed: a queue of N
    concurrent single-seed callers drains as ONE fused ``aggregate_multi``
    command block vs the one-query-one-dispatch baseline (same requests,
    same neighbor samples). Rows record

    * finds-per-query (``gas.count_dispatches`` on the executed drain):
      fused 1/N vs naive 1;
    * collectives-per-query (jaxpr-level all_gather/all_to_all on the
      8-way mesh trace of the exact same blocks): fused 2/N vs naive 2;
    * bit-exactness of the fused scatter-back against the baseline;
    * the hot-vertex cache hit rate on a deterministic hot-set replay
      (4 waves over the same seeds — wave 1 fills, waves 2–4 hit).

    Asserted by the exit code via ``check_serving_rows`` against the
    ``SERVE_FETCH_*`` budget tables in ``repro.analysis.contracts``.
    """
    from repro.analysis.contracts import SERVE_CONTRACT_N
    from repro.launch.jaxpr_stats import collective_counts
    from repro.serving import ServingEngine

    n = SERVE_CONTRACT_N
    g = uniform_graph(V, 6 * V, seed=5)
    indptr, indices, _ = g.to_csr()
    rng = np.random.default_rng(7)
    feats = rng.integers(-5, 6, (V, F)).astype(np.float32)
    seeds = [int(s) for s in rng.integers(0, V, n)]

    # the executed drains run un-sharded (the find counters and the
    # bit-exactness claim are mesh-independent); the collective counts come
    # from the ABSTRACT mesh trace of the identical blocks below
    rows, results = [], {}
    engines = {}
    for form, fuse in (("fused", True), ("naive_per_query", False)):
        eng = ServingEngine(feats, indptr, indices, fanout=fanout,
                            max_batch=n, fuse=fuse)
        rids = [eng.submit([s], tenant=j) for j, s in enumerate(seeds)]
        eng.flush()
        results[form] = [eng.result(r) for r in rids]
        engines[form] = eng

    mesh = make_data_mesh(ways)
    trace_eng = ServingEngine(feats, indptr, indices, fanout=fanout,
                              max_batch=n, mesh=mesh)
    for j, s in enumerate(seeds):
        trace_eng.submit([s], tenant=j)
    fn, fargs = trace_eng.fetch_callable()
    fused_colls = collective_counts(fn, *fargs)
    blocks = fargs[1]

    def naive_trace(f, blocks_):
        outs = []
        for j in range(n):
            outs.extend(cgtrans.aggregate_multi(
                f, blocks_[2 * j:2 * j + 2], mesh=mesh, dataflow="cgtrans"))
        return tuple(outs)

    naive_colls = collective_counts(naive_trace, fargs[0], blocks)

    bitexact = all(
        np.array_equal(a.self_rows, b.self_rows)
        and np.array_equal(a.agg_rows, b.agg_rows)
        for a, b in zip(results["fused"], results["naive_per_query"]))
    for form, colls in (("fused", fused_colls),
                        ("naive_per_query", naive_colls)):
        eng = engines[form]
        rows.append({
            "mode": "serving", "ways": ways, "form": form, "N": n,
            "V": V, "F": F, "fanout": fanout,
            "command_blocks": eng.stats["command_blocks"],
            "finds": eng.stats["find"],
            "finds_per_query": eng.finds_per_query(),
            "all_gather": int(colls["all_gather"]),
            "all_to_all": int(colls["all_to_all"]),
            "collectives_per_query":
                (colls["all_gather"] + colls["all_to_all"]) / n,
            "bitexact_vs_naive": bool(bitexact),
        })

    # the hot-vertex cache: 4 waves over one hot seed set — wave 1 is all
    # misses (and fills), waves 2–4 are all hits → hit_rate 0.75, counted
    hot = [int(h) for h in rng.choice(V, n, replace=False)]
    ceng = ServingEngine(feats, indptr, indices, fanout=fanout,
                         max_batch=n, cache_capacity=2 * n)
    waves = 4
    for _ in range(waves):
        for j, s in enumerate(hot):
            ceng.submit([s], tenant=j)
        ceng.flush()
    snap = ceng.cache.snapshot()
    rows.append({
        "mode": "serving_cache", "ways": 1, "N": n, "waves": waves,
        "V": V, "F": F, "capacity": ceng.cache.capacity,
        "hits": snap["hits"], "misses": snap["misses"],
        "hit_rate": snap["hit_rate"],
        "finds_per_query": ceng.finds_per_query(),
    })
    return rows


def check_serving_rows(rows) -> list:
    """The serving mechanism, asserted deterministically (counters, never
    clocks). Returns failure strings (empty = the claims hold). Budgets
    come from the ``SERVE_FETCH_*`` tables in ``repro.analysis.contracts``
    — the same single source the serve test tier and the lint contracts
    pin — so the bench can never drift from them."""
    from repro.analysis.contracts import (SERVE_CONTRACT_N,
                                          SERVE_FETCH_COLLECTIVES,
                                          SERVE_FETCH_FINDS)

    by = {r["form"]: r for r in rows if r["mode"] == "serving"}
    cache_rows = [r for r in rows if r["mode"] == "serving_cache"]
    failures = []
    f, nv = by["fused"], by["naive_per_query"]
    n = f["N"]
    if n < SERVE_CONTRACT_N:
        failures.append(f"serving rows must batch N >= {SERVE_CONTRACT_N} "
                        f"concurrent requests, saw N={n}")
    if f["command_blocks"] != 1:
        failures.append(f"a fused drain of {n} requests must dispatch ONE "
                        f"command block, saw {f['command_blocks']}")
    if f["finds"] != SERVE_FETCH_FINDS["fused"]:
        failures.append(f"fused drain must issue "
                        f"{SERVE_FETCH_FINDS['fused']} find, saw "
                        f"{f['finds']}")
    if nv["finds"] != SERVE_FETCH_FINDS["naive_per_query"] * n:
        failures.append(f"naive baseline must issue one find per query "
                        f"({n}), saw {nv['finds']}")
    for coll, want in SERVE_FETCH_COLLECTIVES["fused"].items():
        if f[coll] != want:
            failures.append(f"fused drain must trace {want} {coll}, saw "
                            f"{f[coll]}")
    for coll, per_q in SERVE_FETCH_COLLECTIVES["naive_per_query"].items():
        if nv[coll] != per_q * n:
            failures.append(f"naive baseline must trace {per_q} {coll} per "
                            f"query ({per_q * n} total), saw {nv[coll]}")
    for key in ("finds_per_query", "collectives_per_query"):
        if not f[key] < nv[key]:
            failures.append(f"fused {key} ({f[key]:.3f}) not strictly below "
                            f"the naive baseline ({nv[key]:.3f})")
    if not f["bitexact_vs_naive"]:
        failures.append("fused scatter-back diverged from the sequential "
                        "per-request baseline (must be bit-exact)")
    if not cache_rows or cache_rows[0]["hits"] <= 0:
        failures.append("hot-vertex cache replay recorded zero hits")
    return failures


def bench_train_step_time(ways: int = 8) -> list:
    """Wall time of one jitted GraphSAGE+CGTrans TRAIN step on the sharded
    mesh, impl="xla" vs impl="pallas" scheduled/unscheduled — the
    differentiable-kernel path (forward and backward through FAST-GAS),
    actually executed; interleaved best-of-N timing."""
    import jax.random
    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema
    from repro.data import GraphBatchStream, synthetic_node_labels
    from repro.graph import partition_by_src, uniform_graph
    from repro.optim import adamw_init
    from repro.train import make_sage_train_step

    mesh = make_data_mesh(ways)
    g = uniform_graph(128, 1024, seed=0, n_features=8)
    labels = synthetic_node_labels(g.features, 4)
    pg = partition_by_src(g, ways)
    feats = jnp.asarray(pg.features)
    tc = TrainConfig(learning_rate=1e-3)
    stream = GraphBatchStream(g, labels, n_parts=ways, batch_per_part=4,
                              k1=4, k2=4)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}

    runs = {}
    for key in (("xla", False), ("pallas", True), ("pallas", False)):
        impl, scheduled = key
        cfg = GCNConfig(n_features=8, hidden=16, n_classes=4, fanout=4,
                        impl=impl, scheduled=scheduled)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw_init(params, tc),
                 "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_sage_train_step(cfg, tc, feats=feats, mesh=mesh))
        state, m = step(state, batch)            # compile + warm
        jax.block_until_ready(state)
        runs[key] = {"step": step, "state": state,
                     "loss": float(m["total_loss"])}

    def run_one(r):
        r["state"], _ = r["step"](r["state"], batch)
        jax.block_until_ready(r["state"])

    best = _interleaved_min_us(runs, run_one, trials=7, reps=3)
    return [{"mode": "train_step_time", "ways": ways, "impl": impl,
             "scheduled": scheduled, "us": best[(impl, scheduled)],
             "loss": runs[(impl, scheduled)]["loss"]}
            for impl, scheduled in runs]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_collective_bytes.json")
    ap.add_argument("--fast", action="store_true",
                    help="skip the K/F sweeps; mesh-scaling rows only")
    args = ap.parse_args(argv)

    n_dev = jax.device_count()
    if n_dev < 8:
        print(f"need 8 (fake) devices, have {n_dev} — set XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8 before importing jax",
              file=sys.stderr)
        return 2

    rows = []

    def emit(row):
        rows.append(row)
        tag = f"{row['mode']}/{row['ways']}-way K={row.get('K', '-')} F={row['F']}"
        print(f"{tag:34s} baseline={row['baseline']:>12.0f}B "
              f"cgtrans={row['cgtrans']:>12.0f}B ratio={row['ratio']:.1f}")

    # mesh scaling at the reference point (K=16, F=128). The 1-way point is
    # intentionally absent: one shard moves zero collective bytes, so its
    # baseline=0/ratio=0 row carried no information (and polluted ratio
    # consumers downstream).
    for ways in (2, 4, 8):
        emit(bench_sampled(ways, K=16, F=128))
        emit(bench_full_graph(ways, F=16))

    # the paper figure: the operating point of the ≈50× claim (K≈50) —
    # always measured, even under --fast (benchmarks/run.py keys on it)
    paper_row = bench_sampled(8, K=PAPER_K, F=128)
    paper_row["paper_figure"] = f"50x_claim_at_K{PAPER_K}"
    emit(paper_row)

    if not args.fast:
        # fan-out sweep: the compression ratio should track K
        for K in (4, 16, 64):
            emit(bench_sampled(8, K=K, F=128))
        # feature-width sweep: the ratio is width-independent (both scale ∝ F)
        for F in (32, 128, 512):
            emit(bench_sampled(8, K=16, F=F))

    # per-shard aggregation time: the FAST-GAS kernel inside the sharded
    # dataflow vs the XLA oracle (executed on the 8-way fake mesh),
    # scheduled (banded walk, hoisted schedule) vs unscheduled
    agg_rows = bench_agg_time(8)
    for r in agg_rows:
        rows.append(r)
        if r["mode"] == "sched_build":
            print(f"sched_build/{r['ways']}-way "
                  f"{r['us']:>10.0f}us (once per partition+batch)")
        else:
            tag = "sched" if r["scheduled"] else "unsched"
            print(f"agg_time/{r['ways']}-way impl={r['impl']:<6s} {tag:<7s} "
                  f"{r['us']:>10.0f}us total  {r['us_per_shard']:>9.0f}us/shard")

    # the idle-skip mechanism, counted: scheduled vs dense rounds on a
    # clustered graph (the paper's Fig 11(c) case) and its uniform adversary
    for r in bench_skip_rate(8):
        rows.append(r)
        tag = "sched" if r["scheduled"] else "unsched"
        print(f"skip_rate/{r['graph']:<9s} {tag:<7s} "
              f"{r['live_rounds']:>5d}/{r['total_rounds']:<5d} rounds live  "
              f"skip_rate={r['skip_rate']:.2f}")

    # islandized locality partitioning, counted: on the scrambled-id
    # clustered graph the island relabeling must shrink both the remote
    # all_to_all destination rows and the dense round occupancy
    partition_rows = bench_partition(8)
    for r in partition_rows:
        rows.append(r)
        print(f"partition/{r['method']:<9s} "
              f"remote_rows={r['remote_rows']:>5d} "
              f"(max/shard {r['remote_rows_max_shard']:>4d})  "
              f"dense {r['live_rounds']:>5d}/{r['total_rounds']:<5d} rounds "
              f"live  vs_interval={r['remote_rows_vs_interval']:.2f}")

    # request coalescing, counted: the sage-shaped two-stream fetch as one
    # SSD command block — collectives-per-step 2 → 1 (cgtrans), finds
    # 2 → 1, pallas fwd+bwd kernel scatters 3 → 2; bytes for the record
    coalesce_rows = bench_coalesce(8)
    for r in coalesce_rows:
        rows.append(r)
        if r["mode"] == "coalesce":
            print(f"coalesce/{r['flow']:<8s} {r['form']:<9s} "
                  f"all_gather={r['all_gather']} all_to_all={r['all_to_all']} "
                  f"finds={r['finds']}  {r['bytes']:>10.0f}B")
        else:
            print(f"coalesce_grad/pallas {r['form']:<9s} "
                  f"finds={r['finds']} kernel_scatters={r['kernel_scatters']}")

    # the compressed wire: the same cgtrans dataflow lowered per wire
    # format, per-collective bytes split out — the id stream's int16 floor
    # shows at F=128, the payload-dominated totals at F=512
    wire_rows = bench_wire(8)
    for r in wire_rows:
        rows.append(r)
        print(f"wire/K={r['K']} F={r['F']:<4d} {r['wire']:<5s} "
              f"total={r['bytes']:>9.0f}B  "
              f"gather={r['all_gather_bytes']:>7.0f}B  "
              f"a2a={r['all_to_all_bytes']:>9.0f}B")

    # compressed-sparse features: the baseline raw-row shipment per
    # measured density — bytes scale with density, the density-1.0 control
    # must fall back to the exact dense bytes
    sparse_rows = bench_sparse(8)
    for r in sparse_rows:
        rows.append(r)
        print(f"sparse/d={r['target_density']:<4} cap={r['capacity']:<4d} "
              f"{'fit' if r['fits'] else 'dense'} "
              f"a2a={r['all_to_all_bytes']:>9.0f}B "
              f"(dense {r['dense_all_to_all_bytes']:>9.0f}B)  "
              f"ssd/row={r['ssd_bytes_per_row']:>5d}B "
              f"(dense {r['dense_ssd_bytes_per_row']}B)")

    # online serving, counted: N concurrent callers drain as ONE fused
    # command block — finds-per-query 1/N, collectives-per-query 2/N,
    # bit-exact with the per-request baseline; plus the hot-cache replay
    serving_rows = bench_serving(8)
    for r in serving_rows:
        rows.append(r)
        if r["mode"] == "serving":
            print(f"serving/{r['form']:<15s} N={r['N']} "
                  f"blocks={r['command_blocks']} "
                  f"finds/q={r['finds_per_query']:.3f} "
                  f"colls/q={r['collectives_per_query']:.3f} "
                  f"bitexact={r['bitexact_vs_naive']}")
        else:
            print(f"serving_cache N={r['N']}x{r['waves']}waves "
                  f"hits={r['hits']}/{r['hits'] + r['misses']} "
                  f"hit_rate={r['hit_rate']:.2f} "
                  f"finds/q={r['finds_per_query']:.3f}")

    # one full train step (fwd + bwd + AdamW): the differentiable pallas
    # path vs the xla oracle — the backward also runs through the kernel
    for r in bench_train_step_time(8):
        rows.append(r)
        tag = "sched" if r["scheduled"] else "unsched"
        print(f"train_step/{r['ways']}-way impl={r['impl']:<6s} {tag:<7s} "
              f"{r['us']:>10.0f}us/step  loss={r['loss']:.3f}")

    # the paper's claim, asserted: sampled compression ≈ fan-out (same
    # threshold as tests/distributed_cases.py::case_cgtrans_collective_bytes),
    # plus the headline ≥30× at the paper's K≈50 operating point
    checked = [r for r in rows if r["mode"] == "sampled" and r["ways"] == 8]
    failures = []            # (row, threshold-it-missed) — one entry per row
    for r in checked:
        thresh = max(r["K"] / 4,
                     PAPER_MIN_RATIO if r.get("paper_figure") else 0.0)
        if r["ratio"] <= thresh:
            failures.append((r, thresh))
    agg = {(r["impl"], r.get("scheduled")): r["us"] for r in rows
           if r["mode"] == "agg_time"}
    sk = [r for r in rows if r["mode"] == "skip_rate"
          and r["graph"] == "clustered" and r["scheduled"]]
    co = {(r["flow"], r["form"]): r for r in rows if r["mode"] == "coalesce"}
    summary = {
        "claim": "baseline/cgtrans collective bytes > K/4 on the 8-way mesh; "
                 f">= {PAPER_MIN_RATIO}x at the paper's K={PAPER_K}",
        "checked": len(checked),
        "failed": len(failures),
        "max_ratio": max((r["ratio"] for r in checked), default=0.0),
        "paper_figure_ratio": paper_row["ratio"],
        # the scheduler headline: scheduled pallas vs xla vs unscheduled
        # pallas aggregation time (interleaved best-of-N; see the module
        # docstring for the interpret-mode caveat) + clustered skip rate
        "agg_pallas_sched_vs_xla":
            agg[("pallas", True)] / agg[("xla", False)],
        "agg_sched_vs_unsched_pallas":
            agg[("pallas", True)] / agg[("pallas", False)],
        "clustered_skipped_rounds": sk[0]["skipped_rounds"] if sk else 0,
        # the partitioning headline: what the islandized relabeling removes
        # on the scrambled-id clustered graph, per counter (island/interval,
        # lower is better — asserted strict by check_partition_rows)
        "partition_remote_rows": {
            r["method"]: r["remote_rows"] for r in partition_rows},
        "partition_dense_live_rounds": {
            r["method"]: r["live_rounds"] for r in partition_rows},
        # the coalescing headline: collectives-per-step on the cgtrans
        # sampled path, separate two-stream form vs the coalesced command
        # block (each = all_gather + all_to_all counts, deterministic)
        "coalesce_collectives_separate":
            co[("cgtrans", "separate")]["all_gather"]
            + co[("cgtrans", "separate")]["all_to_all"],
        "coalesce_collectives_coalesced":
            co[("cgtrans", "coalesced")]["all_gather"]
            + co[("cgtrans", "coalesced")]["all_to_all"],
        # the serving headline: per-query amortization at N concurrent
        # callers, plus what the hot cache removes on the skewed replay
        "serving_finds_per_query": {
            r["form"]: r["finds_per_query"] for r in serving_rows
            if r["mode"] == "serving"},
        "serving_collectives_per_query": {
            r["form"]: r["collectives_per_query"] for r in serving_rows
            if r["mode"] == "serving"},
        "serving_cache_hit_rate": next(
            r["hit_rate"] for r in serving_rows
            if r["mode"] == "serving_cache"),
        # the wire headline: bytes vs the f32 wire at the paper's K=50 —
        # total ratio per format and the per-collective split at F=128
        # (where the id stream's int16 floor caps the int8 total; the
        # F=512 rows in the JSON show the payload-dominated totals)
        "wire_ratios_K50_F128": {
            w: next(r2["bytes"] for r2 in wire_rows
                    if r2["F"] == 128 and r2["wire"] == "f32")
            / next(r2["bytes"] for r2 in wire_rows
                   if r2["F"] == 128 and r2["wire"] == w)
            for w in ("bf16", "int8")},
        # the sparse-feature headline: baseline all_to_all bytes vs the
        # dense shipment per density (F=512; 1.0 is the gate-fallback
        # control and must read exactly 1.0)
        "sparse_a2a_ratios": {
            str(r2["target_density"]):
                r2["dense_all_to_all_bytes"] / r2["all_to_all_bytes"]
            for r2 in sparse_rows},
    }
    # the scheduler mechanism, asserted DETERMINISTICALLY (round counts,
    # not wall times — timing on this topology is an estimator, the counts
    # are the claim): the scheduled walk on the clustered graph must skip
    # rounds, and execute strictly fewer than the unscheduled occupancy
    # leaves live
    sk_rows = {(r["graph"], r["scheduled"]): r for r in rows
               if r["mode"] == "skip_rate"}
    cs = sk_rows[("clustered", True)]
    cu = sk_rows[("clustered", False)]
    mech_failures = []
    if cs["skipped_rounds"] <= 0:
        mech_failures.append("scheduled walk skipped zero rounds on the "
                             "clustered graph")
    if cs["live_rounds"] >= cu["live_rounds"]:
        mech_failures.append(
            f"scheduled live rounds ({cs['live_rounds']}) not below the "
            f"unscheduled occupancy ({cu['live_rounds']})")
    # the islandization mechanism, asserted the same way (counters, not
    # clocks): strictly fewer remote rows and dense live rounds than the
    # interval cut on the scrambled-id clustered graph
    mech_failures += check_partition_rows(partition_rows)
    # the coalescing mechanism, asserted the same way (counters, not clocks)
    mech_failures += check_coalesce_rows(coalesce_rows)
    # and the serving mechanism: fused command blocks + hot cache
    mech_failures += check_serving_rows(serving_rows)
    # and the wire mechanism: byte ratios per format, counts unchanged
    mech_failures += check_wire_rows(wire_rows)
    # and the sparse-feature mechanism: bytes scale with density, the
    # density-1.0 gate fallback costs exactly nothing
    mech_failures += check_sparse_rows(sparse_rows)

    out = {"jax_version": jax.__version__, "devices": n_dev,
           "rows": rows, "summary": summary}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}: {len(rows)} rows; "
          f"{summary['checked'] - summary['failed']}/{summary['checked']} "
          f"sampled rows beat their threshold "
          f"(max ratio {summary['max_ratio']:.1f}×); clustered idle-skip "
          f"{cs['skipped_rounds']}/{cs['total_rounds']} rounds skipped")
    if failures or mech_failures:
        for r, thresh in failures:
            print(f"FAIL: K={r['K']} F={r['F']} ratio={r['ratio']:.2f} "
                  f"≤ {thresh:.1f}", file=sys.stderr)
        for msg in mech_failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
