"""Benchmark driver — one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]``

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's headline
number). Wall-times are CPU-host times for the jitted artifact (the TPU
numbers are the §Roofline terms from the dry-run); derived columns are the
paper-claim reproductions.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _timeit(fn, *args, n=3, warmup=1):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6, out


def bench_fig14_area(fast=False):
    """Fig 14: area to sustain equal aggregation throughput."""
    from repro.core import cost_model as cm
    a = cm.fig14_area()
    print(f"fig14_area_gas,0.0,{a['gas_mm2']:.2f}mm2")
    print(f"fig14_area_insider,0.0,{a['insider_mm2']:.2f}mm2")
    print(f"fig14_area_digital,0.0,{a['digital_mm2']:.2f}mm2")
    print(f"fig14_area_eff_vs_insider,0.0,{a['area_eff_vs_insider']:.1f}x")


def bench_fig15_cgtrans(fast=False):
    """Fig 15: per-dataset latency of GCNAX vs CGTrans(Insider) vs GRAPHIC."""
    from repro.core import cost_model as cm
    rows = cm.fig15_table()
    for r in rows:
        print(f"fig15_{r['dataset']},0.0,load_red={r['load_reduction']:.0f}x;"
              f"vs_gcnax={r['speedup_vs_gcnax']:.2f}x;"
              f"vs_insider={r['speedup_vs_insider']:.2f}x")
    print(f"fig15_avg,0.0,load_red={np.mean([r['load_reduction'] for r in rows]):.0f}x;"
          f"vs_gcnax={np.mean([r['speedup_vs_gcnax'] for r in rows]):.2f}x;"
          f"vs_insider={np.mean([r['speedup_vs_insider'] for r in rows]):.2f}x")


def _bfs_levels(indptr, indices, n, src=0):
    lev = np.full(n, -1, np.int64)
    lev[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        nxt = []
        for v in frontier:
            for u in indices[indptr[v]:indptr[v + 1]]:
                if lev[u] < 0:
                    lev[u] = d + 1
                    nxt.append(u)
        frontier = nxt
        d += 1
    return lev


def bench_fig16a_algorithms(fast=False):
    """Fig 16(a): FE/BFS/SSSP/CC on the GAS engine — measured wall time of the
    jitted algorithm + trace-model speedups (idle-skip vs typical cache)."""
    import jax.numpy as jnp
    from repro.core import algorithms as alg
    from repro.core import cost_model as cm
    from repro.graph import rmat

    scale = 10 if fast else 12
    g = rmat(scale, 16, seed=3, weights=True)
    indptr, indices, _ = g.to_csr()
    lev = _bfs_levels(indptr, indices, g.n_vertices)
    sim = cm.simulate_gas_traversal(indptr, lev, cache_mb=1.0)

    src = jnp.asarray(g.src)
    dst = jnp.asarray(g.dst)
    w = jnp.asarray(g.weights)
    feats = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((g.n_vertices, 32)).astype(np.float32))

    us, _ = _timeit(lambda: alg.feature_embedding(src, dst, w, feats), n=3)
    print(f"fig16a_feature_embedding,{us:.0f},edges={g.n_edges}")
    us, _ = _timeit(lambda: alg.bfs(src, dst, g.n_vertices, 0, max_iters=64), n=3)
    print(f"fig16a_bfs,{us:.0f},idle_skip={sim['speedup_idle_skip']:.1f}x;"
          f"no_skip={sim['speedup_no_skip']:.2f}x")
    us, _ = _timeit(lambda: alg.sssp(src, dst, w, g.n_vertices, 0, max_iters=64), n=3)
    print(f"fig16a_sssp,{us:.0f},")
    us, _ = _timeit(lambda: alg.connected_components(src, dst, g.n_vertices,
                                                     max_iters=64), n=3)
    print(f"fig16a_cc,{us:.0f},")


def bench_fig16b_scale(fast=False):
    """Fig 16(b): BFS on G500 scales × GAS cache sizes."""
    from repro.core import cost_model as cm
    from repro.graph import rmat

    scales = (10, 12) if fast else (12, 14, 16)
    for scale in scales:
        g = rmat(scale, 16, seed=3)
        indptr, indices, _ = g.to_csr()
        lev = _bfs_levels(indptr, indices, g.n_vertices)
        for mb in (0.5, 1.0, 2.0, 4.0):
            r = cm.simulate_gas_traversal(indptr, lev, cache_mb=mb)
            print(f"fig16b_s{scale}_c{mb},0.0,"
                  f"idle_skip={r['speedup_idle_skip']:.2f}x;passes={r['passes']:.1f}")


def bench_fig16c_breakdown(fast=False):
    """Fig 16(c): Reddit GCN end-to-end latency breakdown."""
    from repro.core import cost_model as cm
    bd = cm.fig16c_breakdown()
    for sysname, d in bd.items():
        parts = ";".join(f"{k}={v * 1e3:.2f}ms" for k, v in d.items() if k != "total")
        print(f"fig16c_{sysname},0.0,total={d['total'] * 1e3:.2f}ms;{parts}")
    cut = 1 - bd["graphic"]["total"] / bd["gcnax"]["total"]
    print(f"fig16c_latency_cut,0.0,{cut * 100:.1f}%")


def bench_collective_bytes(fast=False):
    """The mechanism on real lowered HLO, folded in from
    benchmarks/collective_bytes.py (run on 8 fake devices in a subprocess to
    keep this process single-device; it writes BENCH_collective_bytes.json).
    Emits one CSV row per sampled byte-ratio point — including the paper's
    K≈50 operating point of the ≈50× claim — plus the per-shard
    aggregation-time and full train-step-time columns: the FAST-GAS pallas
    kernel vs the XLA oracle inside the sharded cgtrans dataflow, forward
    (agg_time) and forward+backward+AdamW (train_step, the differentiable
    pallas path)."""
    import json
    import os
    import subprocess
    import tempfile
    here = os.path.dirname(__file__)
    # fast mode skips the K/F sweeps — keep the committed full-sweep
    # trajectory artifact intact and write the reduced set to a temp path
    # (per-invocation, so concurrent users on one host don't collide)
    if fast:
        fd, out_path = tempfile.mkstemp(prefix="BENCH_collective_bytes.",
                                        suffix=".json")
        os.close(fd)
    else:
        out_path = os.path.join(here, "..", "BENCH_collective_bytes.json")
    cmd = [sys.executable, os.path.join(here, "collective_bytes.py"),
           "--out", out_path] + (["--fast"] if fast else [])
    proc = subprocess.run(
        cmd, capture_output=True, text=True,
        env={**os.environ,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": os.path.join(here, "..", "src")})
    try:
        if proc.returncode != 0 or not os.path.exists(out_path):
            tail = (proc.stderr.strip().splitlines() or ["?"])[-1]
            print(f"collective_bytes,ERROR,exit={proc.returncode}:{tail}")
            return
        with open(out_path) as f:
            data = json.load(f)
    finally:
        if fast and os.path.exists(out_path):
            os.unlink(out_path)
    for r in data["rows"]:
        if r["mode"] == "sampled" and r["ways"] == 8:
            tag = "paper_fig_" if r.get("paper_figure") else ""
            print(f"collective_bytes_{tag}K{r['K']}_F{r['F']},0.0,"
                  f"ratio={r['ratio']:.1f}x;baseline={r['baseline']:.0f}B;"
                  f"cgtrans={r['cgtrans']:.0f}B")
        elif r["mode"] == "agg_time":
            tag = "_sched" if r.get("scheduled") else ""
            print(f"agg_time_{r['impl']}{tag},{r['us']:.0f},"
                  f"per_shard_us={r['us_per_shard']:.0f};ways={r['ways']}")
        elif r["mode"] == "skip_rate":
            tag = "sched" if r["scheduled"] else "unsched"
            print(f"skip_rate_{r['graph']}_{tag},0.0,"
                  f"live={r['live_rounds']}/{r['total_rounds']};"
                  f"skip_rate={r['skip_rate']:.2f}")
        elif r["mode"] == "partition":
            print(f"partition_{r['method']},0.0,"
                  f"remote_rows={r['remote_rows']}"
                  f"(max{r['remote_rows_max_shard']});"
                  f"dense_live={r['live_rounds']}/{r['total_rounds']};"
                  f"vs_interval={r['remote_rows_vs_interval']:.2f}")
        elif r["mode"] == "train_step_time":
            tag = "_sched" if r.get("scheduled") else ""
            print(f"train_step_{r['impl']}{tag},{r['us']:.0f},"
                  f"loss={r['loss']:.3f};ways={r['ways']}")
        elif r["mode"] == "coalesce":
            print(f"coalesce_{r['flow']}_{r['form']},0.0,"
                  f"all_gather={r['all_gather']};all_to_all={r['all_to_all']};"
                  f"finds={r['finds']};bytes={r['bytes']:.0f}")
        elif r["mode"] == "coalesce_grad":
            print(f"coalesce_grad_{r['form']},0.0,"
                  f"finds={r['finds']};kernel_scatters={r['kernel_scatters']}")
        elif r["mode"] == "serving":
            print(f"serving_{r['form']},0.0,"
                  f"N={r['N']};blocks={r['command_blocks']};"
                  f"finds_per_query={r['finds_per_query']:.3f};"
                  f"collectives_per_query={r['collectives_per_query']:.3f};"
                  f"bitexact={r['bitexact_vs_naive']}")
        elif r["mode"] == "serving_cache":
            print(f"serving_cache,0.0,"
                  f"hits={r['hits']}/{r['hits'] + r['misses']};"
                  f"hit_rate={r['hit_rate']:.2f};"
                  f"finds_per_query={r['finds_per_query']:.3f}")
    s = data["summary"]
    print(f"collective_bytes_summary,0.0,"
          f"{s['checked'] - s['failed']}/{s['checked']}_rows_pass;"
          f"paper_fig_ratio={s.get('paper_figure_ratio', 0.0):.1f}x;"
          f"agg_sched_vs_xla={s.get('agg_pallas_sched_vs_xla', 0.0):.2f};"
          f"coalesce_collectives="
          f"{s.get('coalesce_collectives_separate', '?')}to"
          f"{s.get('coalesce_collectives_coalesced', '?')};"
          f"serving_finds_per_query="
          f"{s.get('serving_finds_per_query', {}).get('fused', '?')};"
          f"serving_cache_hit_rate="
          f"{s.get('serving_cache_hit_rate', '?')};"
          f"partition_remote_rows="
          f"{s.get('partition_remote_rows', {}).get('interval', '?')}to"
          f"{s.get('partition_remote_rows', {}).get('island', '?')}")


def bench_kernels(fast=False):
    """Pallas kernels (interpret mode, correctness-path timing) vs jnp refs."""
    import jax.numpy as jnp
    from repro.kernels.gas_scatter import gas_scatter, gas_scatter_ref
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    rng = np.random.default_rng(0)
    E, F, R = (2048, 64, 512) if fast else (8192, 128, 1024)
    dst = jnp.asarray(rng.integers(0, R, E).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((E, F)).astype(np.float32))
    us_k, _ = _timeit(lambda: gas_scatter(dst, val, R), n=2)
    us_r, _ = _timeit(lambda: gas_scatter_ref(dst, val, R), n=2)
    print(f"kernel_gas_scatter_interpret,{us_k:.0f},ref_us={us_r:.0f}")

    B, S, H, hd = 1, 256, 4, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    us_k, _ = _timeit(lambda: flash_attention(q, k, v, causal=True), n=2)
    us_r, _ = _timeit(lambda: flash_attention_ref(q, k, v, causal=True), n=2)
    print(f"kernel_flash_attention_interpret,{us_k:.0f},ref_us={us_r:.0f}")


def bench_sage_step(fast=False):
    """Wall time of one jitted GraphSAGE+CGTrans train step (CPU host)."""
    import jax
    import jax.numpy as jnp
    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema, sage_loss
    from repro.data import GraphBatchStream, synthetic_node_labels
    from repro.graph import partition_by_src, uniform_graph
    from repro.optim import adamw_init, adamw_update

    g = uniform_graph(1024, 16384, seed=0, n_features=32)
    labels = synthetic_node_labels(g.features, 8)
    pg = partition_by_src(g, 4)
    feats = jnp.asarray(pg.features)
    cfg = GCNConfig(n_features=32, hidden=64, n_classes=8, fanout=10)
    tc = TrainConfig(learning_rate=1e-3)
    params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
    opt = adamw_init(params, tc)
    stream = GraphBatchStream(g, labels, n_parts=4, batch_per_part=32, k1=10, k2=10)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}

    @jax.jit
    def step(params, opt, batch):
        (_, m), grads = jax.value_and_grad(
            lambda p: sage_loss(p, feats, batch, cfg), has_aux=True)(params)
        params, opt, _ = adamw_update(params, grads, opt, tc)
        return params, opt, m

    us, (_, _, m) = _timeit(lambda: step(params, opt, batch), n=3)
    print(f"sage_train_step,{us:.0f},loss={float(m['loss']):.3f}")


BENCHES = {
    "fig14_area": bench_fig14_area,
    "fig15_cgtrans": bench_fig15_cgtrans,
    "fig16a_algorithms": bench_fig16a_algorithms,
    "fig16b_scale": bench_fig16b_scale,
    "fig16c_breakdown": bench_fig16c_breakdown,
    "collective_bytes": bench_collective_bytes,
    "kernels": bench_kernels,
    "sage_step": bench_sage_step,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        try:
            fn(fast=args.fast)
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
