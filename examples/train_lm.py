"""Train a reduced-config LM end-to-end with the fault-tolerant loop
(checkpoint every 25 steps, resumable, straggler monitor, preemption guard).

    PYTHONPATH=src python examples/train_lm.py --arch mamba2-780m --steps 60
"""

import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    return train.main(["--arch", args.arch, "--reduced",
                       "--steps", str(args.steps),
                       "--ckpt-dir", "/tmp/lm_ckpt"])


if __name__ == "__main__":
    sys.exit(main())
