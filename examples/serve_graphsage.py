"""Serve GraphSAGE queries online: cross-request fused SSD command blocks.

Demonstrates the serving engine (``repro.serving``): concurrent
multi-tenant callers with zipf-skewed seed popularity enqueue into a
size-or-deadline request queue, every drain fuses the pending requests
into ONE ``aggregate_multi`` command block (tenant-tagged segments scatter
results back to their callers), the hot-vertex cache absorbs repeat
self-row lookups, and the run closes with the engine's health snapshot —
finds-per-query vs the one-query-one-dispatch baseline, cache hit rate,
StepMonitor dispatch stats.

    PYTHONPATH=src python examples/serve_graphsage.py
"""

import sys

from repro.launch import serve

sys.exit(serve.main(["--workload", "graph", "--requests", "48",
                     "--tenants", "4", "--batch", "8", "--cache", "32"]))
