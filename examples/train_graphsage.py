"""End-to-end driver (the paper's workload): distributed GraphSAGE training
with the CGTrans dataflow on an 8-shard storage mesh.

Features live owner-sharded on the mesh (never shipped raw); batches carry
only vertex ids; layer-1 aggregation happens at the owner shards and only the
compressed partials cross the interconnect. Full production loop: AdamW +
cosine, checkpointing + resume, straggler monitor, preemption guard.

    PYTHONPATH=src python examples/train_graphsage.py --steps 300
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.common.config import TrainConfig
from repro.common.schema import count_params, init_params
from repro.core.gcn import GCNConfig, gcn_schema, sage_loss
from repro.data import GraphBatchStream, synthetic_node_labels
from repro.graph import partition_by_src, rmat
from repro.launch.mesh import make_data_mesh
from repro.optim import adamw_init
from repro.runtime import PreemptionGuard, StepMonitor
from repro.train import make_sage_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", type=int, default=14,
                    help="R-MAT scale (2^scale vertices)")
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--fanout", type=int, default=10)
    ap.add_argument("--batch-per-part", type=int, default=64)
    ap.add_argument("--dataflow", choices=["cgtrans", "baseline"],
                    default="cgtrans")
    ap.add_argument("--impl", choices=["xla", "pallas"], default="xla",
                    help="GAS backend for every aggregation — pallas runs "
                         "the FAST-GAS kernel forward AND backward (custom "
                         "VJPs; interpret-mode off-TPU, so expect it slow "
                         "on CPU hosts)")
    ap.add_argument("--request-chunk", type=int, default=None,
                    help="SSD command-queue depth: seeds per sampled-"
                         "aggregation request burst (None = unchunked)")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="issue the self-row lookup and the 2-hop "
                         "aggregation as two separate request streams "
                         "(the legacy two-body form) instead of ONE "
                         "coalesced SSD command block")
    ap.add_argument("--ckpt-dir", default="/tmp/graphsage_ckpt")
    args = ap.parse_args()

    mesh = make_data_mesh(8)
    print(f"mesh: {mesh.shape} (storage tier = 'data' axis)")

    g = rmat(args.scale, 16, seed=0)
    rng = np.random.default_rng(1)
    g.features = rng.standard_normal(
        (g.n_vertices, args.features)).astype(np.float32)
    labels = synthetic_node_labels(g.features, 16)
    pg = partition_by_src(g, 8)
    feats = jax.device_put(
        jnp.asarray(pg.features),
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data")))
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges; "
          f"features owner-sharded {pg.features.shape} over 8 shards")

    cfg = GCNConfig(n_features=args.features, hidden=args.hidden, n_classes=16,
                    fanout=args.fanout, dataflow=args.dataflow,
                    impl=args.impl, request_chunk=args.request_chunk,
                    coalesce=not args.no_coalesce)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                     total_steps=args.steps, weight_decay=0.01)
    params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
    print(f"model: {count_params(gcn_schema(cfg)) / 1e6:.2f}M params "
          f"(+{feats.size / 1e6:.1f}M feature table on the storage tier), "
          f"dataflow={args.dataflow} impl={args.impl}")

    stream = GraphBatchStream(g, labels, n_parts=8,
                              batch_per_part=args.batch_per_part,
                              k1=args.fanout, k2=args.fanout)

    step = jax.jit(make_sage_train_step(cfg, tc, feats=feats, mesh=mesh))

    state = {"params": params, "opt": adamw_init(params, tc),
             "step": jnp.zeros((), jnp.int32)}

    def batches():
        for b in stream:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    state, n = train_loop(
        step_fn=step, state=state, batches=batches(),
        total_steps=args.steps,
        ckpt=CheckpointManager(args.ckpt_dir, keep=2), ckpt_every=100,
        monitor=StepMonitor(), guard=PreemptionGuard(), log_every=20)

    # final eval on a fresh batch
    b = {k: jnp.asarray(v) for k, v in stream.batch_at(10_000).items()}
    _, m = sage_loss(state["params"], feats, b, cfg, mesh=mesh)
    print(f"done at step {n}: eval loss {float(m['loss']):.4f} "
          f"acc {float(m['acc']):.3f}")


if __name__ == "__main__":
    main()
