"""Quickstart: the paper's aggregation on the GAS engine in 60 seconds.

Builds a small power-law graph, runs GCN feature aggregation through the
FAST-GAS Pallas kernel (CAM-match + row-parallel update, interpret mode on
CPU), then BFS/SSSP/CC on the same engine, and prints the cost-model headline
numbers (50× loading cut, 3.6×/2.4× speedups).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import cost_model as cm
from repro.graph import rmat

g = rmat(10, 8, seed=0, weights=True)
print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges (R-MAT)")

feats = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((g.n_vertices, 16)).astype(np.float32))
src, dst, w = jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weights)

# aggregation (the paper's Fig 12) through the FAST-GAS kernel
agg = alg.feature_embedding(src, dst, w, feats, impl="pallas")
ref = alg.feature_embedding(src, dst, w, feats, impl="xla")
print(f"GAS kernel aggregation: out {agg.shape}, "
      f"max|err| vs oracle = {float(jnp.max(jnp.abs(agg - ref))):.2e}")

# classic algorithms on the same find-and-compute loop (paper §3.4)
levels = alg.bfs(src, dst, g.n_vertices, 0)
dist = alg.sssp(src, dst, w, g.n_vertices, 0)
comps = alg.connected_components(src, dst, g.n_vertices)
print(f"BFS: reached {int(jnp.isfinite(levels).sum())} vertices, "
      f"max level {int(levels[jnp.isfinite(levels)].max())}")
print(f"SSSP: mean finite distance {float(dist[jnp.isfinite(dist)].mean()):.3f}")
print(f"CC: {len(np.unique(np.asarray(comps)))} components")

# the paper's headline numbers from the calibrated cost model
rows = cm.fig15_table()
print(f"\nCGTrans vs GCNAX (cost model, Table II datasets):")
for r in rows:
    print(f"  {r['dataset']:10s} SSD-loading cut {r['load_reduction']:.0f}x, "
          f"speedup {r['speedup_vs_gcnax']:.2f}x vs GCNAX, "
          f"{r['speedup_vs_insider']:.2f}x vs Insider")
