"""Serve a small LM with batched requests: prefill + greedy decode.

Demonstrates the serving path (KV caches incl. ring buffers for local-attn
layers, gemma-style softcaps) on a reduced gemma2-family model.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch import serve

sys.exit(serve.main(["--arch", "gemma2-2b", "--reduced",
                     "--batch", "4", "--prompt-len", "48", "--gen", "24"]))
