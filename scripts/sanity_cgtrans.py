"""Dev sanity: CGTrans vs baseline vs single-device reference on 8 fake devices."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgtrans
from repro.graph import partition_by_src, uniform_graph, host_sample
from repro.launch.mesh import make_data_mesh

mesh = make_data_mesh(8)
rng = np.random.default_rng(0)

# --- full-graph edge aggregation -----------------------------------------
g = uniform_graph(256, 4096, seed=1, n_features=16, weights=True)
pg = partition_by_src(g, 8)
feats = jnp.asarray(pg.features)
args = (feats, jnp.asarray(pg.src), jnp.asarray(pg.dst),
        jnp.asarray(pg.weights), jnp.asarray(pg.mask))

ref = cgtrans.aggregate_edges(*args, mesh=None)
for flow in ("cgtrans", "baseline"):
    out = jax.jit(lambda *a, f=flow: cgtrans.aggregate_edges(*a, mesh=mesh, dataflow=f))(*args)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"edges/{flow:9s} err={err:.2e} {'ok' if err < 1e-3 else 'FAIL'}")

# max op
ref_m = cgtrans.aggregate_edges(*args, mesh=None, op="max")
out_m = jax.jit(lambda *a: cgtrans.aggregate_edges(*a, mesh=mesh, dataflow="cgtrans", op="max"))(*args)
err = float(jnp.max(jnp.abs(jnp.nan_to_num(out_m, neginf=0) - jnp.nan_to_num(ref_m, neginf=0))))
print(f"edges/max      err={err:.2e} {'ok' if err < 1e-3 else 'FAIL'}")

# --- sampled SAGE aggregation ---------------------------------------------
B, K = 64, 10
seeds = rng.integers(0, 256, B).astype(np.int32)
nbrs, mask = host_sample(g, seeds, K, seed=2)
nbrs_s = jnp.asarray(nbrs.reshape(8, B // 8, K))
mask_s = jnp.asarray(mask.reshape(8, B // 8, K))

ref_s = cgtrans.aggregate_sampled(feats, nbrs_s, mask_s, mesh=None)
for flow in ("cgtrans", "baseline"):
    out = jax.jit(lambda f, n, m, fl=flow: cgtrans.aggregate_sampled(
        f, n, m, mesh=mesh, dataflow=fl))(feats, nbrs_s, mask_s)
    err = float(jnp.max(jnp.abs(out - ref_s)))
    print(f"sage/{flow:9s}  err={err:.2e} {'ok' if err < 1e-3 else 'FAIL'}")
