"""Environment preflight: probe ``repro.compat`` feature detection on the
installed JAX and print a support matrix. Fails fast with ONE actionable
message instead of letting 12 test modules error at collection/runtime.

Exit 0 = the tier-1 suite (including the distributed subprocess cases) can
run here; exit 1 = something required is missing, with the reason printed.

Run:  PYTHONPATH=src python scripts/check_env.py [--json]
(``scripts/ci.sh`` runs this, then tier-1; the CI workflow runs it with
``--json`` and folds the machine-readable matrix into the step summary.)
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true",
                    help="emit the detected matrix as JSON "
                         "({matrix, failures, ok}) instead of the table — "
                         "for the CI step summary")
    args = ap.parse_args(argv)

    failures = []
    rows = []

    # -- python / required third-party ------------------------------------
    rows.append(("python", sys.version.split()[0]))
    for mod, why in [
        ("numpy", "array plumbing everywhere"),
        ("jax", "the whole engine"),
        ("pytest", "tier-1 runner"),
    ]:
        try:
            m = importlib.import_module(mod)
            rows.append((mod, getattr(m, "__version__", "present")))
        except ImportError as e:
            rows.append((mod, "MISSING"))
            failures.append(f"`{mod}` is required ({why}): {e}")

    # -- compat-layer feature detection ------------------------------------
    try:
        from repro import compat
    except ImportError as e:
        if "shard_map" in str(e):
            # compat itself raised importing shard_map: JAX predates even
            # jax.experimental.shard_map — older than the supported range
            print("the installed JAX has no shard_map anywhere (neither "
                  "jax.shard_map nor jax.experimental.shard_map) — older "
                  f"than the supported >=0.4.30 range; upgrade jax ({e})",
                  file=sys.stderr)
        else:
            print(f"cannot import repro.compat — is PYTHONPATH=src set? ({e})",
                  file=sys.stderr)
        return 1

    for key, val in compat.feature_matrix().items():
        rows.append((f"compat.{key}", str(val)))

    # -- smoke: build a mesh + trace a shard_map through compat ------------
    try:
        import jax
        from jax.sharding import PartitionSpec as P

        mesh = compat.make_mesh((1,), ("data",),
                                axis_types=(compat.AxisType.Auto,))
        out = jax.jit(compat.shard_map(lambda x: x * 2, mesh=mesh,
                                       in_specs=P(), out_specs=P()))(
            jax.numpy.ones(4))
        assert float(out.sum()) == 8.0
        rows.append(("compat.smoke", "mesh + shard_map trace ok"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("compat.smoke", "FAILED"))
        failures.append(f"compat smoke test failed on this JAX: {e!r}")

    # -- Pallas interpret mode (the FAST-GAS kernel off-TPU) ---------------
    # the differential tier (tests/test_cgtrans_pallas.py, ci.sh --tier
    # pallas) runs the kernel in interpret mode on CPU; probe it with a tiny
    # scatter so a broken pallas install fails HERE with one message
    try:
        import jax.numpy as jnp
        from repro.kernels.gas_scatter import gas_scatter

        out = gas_scatter(jnp.array([0, 1, 0], jnp.int32),
                          jnp.ones((3, 2), jnp.float32), 2, op="add")
        assert float(out.sum()) == 6.0
        rows.append(("pallas interpret", "functional (gas_scatter probe ok)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("pallas interpret", "BROKEN"))
        failures.append(
            f"Pallas interpret mode is non-functional on this JAX — the "
            f"impl='pallas' differential tier cannot run: {e!r}")

    # -- Pallas interpret VJP (the differentiable FAST-GAS path) -----------
    # the grad tier (tests/test_cgtrans_grad.py, ci.sh --tier grad) takes
    # jax.grad THROUGH the kernel via the custom VJPs in repro.core.gas;
    # probe that the backward traces and produces the known gradient here
    try:
        import jax
        import jax.numpy as jnp
        from repro.core import gas

        dst = jnp.array([0, 1, 0], jnp.int32)
        vals = jnp.ones((3, 2), jnp.float32)
        w = jnp.array([1.0, 2.0, 3.0])
        m = jnp.array([True, True, True])
        g = jax.grad(lambda v: gas.gas_scatter_weighted(
            dst, v, w, m, 2, op="add", impl="pallas").sum())(vals)
        # d_vals[e] = w[e] (every row's cotangent is 1): sum = 2·(1+2+3)
        assert float(g.sum()) == 12.0, float(g.sum())
        rows.append(("pallas interpret VJP",
                     "functional (grad-through-kernel probe ok)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("pallas interpret VJP", "BROKEN"))
        failures.append(
            f"the pallas custom VJP does not trace on this JAX — the "
            f"gradient-parity tier (impl='pallas' training) cannot run: {e!r}")

    # -- fused + scheduled kernel (the locality-scheduled fast path) -------
    # the scheduler tier (tests/test_gas_schedule.py, ci.sh --tier sched)
    # runs the fused weighted kernel through the destination-binned banded
    # walk; probe that it traces in interpret mode and produces the known
    # weighted scatter so a broken scalar-prefetch path fails HERE
    try:
        import jax.numpy as jnp
        from repro.kernels.gas_scatter import (gas_scatter_fused,
                                               schedule_edges)

        dst = jnp.array([2, 0, 2, 9], jnp.int32)
        msk = jnp.array([True, True, True, False])
        w = jnp.array([1.0, 2.0, 3.0, 4.0])
        vals = jnp.ones((4, 2), jnp.float32)
        sched = schedule_edges(dst, msk, 10)
        p = sched.perm
        out = gas_scatter_fused(dst[p], vals[p], w[p], msk[p], 10, op="add",
                                schedule=sched)
        # row 2 gets w0+w2 = 4, row 0 gets w1 = 2, the masked edge nothing
        assert float(out[2, 0]) == 4.0 and float(out[0, 0]) == 2.0, out
        assert float(out.sum()) == 12.0, out
        rows.append(("pallas fused+scheduled",
                     "functional (banded-walk probe ok)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("pallas fused+scheduled", "BROKEN"))
        failures.append(
            f"the fused/scheduled FAST-GAS dispatch does not trace on this "
            f"JAX — the scheduler tier (ci.sh --tier sched) cannot run: "
            f"{e!r}")

    # -- coalesced request blocks (one SSD command block ≡ two calls) ------
    # the coalesce tier (tests/test_cgtrans_coalesce.py, ci.sh --tier
    # coalesce) runs aggregate_multi — the self-lookup + fan-out segments
    # fused into one gather/all_to_all; probe that one combined block
    # reproduces two separate aggregate_sampled calls bit-for-bit HERE
    try:
        import jax.numpy as jnp
        from repro.core import cgtrans

        feats = jnp.arange(2 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 4)
        nb1 = jnp.array([[[3], [9]], [[0], [15]]], jnp.int32)
        mk1 = jnp.ones((2, 2, 1), bool)
        nb2 = jnp.array([[[1, 2, 8]], [[4, 5, 11]]], jnp.int32)
        mk2 = jnp.array([[[True, True, False]], [[True, False, True]]])
        o1, o2 = cgtrans.aggregate_multi(feats, ((nb1, mk1), (nb2, mk2)),
                                         mesh=None)
        s1 = cgtrans.aggregate_sampled(feats, nb1, mk1, mesh=None)
        s2 = cgtrans.aggregate_sampled(feats, nb2, mk2, mesh=None)
        assert bool((o1 == s1).all()) and bool((o2 == s2).all()), (o1, o2)
        rows.append(("coalesced requests",
                     "functional (one command block ≡ two calls)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("coalesced requests", "BROKEN"))
        failures.append(
            f"aggregate_multi does not reproduce the separate request "
            f"streams — the coalesce tier (ci.sh --tier coalesce) cannot "
            f"run: {e!r}")

    # -- wire codecs (the compressed transport layer) ----------------------
    # the wire tier (tests/test_wire.py, ci.sh --tier wire) ships quantized
    # partials and delta-encoded id streams through the collectives; probe
    # the pure codecs HERE (they need bitcast_convert_type over int8/int16,
    # which a stripped backend can lack) so a broken codec fails with one
    # message instead of a parity-matrix explosion
    try:
        import numpy as np
        import jax.numpy as jnp
        from repro.core import wire

        ids = jnp.array([[0, 5, -1, 3]], jnp.int32)
        dec = wire.delta_decode_ids(wire.delta_encode_ids(ids))
        assert bool((dec == ids).all()), dec
        x = jnp.array([[1.0, -3.0, 256.0, float("inf")]], jnp.float32)
        bf = wire.decode_payload(wire.encode_payload(x, "bf16"), "bf16")
        assert bool((bf == x).all()), bf           # ints ≤ 256 + inf: exact
        q = wire.decode_payload(wire.encode_payload(x, "int8"), "int8")
        scale = np.asarray(wire.int8_row_scale(x))[..., None]
        fin = np.isfinite(np.asarray(x))
        err = np.abs(np.asarray(q) - np.asarray(x))[fin]
        assert (err <= scale / 2 + 1e-6).all(), err.max()
        rows.append(("wire codecs",
                     "functional (delta ids exact, bf16 exact, int8 bounded)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("wire codecs", "BROKEN"))
        failures.append(
            f"the compressed-wire codecs do not round-trip on this JAX — "
            f"the wire tier (ci.sh --tier wire) cannot run: {e!r}")

    # -- sparse feature codec (the compressed-sparse tier) -----------------
    # the sparse tier (tests/test_sparse.py, ci.sh --tier sparse) ships
    # bitmap+packed feature rows through the gather and the baseline
    # all_to_all; probe the pure codec HERE (cumsum-positional decode plus
    # the static capacity gate) so a broken round-trip fails with one
    # message instead of a parity-matrix explosion
    try:
        import numpy as np
        import jax.numpy as jnp
        from repro.core import sparse

        x = jnp.array([[0.0, 2.0, 0.0, 0.0, 5.0, 0.0, 0.0, 1.0],
                       [0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]],
                      jnp.float32)
        cap = sparse.table_capacity(np.asarray(x))
        packed, bitmap = sparse.encode_rows(x, cap)
        dec = sparse.decode_rows(packed, bitmap, x.shape[1])
        assert bool((dec == x).all()), dec              # round-trip is exact
        pc = np.asarray(sparse.popcount(bitmap))
        assert (pc == [3, 0]).all(), pc                 # bitmap ≡ packed len
        assert sparse.sparse_fits(cap, 64)              # small cap wins at F=64
        assert not sparse.sparse_fits(8, 8)             # dense table: gate off
        rows.append(("sparse codec",
                     "functional (bitmap+packed round-trip exact, capacity "
                     "gate static)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("sparse codec", "BROKEN"))
        failures.append(
            f"the compressed-sparse feature codec does not round-trip on "
            f"this JAX — the sparse tier (ci.sh --tier sparse) cannot "
            f"run: {e!r}")

    # -- islandized locality partitioner (the partitioning tier) -----------
    # the part tier (tests/test_partition.py, ci.sh --tier part) rests on
    # islandize emitting a true permutation whose packing beats the interval
    # split on community graphs; probe the host-side pipeline end to end on
    # a tiny shuffled clustered graph so a numpy/BFS regression fails with
    # one message instead of a tier-wide explosion
    try:
        import numpy as np
        from repro.graph import (COOGraph, clustered_graph, islandize,
                                 partition_by_src, partition_graph,
                                 remote_destination_rows)

        gk = clustered_graph(64, 512, n_clusters=8, p_intra=0.95, seed=0)
        pm = np.random.default_rng(1).permutation(64).astype(np.int32)
        gk = COOGraph(64, pm[gk.src], pm[gk.dst])
        isl = islandize(gk, 4)
        assert np.array_equal(np.sort(isl.relabel), np.arange(64)), "not a permutation"
        assert np.array_equal(isl.inverse[isl.relabel], np.arange(64))
        rr_i = remote_destination_rows(partition_by_src(gk, 4)).sum()
        rr_s = remote_destination_rows(
            partition_graph(gk, 4, method="island")[0]).sum()
        assert int(rr_s) < int(rr_i), (rr_i, rr_s)
        rows.append(("islandize",
                     "functional (relabel is a permutation, locality win "
                     f"{int(rr_i)}->{int(rr_s)} remote rows)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("islandize", "BROKEN"))
        failures.append(
            f"the islandized locality partitioner failed its probe — the "
            f"partitioning tier (ci.sh --tier part) cannot run: {e!r}")

    # -- abstract tracing through shard_map (the lint/contract layer) ------
    # scripts/lint.py verifies every DataflowContract by jax.make_jaxpr /
    # eval_shape over ShapeDtypeStruct args — traced through shard_map with
    # NOTHING executed, which is exactly what a headless CI box must
    # support; probe it here so a JAX that can't trace abstractly fails
    # with one message instead of 39 contract errors
    try:
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        mesh = compat.make_mesh((1,), ("data",),
                                axis_types=(compat.AxisType.Auto,))
        fn = compat.shard_map(lambda x: jax.lax.psum(x, "data"),
                              mesh=mesh, in_specs=P(), out_specs=P())
        out = jax.eval_shape(fn, jax.ShapeDtypeStruct((4, 2), jnp.float32))
        assert out.shape == (4, 2) and out.dtype == jnp.float32, out
        jx = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4, 2), jnp.float32))
        assert jx.jaxpr.eqns, "empty jaxpr from an abstract shard_map trace"
        rows.append(("abstract trace",
                     "functional (eval_shape/make_jaxpr through shard_map)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash the report
        rows.append(("abstract trace", "BROKEN"))
        failures.append(
            f"abstract tracing through shard_map failed — the lint tier "
            f"(scripts/lint.py dataflow contracts) cannot run: {e!r}")

    # -- fake-device topology for the distributed cases --------------------
    flag = "--xla_force_host_platform_device_count=8"
    rows.append(("distributed tests",
                 f"subprocesses set XLA_FLAGS={flag} themselves"))

    # -- offline property-testing story ------------------------------------
    try:
        importlib.import_module("hypothesis")
        rows.append(("hypothesis", "installed (property tests use it)"))
    except ImportError:
        rows.append(("hypothesis",
                     "absent — tests/_propcheck.py deterministic fallback"))

    if args.json:
        print(json.dumps({"matrix": dict(rows), "failures": failures,
                          "ok": not failures}, indent=2))
        return 1 if failures else 0

    width = max(len(k) for k, _ in rows)
    print("repro environment support matrix")
    print("-" * (width + 40))
    for k, v in rows:
        print(f"{k:<{width}}  {v}")
    print("-" * (width + 40))

    if failures:
        print("\nNOT RUNNABLE:", file=sys.stderr)
        for f in failures:
            print(f"  * {f}", file=sys.stderr)
        return 1
    print("ok: tier-1 suite is runnable here "
          "(PYTHONPATH=src python -m pytest -x -q)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
