"""Per-op HBM/collective traffic breakdown for one dry-run cell (the §Perf
profiling tool — 'the profile' on a CPU host is the lowered HLO).

    PYTHONPATH=src python scripts/hbm_breakdown.py <arch> <shape> [hbm|coll]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections
import re
import sys

import jax

from repro import configs
from repro.launch.specs import build_case
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis as H


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    mode = sys.argv[3] if len(sys.argv) > 3 else "hbm"
    mesh = make_production_mesh()
    cfg = configs.get_config(arch)
    case = build_case(cfg, configs.get_shape(shape), mesh)
    with mesh:
        comp = jax.jit(case.fn, in_shardings=case.in_shardings,
                       out_shardings=case.out_shardings,
                       donate_argnums=case.donate).lower(*case.arg_structs).compile()
    comps, entry = H.parse_hlo(comp.as_text())
    mult = H.multiplicities(comps, entry)
    table = {}
    for c in comps.values():
        for ins in c.instrs:
            table[ins.name] = (ins.result_bytes, ins.result_is_tuple, ins.result_dims)

    def opsum(ins):
        return sum(table.get(o, (0.0, True, []))[0] for o in ins.operands
                   if not table.get(o, (0.0, True, []))[1])

    rows = collections.Counter()
    for name, c in comps.items():
        m = mult.get(name, 0)
        if m <= 0:
            continue
        for ins in c.instrs:
            is_coll = any(ins.opcode.startswith(k) for k in H._COLLECTIVES)
            if mode == "coll" and not is_coll:
                continue
            if mode == "hbm" and (is_coll or ins.opcode not in H._TRAFFIC_OPS):
                continue
            os_ = opsum(ins)
            if ins.opcode == "dynamic-slice":
                b = 2 * ins.result_bytes
            elif ins.opcode == "dynamic-update-slice" or (
                    ins.opcode == "fusion" and "dynamic_update_slice" in ins.attrs):
                mx = max([table.get(o, (0.0, True, []))[0]
                          for o in ins.operands
                          if not table.get(o, (0.0, True, []))[1]] or [0.0])
                b = 2 * max(os_ - mx, 0.0)
            elif is_coll:
                b = max(ins.result_bytes, os_)
            else:
                b = ins.result_bytes + os_
            om = re.search(r'op_name="([^"]*)"', ins.attrs)
            rows[(ins.opcode, om.group(1)[-75:] if om else ins.name)] += m * b
    total = sum(rows.values())
    print(f"total {mode}: {total / 1e12:.2f} TB/device")
    for (op, o), b in rows.most_common(18):
        print(f"{b / 1e12:7.2f} TB ({100 * b / total:4.1f}%) {op:20s} {o}")


if __name__ == "__main__":
    main()
