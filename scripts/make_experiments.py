"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
results/dryrun/*.json. Sections outside the AUTOGEN markers are preserved.

    PYTHONPATH=src python scripts/make_experiments.py
"""

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "results", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

ARCH_ORDER = ["llama-3.2-vision-90b", "recurrentgemma-2b", "qwen1.5-0.5b",
              "gemma2-2b", "phi3-medium-14b", "gemma3-12b",
              "moonshot-v1-16b-a3b", "deepseek-moe-16b", "whisper-base",
              "mamba2-780m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load():
    recs = {}
    for f in glob.glob(os.path.join(RESULTS, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | fits | GB/dev (adj) | GB raw | args GB | GFLOP/dev | coll MB/dev | compile |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    from repro.configs import SKIP_CELLS
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            if (a, s) in SKIP_CELLS:
                rows.append(f"| {a} | {s} | — | skip | — | — | — | — | — | — |")
                continue
            for mesh in ("pod16x16", "pod2x16x16"):
                r = recs.get((a, s, mesh))
                if not r or not r.get("ok"):
                    rows.append(f"| {a} | {s} | {mesh} | **FAIL** | | | | | | |")
                    continue
                m = r["memory"]
                raw = m["peak_bytes_per_device"] / 1e9
                args = m.get("args_bytes_per_device_exact", 0) / 1e9
                adj = m.get("peak_bytes_adjusted", m["peak_bytes_per_device"]) / 1e9
                adj = max(adj, args)  # the emulation detector can over-subtract
                rf = r["roofline"]
                fits = "✓" if adj <= 16.0 else ("~" if args <= 16.0 else "✗")
                rows.append(
                    f"| {a} | {s} | {mesh} | {fits} | {adj:.1f} | {raw:.1f} | "
                    f"{args:.1f} | {rf['flops'] / 1e9:.0f} | "
                    f"{rf['collective_bytes'] / 1e6:.0f} | {r['compile_s']:.0f}s |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | T_comp | T_mem | T_coll | dominant | roofline frac | MODEL_FLOPs/dev | useful ratio | next lever |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    from repro.configs import SKIP_CELLS
    levers = {
        "memory": "cut HBM traffic: larger fused blocks / bf16 collectives / fewer remat re-reads",
        "compute": "already MXU-bound: raise useful ratio (less remat recompute)",
        "collective": "shrink/overlap collectives: bf16 psums, FSDP-vs-TP crossover, CGTrans-style aggregation",
    }
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            if (a, s) in SKIP_CELLS:
                continue
            r = recs.get((a, s, "pod16x16"))
            if not r or not r.get("ok"):
                continue
            rf = r["roofline"]
            tc, tm, tl = rf["t_compute"], rf["t_memory"], rf["t_collective"]
            dom = rf["dominant"]
            tdom = max(tc, tm, tl)
            frac = tc / max(tdom, 1e-12)   # compute fraction of the bound
            rows.append(
                f"| {a} | {s} | {_fmt_s(tc)} | {_fmt_s(tm)} | {_fmt_s(tl)} | "
                f"{dom} | {frac:.2f} | {rf['model_flops'] / 1e9:.0f}G | "
                f"{rf['useful_ratio']:.2f} | {levers[dom]} |")
    return "\n".join(rows)


def splice(text: str, marker: str, payload: str) -> str:
    begin = f"<!-- AUTOGEN:{marker}:BEGIN -->"
    end = f"<!-- AUTOGEN:{marker}:END -->"
    pattern = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.S)
    block = f"{begin}\n{payload}\n{end}"
    if pattern.search(text):
        return pattern.sub(lambda _: block, text)
    return text + "\n" + block + "\n"


def main():
    recs = _load()
    text = open(EXP).read() if os.path.exists(EXP) else "# EXPERIMENTS\n"
    text = splice(text, "DRYRUN", dryrun_table(recs))
    text = splice(text, "ROOFLINE", roofline_table(recs))
    open(EXP, "w").write(text)
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    print(f"EXPERIMENTS.md updated: {n_ok}/{len(recs)} cells ok")


if __name__ == "__main__":
    main()
