"""Dev sanity: tiny config per family — loss, grad, prefill+decode consistency."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.common.schema import init_params, param_structs
from repro.models import transformer as T

FAMS = {
    "dense": dict(pattern=("attn",), qkv_bias=True),
    "gemma": dict(pattern=("local", "attn"), window=8, attn_logit_softcap=50.0,
                  final_logit_softcap=30.0, post_norms=True, rms_zero_centered=True,
                  embed_scale=True, qk_norm=True, query_pre_attn_scalar=16.0,
                  rope_theta_global=1e6),
    "moe": dict(pattern=("moe",), first_k_dense=1, n_experts=8, top_k=2,
                n_shared_experts=2, d_ff_dense=96),
    "ssm": dict(pattern=("ssd",), ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                ssm_expand=2),
    "hybrid": dict(pattern=("rglru", "rglru", "local"), window=8, lru_width=48),
    "audio": dict(pattern=("dec",), is_encoder_decoder=True, n_enc_layers=2,
                  enc_seq=12, norm_type="ln", mlp_gated=False, mlp_bias=True,
                  act="gelu", tie_embeddings=True),
    "vlm": dict(pattern=("attn", "attn", "cross"), vision_seq=10),
}

B, S, V = 2, 16, 64
ok = True
for fam, kw in FAMS.items():
    cfg = ModelConfig(name=f"tiny-{fam}", family=fam, n_layers=6 if fam != "audio" else 2,
                      d_model=48, n_heads=4, n_kv_heads=2, d_ff=96, vocab=V,
                      head_dim=12, param_dtype="float32", compute_dtype="float32",
                      remat="none", **kw)
    cfg.validate()
    key = jax.random.PRNGKey(0)
    schema = T.model_schema(cfg, max_seq=S)
    params = init_params(schema, key)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, V),
             "labels": jax.random.randint(key, (B, S), 0, V)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    if cfg.vision_seq:
        batch["vision"] = jax.random.normal(key, (B, cfg.vision_seq, cfg.d_model))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, batch, cfg), has_aux=True)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree.leaves(grads)))
    bad = (not np.isfinite(float(loss))) or (not np.isfinite(float(gnorm)))

    # prefill + decode consistency: prefill S-1 tokens, decode token S-1,
    # compare against prefill of all S tokens' last logits
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S - 1]
    logits_a, caches = T.prefill(params, pre_batch, cfg, cache_len=S)
    logits_b, _ = T.decode_step(params, batch["tokens"][:, S - 1:S], caches,
                                jnp.array(S - 1, jnp.int32), cfg)
    logits_full, _ = T.prefill(params, batch, cfg, cache_len=S)
    err = float(jnp.max(jnp.abs(logits_b - logits_full)))
    bad |= err > 2e-2 or not np.isfinite(err)
    print(f"{fam:8s} loss={float(loss):7.4f} gnorm={float(gnorm):9.3f} "
          f"decode_err={err:.2e} {'FAIL' if bad else 'ok'}")
    ok &= not bad

sys.exit(0 if ok else 1)
