"""graphlint: the repo's two-layer static analysis, as one exit code.

Layer 1 (AST, ``repro.analysis.source_lint``): compat single-door rule,
dispatch-site coverage, pytest marker registration, f64 literals — file:line
violations, suppressible inline with ``# lint: allow(rule): justification``.

Layer 2 (jaxpr, ``repro.analysis.contracts`` + ``dtype_flow``): every
registered ``DataflowContract`` is traced ABSTRACTLY (``jax.make_jaxpr``
over ``ShapeDtypeStruct`` args — nothing executes) on a forced 8-fake-device
topology and checked against its committed collective/dispatch budget and
the dtype-flow rules. A refactor that adds a collective, drops a
``count_dispatches`` tick, or promotes to f64 fails HERE with the budget
line that moved — before any bench row drifts.

Run:   PYTHONPATH=src python scripts/lint.py [--json] [--ast-only]
                                             [--contracts NAME_SUBSTR]
Exit:  0 = clean; 1 = violations/failures (listed); 2 = usage.

``scripts/ci.sh --tier lint`` runs this plus ``tests/test_analysis.py``;
the CI workflow folds ``--json`` into the step summary. To amend a budget
after an INTENTIONAL dataflow change, edit the table in
``src/repro/analysis/contracts.py`` (see README "Static contracts").
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# the jaxpr layer traces shard_map programs over the same 8-way fake
# topology the distributed tests use; must be set before jax imports
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report ({ast, contracts, ok}) — "
                         "for the CI step summary")
    ap.add_argument("--ast-only", action="store_true",
                    help="skip the jaxpr layer (no jax import; sub-second)")
    ap.add_argument("--contracts", metavar="SUBSTR", default=None,
                    help="verify only contracts whose name contains SUBSTR")
    args = ap.parse_args(argv)

    from repro.analysis.source_lint import lint_repo

    ast_violations = [str(v) for v in lint_repo(REPO)]

    contract_failures = {}
    n_contracts = 0
    if not args.ast_only:
        from repro.analysis.contracts import CONTRACTS, verify_all
        names = [n for n in CONTRACTS
                 if args.contracts is None or args.contracts in n]
        n_contracts = len(names)
        contract_failures = verify_all(names)

    ok = not ast_violations and not contract_failures
    if args.json:
        print(json.dumps({
            "ast": ast_violations,
            "contracts": {"checked": n_contracts,
                          "failed": contract_failures},
            "ok": ok,
        }, indent=2))
        return 0 if ok else 1

    for v in ast_violations:
        print(v, file=sys.stderr)
    for name, fails in contract_failures.items():
        for f in fails:
            print(f, file=sys.stderr)
    if ok:
        layer2 = ("" if args.ast_only
                  else f"; {n_contracts} dataflow contracts verified")
        print(f"lint ok: 0 AST violations{layer2}")
        return 0
    print(f"\nlint FAILED: {len(ast_violations)} AST violations, "
          f"{len(contract_failures)} contracts broken "
          f"(budgets live in src/repro/analysis/contracts.py — amend only "
          f"for an intentional dataflow change)", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
