#!/usr/bin/env python
"""Bench-drift gate: compare a fresh ``collective_bytes.py`` JSON against
the committed ``BENCH_collective_bytes.json``.

The bench file mixes two kinds of rows. COUNTER/RATIO rows (collective
bytes out of compiled HLO, dispatch counts, skip-rate round counts,
capacity gates) are deterministic functions of the code — if a fresh run
disagrees with the committed file, someone changed the mechanism without
regenerating the committed claim, and that silent drift is exactly what
this gate fails on. TIMING rows (``agg_time``/``sched_build``/
``train_step_time`` modes, and ``us``/``us_per_shard``/``loss`` fields
anywhere) are interpreter-mode estimators — noisy by design, ignored here.

Rows pair up by identity (mode + the declared parameter fields); every
remaining non-timing field must match EXACTLY. Rows present on only one
side are informational, never failures — the bench-smoke lane runs
``--fast`` (a strict subset of the committed full run), and a missing row
is a coverage note, not counter drift. Summary keys are compared only for
the declared deterministic set (the ``--fast``-dependent aggregates like
``checked``/``max_ratio`` legitimately differ between lanes).

Usage:  check_bench_drift.py FRESH.json COMMITTED.json
Exit 0 = no drift; exit 1 = drift (a markdown table of every mismatch is
printed — pipe it into the CI step summary).
"""

from __future__ import annotations

import json
import sys

#: row modes that are wall-clock measurements — skipped wholesale
TIMING_MODES = {"agg_time", "sched_build", "train_step_time"}

#: wall-clock fields that may appear on otherwise-counted rows — ignored
TIMING_FIELDS = {"us", "us_per_shard", "loss"}

#: fields that IDENTIFY a row (the bench sweep parameters); everything
#: else on the row is a measured claim and must match exactly
ID_FIELDS = {
    "mode", "ways", "K", "F", "V", "E", "B_loc", "part", "N", "waves",
    "fanout", "wire", "flow", "form", "impl", "scheduled", "graph",
    "method", "target_density", "paper_figure",
}

#: summary keys that are deterministic (counted, never clocked) and
#: independent of the --fast subset — compared exactly
DETERMINISTIC_SUMMARY = (
    "paper_figure_ratio", "clustered_skipped_rounds",
    "coalesce_collectives_separate", "coalesce_collectives_coalesced",
    "partition_remote_rows", "partition_dense_live_rounds",
    "serving_finds_per_query", "serving_collectives_per_query",
    "serving_cache_hit_rate", "wire_ratios_K50_F128", "sparse_a2a_ratios",
)


def row_key(row: dict):
    return tuple(sorted((k, row[k]) for k in row if k in ID_FIELDS))


def fmt_key(key) -> str:
    return " ".join(f"{k}={v}" for k, v in key)


def compare(fresh: dict, committed: dict):
    """Returns (drift, notes): drift rows are failures, notes informational.
    Each drift entry is (where, field, committed value, fresh value)."""
    drift, notes = [], []

    if fresh.get("jax_version") != committed.get("jax_version"):
        notes.append(f"jax version differs: committed "
                     f"{committed.get('jax_version')}, fresh "
                     f"{fresh.get('jax_version')} — regenerate the "
                     f"committed file if counters moved with it")

    f_rows = {row_key(r): r for r in fresh.get("rows", [])
              if r.get("mode") not in TIMING_MODES}
    c_rows = {row_key(r): r for r in committed.get("rows", [])
              if r.get("mode") not in TIMING_MODES}
    only_f = sorted(set(f_rows) - set(c_rows))
    only_c = sorted(set(c_rows) - set(f_rows))
    for k in only_f:
        notes.append(f"row only in fresh run (coverage note): {fmt_key(k)}")
    for k in only_c:
        notes.append(f"row only in committed file (the --fast lane skips "
                     f"it): {fmt_key(k)}")

    for k in sorted(set(f_rows) & set(c_rows)):
        fr, cr = f_rows[k], c_rows[k]
        fields = (set(fr) | set(cr)) - ID_FIELDS - TIMING_FIELDS
        for field in sorted(fields):
            fv, cv = fr.get(field), cr.get(field)
            if fv != cv:
                drift.append((fmt_key(k), field, cv, fv))

    fs = fresh.get("summary", {})
    cs = committed.get("summary", {})
    for key in DETERMINISTIC_SUMMARY:
        if key in fs and key in cs and fs[key] != cs[key]:
            drift.append(("summary", key, cs[key], fs[key]))

    return drift, notes


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        fresh = json.load(f)
    with open(argv[1]) as f:
        committed = json.load(f)

    drift, notes = compare(fresh, committed)

    print("## Bench drift check")
    print(f"fresh `{argv[0]}` vs committed `{argv[1]}`\n")
    if notes:
        for n in notes:
            print(f"- note: {n}")
        print()
    if not drift:
        print("**No drift**: every shared counter/ratio row matches the "
              "committed file exactly (timing rows ignored).")
        return 0
    print(f"**DRIFT**: {len(drift)} counter field(s) disagree with the "
          f"committed claims — regenerate `BENCH_collective_bytes.json` "
          f"with a full (non-`--fast`) run if the change is intentional.\n")
    print("| row | field | committed | fresh |")
    print("|---|---|---|---|")
    for where, field, cv, fv in drift:
        print(f"| {where} | {field} | {cv} | {fv} |")
    return 1


if __name__ == "__main__":
    sys.exit(main())
