#!/usr/bin/env bash
# CI entry point: environment preflight, then the selected test lane.
#
#   scripts/ci.sh                        # full tier-1 (includes ~4 min of
#                                        # distributed subprocess cases)
#   scripts/ci.sh --tier pallas          # the FAST-GAS differential suite
#                                        # only, on 8 fake devices (the
#                                        # pallas/xla parity lane)
#   scripts/ci.sh --tier grad            # the gradient-parity tier only:
#                                        # jax.grad through the pallas
#                                        # kernel ≡ xla ≡ finite differences
#   scripts/ci.sh --tier sched           # the edge-scheduler tier only:
#                                        # schedule invariants, fused kernel
#                                        # ≡ oracle, scheduled ≡ unscheduled
#                                        # bit-exact, idle-skip counters
#   scripts/ci.sh -m "not distributed"   # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TIER="full"
ARGS=()
while [[ $# -gt 0 ]]; do
  if [[ "$1" == "--tier" ]]; then
    TIER="${2:?--tier needs an argument (full|pallas|grad|sched)}"
    shift 2
  else
    ARGS+=("$1")
    shift
  fi
done

python scripts/check_env.py

case "$TIER" in
  full)
    python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}
    ;;
  pallas)
    # the differential tier: pallas ≡ xla ≡ reference across both sharded
    # dataflows. The in-process matrix runs directly on the fake 8-device
    # topology; the on-mesh matrix still subprocesses (and sets its own
    # XLA_FLAGS), so forcing the flag here is safe for this lane.
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
      python -m pytest -x -q tests/test_cgtrans_pallas.py ${ARGS[@]+"${ARGS[@]}"}
    ;;
  grad)
    # the gradient-parity tier: jax.grad through the FAST-GAS custom VJPs
    # ≡ the xla oracle ≡ finite differences, chunked ≡ unchunked, plus the
    # pallas train-step parity. Same topology note as the pallas lane.
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
      python -m pytest -x -q tests/test_cgtrans_grad.py ${ARGS[@]+"${ARGS[@]}"}
    ;;
  sched)
    # the scheduler-parity tier: destination-binned schedule invariants,
    # the fused weighted kernel vs the jnp oracle, scheduled ≡ unscheduled
    # bit-exactness (values AND gradients), and the idle-skip round
    # counters on clustered graphs. Single-process (no mesh needed).
    python -m pytest -x -q tests/test_gas_schedule.py ${ARGS[@]+"${ARGS[@]}"}
    ;;
  *)
    echo "unknown --tier '$TIER' (expected: full|pallas|grad|sched)" >&2
    exit 2
    ;;
esac
