#!/usr/bin/env bash
# CI entry point: environment preflight, then the tier-1 suite.
#
#   scripts/ci.sh                # full tier-1 (includes ~4 min of
#                                # distributed subprocess cases)
#   scripts/ci.sh -m "not distributed"   # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python scripts/check_env.py
python -m pytest -x -q "$@"
