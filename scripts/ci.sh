#!/usr/bin/env bash
# CI entry point: environment preflight, then the selected test lane.
#
#   scripts/ci.sh                        # full tier-1 (includes ~4 min of
#                                        # distributed subprocess cases)
#   scripts/ci.sh --tier pallas          # the FAST-GAS differential suite
#                                        # only, on 8 fake devices (the
#                                        # pallas/xla parity lane)
#   scripts/ci.sh --tier grad            # the gradient-parity tier only:
#                                        # jax.grad through the pallas
#                                        # kernel ≡ xla ≡ finite differences
#   scripts/ci.sh --tier sched           # the edge-scheduler tier only:
#                                        # schedule invariants, fused kernel
#                                        # ≡ oracle, scheduled ≡ unscheduled
#                                        # bit-exact, idle-skip counters
#   scripts/ci.sh --tier coalesce        # the coalesced-request tier only:
#                                        # one SSD command block ≡ two
#                                        # separate streams (values, grads,
#                                        # collective/dispatch counters)
#   scripts/ci.sh --tier serve           # the online-serving tier: fused
#                                        # cross-request command blocks ≡
#                                        # per-request dispatch, triggers,
#                                        # hot cache, tenant scatter-back
#   scripts/ci.sh --tier lint            # the static-analysis tier:
#                                        # scripts/lint.py (AST rules +
#                                        # abstract-traced dataflow
#                                        # contracts) plus its own test file
#   scripts/ci.sh --tier wire            # the compressed-wire tier: codec
#                                        # properties (delta ids, bf16,
#                                        # int8 bounds) plus the on-mesh
#                                        # bf16/int8 parity matrix
#   scripts/ci.sh --tier part            # the partitioning tier: islandize
#                                        # invariants + vectorized
#                                        # partitioner degenerate cases +
#                                        # generator contracts + the
#                                        # islandized ≡ interval parity
#                                        # matrix (host and 8-way mesh)
#   scripts/ci.sh --tier sparse          # the compressed-sparse feature
#                                        # tier: bitmap+packed codec
#                                        # properties, the capacity gate,
#                                        # feature-block skip bit-exactness,
#                                        # the bench-drift gate, and the
#                                        # sparse ≡ dense on-mesh parity
#                                        # matrix (values AND grads)
#   scripts/ci.sh --list-tiers           # machine-readable lane list (one
#                                        # per line) — .github/workflows/
#                                        # ci.yml builds its job matrix
#                                        # from this, so the two can't drift
#   scripts/ci.sh -m "not distributed"   # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# every lane the workflow matrix runs; `full` is tier-1 (the workflow passes
# it `-m "not distributed"` — the subprocess cases already run one-per-lane)
TIERS=(pallas grad sched coalesce serve lint wire part sparse full)

TIER="full"
# seeded with the always-on flags so the array is never empty: the classic
# `${ARGS[@]+"${ARGS[@]}"}` guard mis-splits quoted args containing spaces
# (e.g. `-m "not distributed"`) on bash 4.2/4.3 under `set -u`, while a
# non-empty `"${ARGS[@]}"` expansion is safe on every bash
ARGS=(-x -q)
while [[ $# -gt 0 ]]; do
  case "$1" in
    --tier)
      TIER="${2:?--tier needs an argument (use --list-tiers)}"
      shift 2
      ;;
    --list-tiers)
      printf '%s\n' "${TIERS[@]}"
      exit 0
      ;;
    *)
      ARGS+=("$1")
      shift
      ;;
  esac
done

python scripts/check_env.py

case "$TIER" in
  full)
    python -m pytest "${ARGS[@]}"
    ;;
  pallas)
    # the differential tier: pallas ≡ xla ≡ reference across both sharded
    # dataflows. The in-process matrix runs directly on the fake 8-device
    # topology; the on-mesh matrix still subprocesses (and sets its own
    # XLA_FLAGS), so forcing the flag here is safe for this lane.
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
      python -m pytest "${ARGS[@]}" tests/test_cgtrans_pallas.py
    ;;
  grad)
    # the gradient-parity tier: jax.grad through the FAST-GAS custom VJPs
    # ≡ the xla oracle ≡ finite differences, chunked ≡ unchunked, plus the
    # pallas train-step parity. Same topology note as the pallas lane.
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
      python -m pytest "${ARGS[@]}" tests/test_cgtrans_grad.py
    ;;
  sched)
    # the scheduler-parity tier: destination-binned schedule invariants,
    # the fused weighted kernel vs the jnp oracle, scheduled ≡ unscheduled
    # bit-exactness (values AND gradients), and the idle-skip round
    # counters on clustered graphs. Single-process (no mesh needed).
    python -m pytest "${ARGS[@]}" tests/test_gas_schedule.py
    ;;
  coalesce)
    # the coalesced-request tier: aggregate_multi (one SSD command block)
    # ≡ separate aggregate_sampled streams, bit-exact values+grads, the
    # segment-descriptor invariants, and the deterministic counters
    # (finds 2 → 1, backward scatters 2 → 1, collectives 2 → 1 on-mesh).
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
      python -m pytest "${ARGS[@]}" tests/test_cgtrans_coalesce.py
    ;;
  serve)
    # the online-serving tier: cross-request fused command blocks ≡
    # sequential per-request dispatch bit-exact, the size-or-deadline
    # trigger, hot-vertex cache row fidelity + hit counters, tenant
    # scatter-back isolation, and the counted finds/collectives-per-query
    # ratios (the sharded cells run on the fake 8-device topology).
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
      python -m pytest "${ARGS[@]}" tests/test_serving.py
    ;;
  lint)
    # the static-analysis tier: both lint layers over the repo (lint.py
    # forces its own fake-device topology for the abstract traces), then
    # the analysis test file (planted-violation fixtures + the contract
    # meta-test). Everything here traces abstractly — no mesh execution.
    python scripts/lint.py
    python -m pytest "${ARGS[@]}" tests/test_analysis.py
    ;;
  wire)
    # the compressed-wire tier: the codec property suite (delta id
    # round-trips, bf16 bit-exactness on small integers, int8 error bounds
    # + sentinel identities) runs on the host; the parity matrix (bf16 ≡
    # f32 bit-exact on integer payloads, values AND grads, across both
    # impls and all three ops) runs once in an 8-device subprocess that
    # sets its own XLA_FLAGS, so no topology forcing is needed here.
    python -m pytest "${ARGS[@]}" tests/test_wire.py
    ;;
  part)
    # the partitioning tier: islandize permutation/alignment invariants,
    # the vectorized partition_by_src vs the loop oracle (+ its pinned
    # degenerate shapes), synthetic-generator contracts, the in-process
    # islandized ≡ interval parity (values, grads, serving with the cache
    # on), and the 8-way subprocess matrix — the subprocess sets its own
    # XLA_FLAGS, so no topology forcing is needed here.
    python -m pytest "${ARGS[@]}" tests/test_partition.py
    ;;
  sparse)
    # the compressed-sparse feature tier: the bitmap+packed codec property
    # suite (round-trips at random densities incl. all-zero rows and
    # density 1.0, popcount ≡ packed length, the static capacity gate),
    # the feature-block skip dispatch bit-exactness, the bench-drift gate
    # against the committed counter JSON, and the sparse ≡ dense parity
    # matrix (values AND grads across dataflow × impl × op) — the on-mesh
    # matrix runs once in an 8-device subprocess that sets its own
    # XLA_FLAGS, so no topology forcing is needed here.
    python -m pytest "${ARGS[@]}" tests/test_sparse.py tests/test_bench_drift.py
    ;;
  *)
    echo "unknown --tier '$TIER' (expected one of: ${TIERS[*]})" >&2
    exit 2
    ;;
esac
