"""repro: GRAPHIC/CGTrans (Chen et al., 2022) on a TPU-native JAX stack."""

__version__ = "1.0.0"
