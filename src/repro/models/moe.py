"""Mixture-of-Experts FFN (deepseek-moe / moonshot style).

Capacity-based GShard-style dispatch expressed as einsums so GSPMD partitions
experts over the ``model`` axis (EP). The combine einsum reduces the expert
axis *before* any cross-shard movement — each expert shard emits partially
combined token outputs and the inter-shard traffic is one psum of the
**combined** (B,S,D) tensor. That is exactly the paper's CGTrans dataflow
(aggregate at the owner, transmit compressed): bytes ∝ tokens·D instead of
tokens·top_k·D. ``repro.core.cgtrans`` measures the two variants.

Shared experts (deepseek: 2) run as an always-on dense FFN.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.schema import ParamDef
from repro.models import layers


def moe_schema(cfg: ModelConfig) -> Dict[str, Any]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s: Dict[str, Any] = {
        "router": ParamDef((D, E), ("embed", None), init="lecun", dtype=jnp.float32),
        "w_gate": ParamDef((E, D, F), ("experts", "embed", None), init="lecun"),
        "w_up": ParamDef((E, D, F), ("experts", "embed", None), init="lecun"),
        "w_down": ParamDef((E, F, D), ("experts", None, "embed"), init="lecun"),
    }
    if cfg.n_shared_experts:
        s["shared"] = layers.mlp_schema(cfg, cfg.d_ff * cfg.n_shared_experts)
    return s


def _capacity(tokens_per_group: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(tokens_per_group * top_k * factor / n_experts) + 1
    return max(c, top_k)


def route(router_w: jax.Array, x: jax.Array, cfg: ModelConfig):
    """Top-k routing. x: (..., D) → (weights (..., k), ids (..., k), aux)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance aux loss: E * Σ_e (mean router prob)·(routed fraction).
    E = cfg.n_experts
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_ids.reshape(-1), E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return top_p, top_ids, aux


def moe_apply(
    p: Dict[str, Any],
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    capacity_factor: float = 1.25,
    group_size: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    t = min(group_size, T)
    G = T // t
    xf = x.reshape(G, t, D)

    top_p, top_ids, aux = route(p["router"], xf, cfg)  # (G,t,K)

    C = _capacity(t, E, K, capacity_factor)
    # position of each (token, k) slot within its expert queue, per group
    e_onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.int32)          # (G,t,K,E)
    flat = e_onehot.reshape(G, t * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                       # (G,t*K,E)
    pos = jnp.sum(pos_in_e.reshape(G, t, K, E) * e_onehot, axis=-1)  # (G,t,K)
    keep = pos < C
    w = top_p * keep.astype(top_p.dtype)

    # dispatch tensor (G,t,E,C) — bf16, sharded on E over "model"
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x.dtype)[..., :C]
    disp = jnp.einsum("gtke,gtkc->gtec", e_onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", e_onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)

    # gather expert inputs, run experts, combine (expert axis reduced in-place)
    xin = jnp.einsum("gtec,gtd->gecd", disp, xf)                     # (G,E,C,D)
    g = layers._act(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"].astype(x.dtype)), cfg.act)
    u = jnp.einsum("gecd,edf->gecf", xin, p["w_up"].astype(x.dtype))
    xout = jnp.einsum("gecf,efd->gecd", g * u, p["w_down"].astype(x.dtype))
    out = jnp.einsum("gecd,gtec->gtd", xout, comb)                   # reduces E first

    out = out.reshape(B, S, D)
    if "shared" in p:
        out = out + layers.mlp_apply(p["shared"], x, cfg)
    return out, aux.astype(jnp.float32)
