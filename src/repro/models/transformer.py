"""Model assembly: block-pattern scanned stacks for all assigned families.

The per-layer heterogeneity (local/global attention, cross-attn, MoE-vs-dense,
recurrent-vs-attn) is expressed as a repeating *pattern*; parameters are
stacked per pattern-position over block repetitions and the stack runs under
one ``lax.scan`` (HLO size O(pattern), not O(n_layers) — required to compile
100-layer 90B configs on one CPU). Remainder layers and MoE first-k-dense
prefixes are unrolled outside the scan.

Three entry points per model: ``loss_fn`` (train), ``prefill`` (logits +
populated cache), ``decode_step`` (one token against the cache).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.common.config import ModelConfig
from repro.common.schema import ParamDef, stack as stack_schema
from repro.models import griffin, layers, moe, ssm
from repro.models.embedding import chunked_softmax_xent, embed_lookup, logits_matmul
from repro.models.layers import LayerCtx, apply_norm, norm_schema, rope_tables


def _cdt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# per-layer schema / apply / prefill / decode, dispatched on kind
# ---------------------------------------------------------------------------

def layer_schema(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    n = lambda: norm_schema(cfg, cfg.d_model)
    if kind == "ssd":
        return {"norm": n(), "mixer": ssm.ssd_schema(cfg)}
    if kind == "rglru":
        s = {"norm": n(), "mixer": griffin.rglru_schema(cfg),
             "norm2": n(), "mlp": layers.mlp_schema(cfg)}
        return s
    if kind in ("attn", "local", "enc"):
        dff = cfg.d_ff_dense or cfg.d_ff
        is_prefix_dense = kind == "attn" and cfg.first_k_dense > 0
        s = {"norm": n(),
             "attn": layers.attn_schema(cfg),
             "norm2": n(),
             "mlp": layers.mlp_schema(cfg, dff if is_prefix_dense else cfg.d_ff)}
        if cfg.post_norms:
            s["post_attn_norm"] = n()
            s["post_mlp_norm"] = n()
        return s
    if kind == "moe":
        s = {"norm": n(), "attn": layers.attn_schema(cfg),
             "norm2": n(), "moe": moe.moe_schema(cfg)}
        return s
    if kind == "cross":
        return {"norm": n(),
                "attn": layers.attn_schema(cfg, cross=True, gated=True),
                "norm2": n(),
                "mlp": layers.mlp_schema(cfg, gated_tag=True)}
    if kind == "dec":
        return {"norm": n(), "self_attn": layers.attn_schema(cfg),
                "norm_x": n(), "cross_attn": layers.attn_schema(cfg, cross=True),
                "norm2": n(), "mlp": layers.mlp_schema(cfg)}
    raise ValueError(kind)


def layer_cache_schema(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                       tp: int = 16) -> Dict[str, Any]:
    if kind == "ssd":
        return {"mixer": ssm.ssd_cache_schema(cfg, batch)}
    if kind == "rglru":
        return {"mixer": griffin.rglru_cache_schema(cfg, batch)}
    if kind in ("attn", "local", "moe"):
        return {"attn": layers.attn_cache_schema(cfg, batch, seq_len, kind=kind, tp=tp)}
    if kind == "cross":
        return {"attn": layers.cross_cache_schema(cfg, batch, cfg.vision_seq, tp=tp)}
    if kind == "dec":
        return {"self_attn": layers.attn_cache_schema(cfg, batch, seq_len, kind="attn", tp=tp),
                "cross_attn": layers.cross_cache_schema(cfg, batch, cfg.enc_seq, tp=tp)}
    raise ValueError(kind)


def _residual(x, delta, p, cfg, post_key):
    if cfg.post_norms and post_key in p:
        delta = apply_norm(p[post_key], delta, cfg)
    return x + delta


def layer_apply(cfg: ModelConfig, kind: str, p, x, ctx: LayerCtx):
    """Full-sequence layer. Returns (x, aux)."""
    aux = jnp.float32(0.0)
    if kind == "ssd":
        h = apply_norm(p["norm"], x, cfg)
        return x + ssm.ssd_apply(p["mixer"], h, cfg), aux
    if kind == "rglru":
        h = apply_norm(p["norm"], x, cfg)
        x = x + griffin.rglru_apply(p["mixer"], h, cfg)
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), aux
    if kind in ("attn", "local", "enc"):
        h = apply_norm(p["norm"], x, cfg)
        x = _residual(x, layers.attn_apply(p["attn"], h, ctx, kind=kind), p, cfg, "post_attn_norm")
        h = apply_norm(p["norm2"], x, cfg)
        return _residual(x, layers.mlp_apply(p["mlp"], h, cfg), p, cfg, "post_mlp_norm"), aux
    if kind == "moe":
        h = apply_norm(p["norm"], x, cfg)
        x = x + layers.attn_apply(p["attn"], h, ctx, kind="attn")
        h = apply_norm(p["norm2"], x, cfg)
        out, aux = moe.moe_apply(p["moe"], h, cfg)
        return x + out, aux
    if kind == "cross":
        h = apply_norm(p["norm"], x, cfg)
        x = x + layers.cross_attn_apply(p["attn"], h, ctx)
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), aux
    if kind == "dec":
        h = apply_norm(p["norm"], x, cfg)
        x = x + layers.attn_apply(p["self_attn"], h, ctx, kind="attn")
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + layers.cross_attn_apply(p["cross_attn"], h, ctx)
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), aux
    raise ValueError(kind)


def layer_prefill(cfg: ModelConfig, kind: str, p, x, ctx: LayerCtx, cache_len: int):
    """Full-sequence layer that also emits the decode cache."""
    if kind == "ssd":
        h = apply_norm(p["norm"], x, cfg)
        out, cache = ssm.ssd_apply(p["mixer"], h, cfg, return_cache=True)
        return x + out, {"mixer": cache}
    if kind == "rglru":
        h = apply_norm(p["norm"], x, cfg)
        out, cache = griffin.rglru_apply(p["mixer"], h, cfg, return_cache=True)
        x = x + out
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), {"mixer": cache}
    if kind in ("attn", "local"):
        h = apply_norm(p["norm"], x, cfg)
        a, cache = layers.attn_prefill(p["attn"], h, ctx, kind=kind, cache_len=cache_len)
        x = _residual(x, a, p, cfg, "post_attn_norm")
        h = apply_norm(p["norm2"], x, cfg)
        return _residual(x, layers.mlp_apply(p["mlp"], h, cfg), p, cfg, "post_mlp_norm"), {"attn": cache}
    if kind == "moe":
        h = apply_norm(p["norm"], x, cfg)
        a, cache = layers.attn_prefill(p["attn"], h, ctx, kind="attn", cache_len=cache_len)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg)
        out, _ = moe.moe_apply(p["moe"], h, cfg, capacity_factor=2.0)
        return x + out, {"attn": cache}
    if kind == "cross":
        cache = layers.cross_build_cache(p["attn"], ctx.memory.astype(x.dtype), cfg)
        h = apply_norm(p["norm"], x, cfg)
        x = x + layers.cross_attn_apply(p["attn"], h, ctx)
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), {"attn": cache}
    if kind == "dec":
        h = apply_norm(p["norm"], x, cfg)
        a, self_cache = layers.attn_prefill(p["self_attn"], h, ctx, kind="attn", cache_len=cache_len)
        x = x + a
        cross_cache = layers.cross_build_cache(p["cross_attn"], ctx.memory.astype(x.dtype), cfg)
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + layers.cross_attn_apply(p["cross_attn"], h, ctx)
        h = apply_norm(p["norm2"], x, cfg)
        return (x + layers.mlp_apply(p["mlp"], h, cfg),
                {"self_attn": self_cache, "cross_attn": cross_cache})
    raise ValueError(kind)


def layer_decode(cfg: ModelConfig, kind: str, p, x, cache, ctx: LayerCtx):
    """One-token step. x: (B,1,D). Returns (x, new_cache)."""
    if kind == "ssd":
        h = apply_norm(p["norm"], x, cfg)
        out, c = ssm.ssd_decode(p["mixer"], h, cache["mixer"], cfg)
        return x + out, {"mixer": c}
    if kind == "rglru":
        h = apply_norm(p["norm"], x, cfg)
        out, c = griffin.rglru_decode(p["mixer"], h, cache["mixer"], cfg)
        x = x + out
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), {"mixer": c}
    if kind in ("attn", "local"):
        h = apply_norm(p["norm"], x, cfg)
        a, c = layers.attn_decode(p["attn"], h, cache["attn"], ctx, kind=kind)
        x = _residual(x, a, p, cfg, "post_attn_norm")
        h = apply_norm(p["norm2"], x, cfg)
        return _residual(x, layers.mlp_apply(p["mlp"], h, cfg), p, cfg, "post_mlp_norm"), {"attn": c}
    if kind == "moe":
        h = apply_norm(p["norm"], x, cfg)
        a, c = layers.attn_decode(p["attn"], h, cache["attn"], ctx, kind="attn")
        x = x + a
        h = apply_norm(p["norm2"], x, cfg)
        out, _ = moe.moe_apply(p["moe"], h, cfg, capacity_factor=2.0, group_size=64)
        return x + out, {"attn": c}
    if kind == "cross":
        h = apply_norm(p["norm"], x, cfg)
        a, c = layers.cross_attn_decode(p["attn"], h, cache["attn"], ctx)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), {"attn": c}
    if kind == "dec":
        h = apply_norm(p["norm"], x, cfg)
        a, sc = layers.attn_decode(p["self_attn"], h, cache["self_attn"], ctx, kind="attn")
        x = x + a
        h = apply_norm(p["norm_x"], x, cfg)
        a, cc = layers.cross_attn_decode(p["cross_attn"], h, cache["cross_attn"], ctx)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg)
        return x + layers.mlp_apply(p["mlp"], h, cfg), {"self_attn": sc, "cross_attn": cc}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack layout: prefix (unrolled) + blocks (scanned) + suffix (unrolled)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StackLayout:
    prefix: Tuple[str, ...]      # layer kinds, unrolled
    pattern: Tuple[str, ...]     # one block of the scan
    n_blocks: int
    suffix: Tuple[str, ...]      # remainder layers, unrolled


def stack_layout(cfg: ModelConfig) -> StackLayout:
    kinds = cfg.layer_kinds()
    pre = kinds[:cfg.first_k_dense]
    body = kinds[cfg.first_k_dense:]
    pattern = cfg.pattern * max(cfg.block_repeat, 1)
    period = len(pattern)
    if not cfg.scan_layers:
        return StackLayout(tuple(kinds), pattern, 0, ())
    n_blocks = len(body) // period
    if n_blocks <= 1:  # no point scanning a single block
        return StackLayout(tuple(kinds), pattern, 0, ())
    suffix = body[n_blocks * period:]
    return StackLayout(tuple(pre), pattern, n_blocks, tuple(suffix))


def stack_schema_for(cfg: ModelConfig) -> Dict[str, Any]:
    lay = stack_layout(cfg)
    s: Dict[str, Any] = {}
    for i, kind in enumerate(lay.prefix):
        s[f"prefix_{i}"] = layer_schema(cfg, kind)
    if lay.n_blocks:
        block = {f"p{j}": layer_schema(cfg, k) for j, k in enumerate(lay.pattern)}
        s["blocks"] = stack_schema(block, lay.n_blocks)
    for i, kind in enumerate(lay.suffix):
        s[f"suffix_{i}"] = layer_schema(cfg, kind)
    return s


def stack_cache_schema_for(cfg: ModelConfig, batch: int, seq_len: int,
                           tp: int = 16) -> Dict[str, Any]:
    lay = stack_layout(cfg)
    s: Dict[str, Any] = {}
    for i, kind in enumerate(lay.prefix):
        s[f"prefix_{i}"] = layer_cache_schema(cfg, kind, batch, seq_len, tp)
    if lay.n_blocks:
        block = {f"p{j}": layer_cache_schema(cfg, k, batch, seq_len, tp)
                 for j, k in enumerate(lay.pattern)}
        s["blocks"] = stack_schema(block, lay.n_blocks)
    for i, kind in enumerate(lay.suffix):
        s[f"suffix_{i}"] = layer_cache_schema(cfg, kind, batch, seq_len, tp)
    return s


def _run_stack_apply(cfg: ModelConfig, params, x, ctx: LayerCtx):
    lay = stack_layout(cfg)
    aux = jnp.float32(0.0)
    for i, kind in enumerate(lay.prefix):
        x, a = layer_apply(cfg, kind, params[f"prefix_{i}"], x, ctx)
        aux = aux + a

    if lay.n_blocks:
        def block_fn(carry, bp):
            x, aux = carry
            for j, kind in enumerate(lay.pattern):
                x, a = layer_apply(cfg, kind, bp[f"p{j}"], x, ctx)
                aux = aux + a
            return (x, aux), None

        if cfg.remat == "block":
            block_fn = jax.checkpoint(block_fn, prevent_cse=False)
        (x, aux), _ = lax.scan(block_fn, (x, aux), params["blocks"])

    for i, kind in enumerate(lay.suffix):
        x, a = layer_apply(cfg, kind, params[f"suffix_{i}"], x, ctx)
        aux = aux + a
    return x, aux


def _run_stack_prefill(cfg: ModelConfig, params, x, ctx: LayerCtx, cache_len: int):
    lay = stack_layout(cfg)
    caches: Dict[str, Any] = {}
    for i, kind in enumerate(lay.prefix):
        x, c = layer_prefill(cfg, kind, params[f"prefix_{i}"], x, ctx, cache_len)
        caches[f"prefix_{i}"] = c

    if lay.n_blocks:
        def block_fn(x, bp):
            cs = {}
            for j, kind in enumerate(lay.pattern):
                x, c = layer_prefill(cfg, kind, bp[f"p{j}"], x, ctx, cache_len)
                cs[f"p{j}"] = c
            return x, cs

        x, caches["blocks"] = lax.scan(block_fn, x, params["blocks"])

    for i, kind in enumerate(lay.suffix):
        x, c = layer_prefill(cfg, kind, params[f"suffix_{i}"], x, ctx, cache_len)
        caches[f"suffix_{i}"] = c
    return x, caches


def _run_stack_decode(cfg: ModelConfig, params, x, caches, ctx: LayerCtx):
    lay = stack_layout(cfg)
    new: Dict[str, Any] = {}
    for i, kind in enumerate(lay.prefix):
        x, c = layer_decode(cfg, kind, params[f"prefix_{i}"], x, caches[f"prefix_{i}"], ctx)
        new[f"prefix_{i}"] = c

    if lay.n_blocks:
        def block_fn(x, inp):
            bp, bc = inp
            cs = {}
            for j, kind in enumerate(lay.pattern):
                x, c = layer_decode(cfg, kind, bp[f"p{j}"], x, bc[f"p{j}"], ctx)
                cs[f"p{j}"] = c
            return x, cs

        x, new["blocks"] = lax.scan(block_fn, x, (params["blocks"], caches["blocks"]))

    for i, kind in enumerate(lay.suffix):
        x, c = layer_decode(cfg, kind, params[f"suffix_{i}"], x, caches[f"suffix_{i}"], ctx)
        new[f"suffix_{i}"] = c
    return x, new


# ---------------------------------------------------------------------------
# whole-model schema
# ---------------------------------------------------------------------------

def model_schema(cfg: ModelConfig, *, max_seq: int = 0) -> Dict[str, Any]:
    D = cfg.d_model
    V = cfg.vocab_padded
    s: Dict[str, Any] = {
        "embed": {"table": ParamDef((V, D), ("vocab", "embed"),
                                    init="normal", scale=1.0)},
        "final_norm": norm_schema(cfg, D),
        "stack": stack_schema_for(cfg),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = {"table": ParamDef((V, D), ("vocab", "embed"), init="lecun")}
    if cfg.is_encoder_decoder:
        enc_block = {f"p{j}": layer_schema(cfg, "enc") for j in range(1)}
        s["encoder"] = {
            "blocks": stack_schema(enc_block, cfg.n_enc_layers),
            "norm": norm_schema(cfg, D),
        }
        s["dec_pos"] = {"table": ParamDef((max_seq or cfg.max_dec_pos or 448, D),
                                          (None, "embed"), init="normal", scale=0.02)}
    return s


def _sincos_pos(S: int, D: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _make_ctx(cfg: ModelConfig, positions: jax.Array, memory=None, pos=None,
              use_flash: bool = False, mesh=None) -> LayerCtx:
    hd = cfg.hd
    rope_l = rope_tables(positions, hd, cfg.rope_theta)
    rope_g = (rope_tables(positions, hd, cfg.rope_theta_global)
              if cfg.rope_theta_global else rope_l)
    return LayerCtx(cfg=cfg, rope_local=rope_l, rope_global=rope_g,
                    memory=memory, pos=pos, use_flash=use_flash, mesh=mesh)


def _encode(cfg: ModelConfig, params, frames: jax.Array, use_flash: bool = False) -> jax.Array:
    """Whisper encoder over stubbed frame embeddings (B, enc_seq, D)."""
    x = frames.astype(_cdt(cfg))
    x = x + _sincos_pos(x.shape[1], cfg.d_model, x.dtype)[None]
    ctx = _make_ctx(cfg, jnp.arange(x.shape[1]), use_flash=use_flash)

    def block_fn(x, bp):
        x, _ = layer_apply(cfg, "enc", bp["p0"], x, ctx)
        return x, None

    x, _ = lax.scan(block_fn, x, params["encoder"]["blocks"])
    return apply_norm(params["encoder"]["norm"], x, cfg)


def _embed_tokens(cfg, params, tokens, mesh):
    x = embed_lookup(params["embed"]["table"], tokens, mesh=mesh,
                     cgtrans=cfg.cgtrans_embedding, compute_dtype=_cdt(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _memory_from_batch(cfg, params, batch, use_flash=False):
    if cfg.is_encoder_decoder:
        return _encode(cfg, params, batch["frames"], use_flash)
    if cfg.vision_seq:
        return batch["vision"]
    return None


def _unembed_table(cfg, params):
    return params["unembed"]["table"] if not cfg.tie_embeddings else params["embed"]["table"]


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, mesh: Optional[Mesh] = None, use_flash: bool = False):
    """batch: tokens (B,S), labels (B,S); + frames/vision for audio/vlm.

    Returns (loss, metrics).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens, mesh)
    if cfg.is_encoder_decoder:
        x = x + params["dec_pos"]["table"][:S].astype(x.dtype)[None]
    memory = _memory_from_batch(cfg, params, batch, use_flash)
    ctx = _make_ctx(cfg, jnp.arange(S), memory=memory, use_flash=use_flash, mesh=mesh)
    x, aux = _run_stack_apply(cfg, params["stack"], x, ctx)
    x = apply_norm(params["final_norm"], x, cfg)
    loss_sum, cnt = chunked_softmax_xent(
        x, _unembed_table(cfg, params), batch["labels"],
        softcap=cfg.final_logit_softcap, valid_vocab=cfg.vocab, mesh=mesh)
    loss = loss_sum / jnp.maximum(cnt, 1.0)
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": cnt}


def prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, cache_len: int, mesh: Optional[Mesh] = None, use_flash: bool = False):
    """Full-sequence forward building the decode cache.

    Returns (last_token_logits (B,V) f32, caches).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens, mesh)
    if cfg.is_encoder_decoder:
        x = x + params["dec_pos"]["table"][:S].astype(x.dtype)[None]
    memory = _memory_from_batch(cfg, params, batch, use_flash)
    ctx = _make_ctx(cfg, jnp.arange(S), memory=memory, use_flash=use_flash, mesh=mesh)
    x, caches = _run_stack_prefill(cfg, params["stack"], x, ctx, cache_len)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_matmul(x[:, -1], _unembed_table(cfg, params),
                           softcap=cfg.final_logit_softcap,
                           valid_vocab=cfg.vocab)
    return logits, caches


def decode_step(params, token: jax.Array, caches, pos: jax.Array, cfg: ModelConfig,
                *, mesh: Optional[Mesh] = None):
    """token: (B,1) int32; pos: scalar int32 (uniform static-batch decode).

    Returns (logits (B,V) f32, new caches).
    """
    x = _embed_tokens(cfg, params, token, mesh)
    if cfg.is_encoder_decoder:
        x = x + params["dec_pos"]["table"][pos].astype(x.dtype)[None, None, :]
    ctx = _make_ctx(cfg, pos[None] if pos.ndim == 0 else pos, pos=pos, mesh=mesh)
    x, new_caches = _run_stack_decode(cfg, params["stack"], x, caches, ctx)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_matmul(x[:, -1], _unembed_table(cfg, params),
                           softcap=cfg.final_logit_softcap,
                           valid_vocab=cfg.vocab)
    return logits, new_caches
