from repro.models.transformer import (
    decode_step,
    loss_fn,
    model_schema,
    prefill,
    stack_cache_schema_for,
    stack_layout,
)

__all__ = [
    "decode_step", "loss_fn", "model_schema", "prefill",
    "stack_cache_schema_for", "stack_layout",
]
