"""Mamba2 mixer via SSD (state-space duality), chunked matmul formulation.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) computes the selective-SSM
recurrence as block matmuls: intra-chunk "attention-like" term + inter-chunk
recurrent state carry. This is the TPU-friendly form (MXU matmuls + one short
scan over chunks) — exactly the kind of rethink DESIGN §2 calls for.

Sharding layout (TP over "ssm_heads" = the model axis): z/x/dt projections and
the x-conv are sharded on d_inner/heads; B and C (state projections, N=128)
are replicated — so every slice in the layer is shard-aligned and the only
per-layer collective is the out_proj contraction psum (verified in the
dry-run HLO; a fused in_proj would cost ~3 GB/layer of resharding instead).

Layout: d_inner = expand·d_model, H = d_inner/head_dim heads, state N,
single B/C group (n_groups = 1, matching mamba2-780m).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig
from repro.common.schema import ParamDef
from repro.models.layers import rms_norm


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


def ssd_schema(cfg: ModelConfig) -> Dict[str, Any]:
    D = cfg.d_model
    d_inner, H, P_, N = dims(cfg)
    K = cfg.conv_kernel
    return {
        "z_proj": ParamDef((D, d_inner), ("embed", "ssm_heads"), init="lecun"),
        "x_proj": ParamDef((D, d_inner), ("embed", "ssm_heads"), init="lecun"),
        "b_proj": ParamDef((D, N), ("embed", None), init="lecun"),
        "c_proj": ParamDef((D, N), ("embed", None), init="lecun"),
        "dt_proj": ParamDef((D, H), ("embed", "ssm_heads"), init="lecun"),
        "conv_x_w": ParamDef((K, d_inner), (None, "ssm_heads"), init="lecun"),
        "conv_x_b": ParamDef((d_inner,), ("ssm_heads",), init="zeros"),
        "conv_b_w": ParamDef((K, N), (None, None), init="lecun"),
        "conv_b_b": ParamDef((N,), (None,), init="zeros"),
        "conv_c_w": ParamDef((K, N), (None, None), init="lecun"),
        "conv_c_b": ParamDef((N,), (None,), init="zeros"),
        "a_log": ParamDef((H,), ("ssm_heads",), init="custom", custom="ssm_a_log"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="custom", custom="ssm_dt_bias"),
        "d_skip": ParamDef((H,), ("ssm_heads",), init="ones"),
        "out_norm": ParamDef((d_inner,), ("ssm_heads",), init="ones"),
        "out_proj": ParamDef((d_inner, D), ("ssm_heads", "embed"), init="lecun"),
    }


def ssd_cache_schema(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    d_inner, H, P_, N = dims(cfg)
    K = cfg.conv_kernel
    return {
        "state": ParamDef((batch, H, P_, N), ("batch", "ssm_heads", None, None),
                          init="zeros", dtype=jnp.float32),
        "conv_x": ParamDef((batch, K - 1, d_inner), ("batch", None, "ssm_heads"),
                           init="zeros", dtype=jnp.float32),
        "conv_b": ParamDef((batch, K - 1, N), ("batch", None, None),
                           init="zeros", dtype=jnp.float32),
        "conv_c": ParamDef((batch, K - 1, N), ("batch", None, None),
                           init="zeros", dtype=jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, history=None,
                 act: bool = True):
    """Depthwise causal conv along seq. x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    out = out + b.astype(x.dtype)
    if act:
        out = jax.nn.silu(out)
    return out, xp[:, -(K - 1):]


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD: one lax.scan over chunks (state carried between chunks).

    Per chunk: an intra-chunk attention-like matmul term + the contribution of
    the carried state. Memory stays O(L²·H) per step; the recurrence between
    chunks is inherently serial, and the per-chunk matmuls are the MXU work.

    x: (B,S,H,P)  dt: (B,S,H) post-softplus f32  A: (H,) negative
    Bm, Cm: (B,S,N) single group.
    Returns y: (B,S,H,P) f32, final state (B,H,P,N) f32.
    """
    Bsz, S, H, P_ = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    S_orig = S
    if S % L:
        # pad with dt=0 steps: zero dt ⇒ no state update and no output weight,
        # so padding is exact (not approximate).
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nC = S // L
    xc = jnp.moveaxis(x.reshape(Bsz, nC, L, H, P_), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nC, L, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bsz, nC, L, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(Bsz, nC, L, N), 1, 0)
    li = jnp.arange(L)
    causal = (li[:, None] >= li[None, :])[None, :, :, None]  # (1,L,L,1)
    s0 = (jnp.zeros((Bsz, H, P_, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        xi, dti, Bi, Ci = inp                               # per-chunk slices
        dA = dti * A[None, None, :]                         # (B,L,H) ≤ 0, f32
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1, :]                               # (B,H)
        # intra-chunk: att[l,m] = C_l·B_m · exp(cum_l - cum_m) · dt_m, l ≥ m
        cb = jnp.einsum("bln,bmn->blm", Ci, Bi,
                        preferred_element_type=jnp.float32)  # (B,L,L)
        seg = cum[:, :, None, :] - cum[:, None, :, :]       # (B,L,L,H)
        # mask BEFORE exp: exp(-inf)=0 keeps fwd and grad finite (exp of the
        # (positive) non-causal entries would overflow and NaN the vjp).
        decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))
        att = (cb[..., None] * decay * dti[:, None, :, :]).astype(xi.dtype)
        y = jnp.einsum("blmh,bmhp->blhp", att, xi,
                       preferred_element_type=jnp.float32)
        # carried-state contribution: y_off_l = C_l · (exp(cum_l) ⊙ S_in)
        y = y + jnp.einsum("bln,blh,bhpn->blhp", Ci.astype(jnp.float32),
                           jnp.exp(cum), s)
        # state update: S_out = exp(total)·S_in + Σ_m exp(total-cum_m)·dt_m·B_m⊗x_m
        dstate = jnp.exp(total[:, None, :] - cum) * dti     # (B,L,H)
        cs = jnp.einsum("bln,blh,blhp->bhpn", Bi.astype(jnp.float32), dstate,
                        xi.astype(jnp.float32))
        s = s * jnp.exp(total)[:, :, None, None] + cs
        # stack chunk outputs in compute dtype (bf16): halves the dominant
        # live buffer of the layer (the f32 accumulation already happened
        # inside the einsums via preferred_element_type).
        return s, y.astype(xi.dtype)

    s_final, ys = lax.scan(step, s0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P_)
    return y[:, :S_orig].astype(jnp.float32), s_final


def _proj(x, w):
    return jnp.einsum("bsd,dk->bsk", x, w.astype(x.dtype))


def ssd_apply(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig,
              init_state=None, conv_history=None, return_cache: bool = False):
    """Full-sequence mamba2 mixer. x: (B,S,D) → (B,S,D)."""
    d_inner, H, P_, N = dims(cfg)
    B, S, D = x.shape
    z = _proj(x, p["z_proj"])
    xs = _proj(x, p["x_proj"])
    Bm = _proj(x, p["b_proj"])
    Cm = _proj(x, p["c_proj"])
    dt = _proj(x, p["dt_proj"])
    hx = hb = hc = None
    if conv_history is not None:
        hx, hb, hc = (conv_history["conv_x"], conv_history["conv_b"],
                      conv_history["conv_c"])
    xs, nhx = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"], hx)
    Bm, nhb = _causal_conv(Bm, p["conv_b_w"], p["conv_b_b"], hb)
    Cm, nhc = _causal_conv(Cm, p["conv_c_w"], p["conv_c_b"], hc)
    xs = xs.reshape(B, S, H, P_)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps, False)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    if return_cache:
        cache = {"state": state,
                 "conv_x": nhx.astype(jnp.float32),
                 "conv_b": nhb.astype(jnp.float32),
                 "conv_c": nhc.astype(jnp.float32)}
        return out, cache
    return out


def _conv_step(v, hist, w, b, act: bool = True):
    """Single-token depthwise conv against history. v: (B,C)."""
    full = jnp.concatenate([hist.astype(v.dtype), v[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.sum(full * w.astype(v.dtype)[None], axis=1) + b.astype(v.dtype)
    if act:
        out = jax.nn.silu(out)
    return out, full[:, 1:]


def ssd_decode(p: Dict[str, Any], x: jax.Array, cache: Dict[str, jax.Array], cfg: ModelConfig):
    """Single-token recurrent update. x: (B,1,D)."""
    d_inner, H, P_, N = dims(cfg)
    B = x.shape[0]
    x0 = x[:, 0]
    z = jnp.einsum("bd,dk->bk", x0, p["z_proj"].astype(x.dtype))
    xs = jnp.einsum("bd,dk->bk", x0, p["x_proj"].astype(x.dtype))
    Bm = jnp.einsum("bd,dk->bk", x0, p["b_proj"].astype(x.dtype))
    Cm = jnp.einsum("bd,dk->bk", x0, p["c_proj"].astype(x.dtype))
    dt = jnp.einsum("bd,dk->bk", x0, p["dt_proj"].astype(x.dtype))
    xs, nhx = _conv_step(xs, cache["conv_x"], p["conv_x_w"], p["conv_x_b"])
    Bm, nhb = _conv_step(Bm, cache["conv_b"], p["conv_b_w"], p["conv_b_b"])
    Cm, nhc = _conv_step(Cm, cache["conv_c"], p["conv_c_w"], p["conv_c_b"])
    xs = xs.reshape(B, H, P_)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])   # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dA = jnp.exp(dt_ * A[None, :])                                          # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt_, Bm, xs.astype(jnp.float32))
    state = cache["state"] * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)                               # (B,H,P)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps, False)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"].astype(x.dtype))[:, None, :]
    return out, {"state": state, "conv_x": nhx.astype(jnp.float32),
                 "conv_b": nhb.astype(jnp.float32),
                 "conv_c": nhc.astype(jnp.float32)}
