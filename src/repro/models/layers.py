"""Shared transformer layer library.

Everything is pure-functional: ``*_schema(cfg)`` declares parameters (shapes +
logical sharding axes), ``*_apply`` runs a full sequence, ``*_decode`` runs one
token against a cache. Attention is *chunked over queries* (scores never
materialize at (S, T) for long sequences) so 32k-prefill dry-runs report honest
activation memory even on the pure-XLA path; the Pallas flash kernel
(`repro.kernels.flash_attention`) is the TPU fast path for the same math.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig
from repro.common.schema import ParamDef

NEG_INF = -2.3819763e38  # matches XLA's finite mask value


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float, zero_centered: bool) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    scale = (1.0 + w) if zero_centered else w
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_schema(cfg: ModelConfig, d: int) -> Dict[str, ParamDef]:
    if cfg.norm_type == "ln":
        return {
            "w": ParamDef((d,), (None,), init="ones"),
            "b": ParamDef((d,), (None,), init="zeros"),
        }
    init = "zeros" if cfg.rms_zero_centered else "ones"
    return {"w": ParamDef((d,), (None,), init=init)}


def apply_norm(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.norm_type == "ln":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps, cfg.rms_zero_centered)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, hd: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables, shape positions.shape + (hd//2,). float32."""
    freq = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (S, hd//2) or broadcastable."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    # tables broadcast over the head axis: (S, hd/2) -> (S, 1, hd/2)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# layer context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerCtx:
    cfg: ModelConfig
    rope_local: Tuple[jax.Array, jax.Array]   # (cos, sin) for window/default theta
    rope_global: Tuple[jax.Array, jax.Array]  # gemma3 global-layer theta
    memory: Optional[jax.Array] = None        # encoder / vision memory (B, M, D)
    pos: Optional[jax.Array] = None           # decode: scalar current position
    q_chunk: int = 1024
    use_flash: bool = False                   # route full attn through Pallas
    mesh: Optional[object] = None             # for activation sharding constraints


def _tp_size(mesh) -> int:
    if mesh is not None and "model" in getattr(mesh, "axis_names", ()):
        return mesh.shape["model"]
    return 1


def _constrain(x, mesh, spec_axes):
    """with_sharding_constraint against the ctx mesh (no-op without mesh)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.common.logical import batch_axes
    resolved = []
    for a in spec_axes:
        if a == "batch":
            dp = batch_axes(mesh)
            resolved.append(dp if len(dp) > 1 else (dp[0] if dp else None))
        elif a == "model":
            resolved.append("model" if "model" in mesh.axis_names else None)
        else:
            resolved.append(None)
    return lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*resolved)))


def rope_for(kind: str, ctx: LayerCtx):
    if kind == "attn" and ctx.cfg.rope_theta_global:
        return ctx.rope_global
    return ctx.rope_local


# ---------------------------------------------------------------------------
# core chunked attention
# ---------------------------------------------------------------------------

def _mask_bias(qpos, kpos, *, causal: bool, window: int) -> jax.Array:
    """(len(qpos), len(kpos)) additive bias of 0 / NEG_INF."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window:
        ok &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(
    q: jax.Array,                # (B, S, H, hd) — already scaled
    k: jax.Array,                # (B, T, Hkv, hd)
    v: jax.Array,                # (B, T, Hkv, hd)
    *,
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qc = q_chunk if S % q_chunk == 0 else S
    n = S // qc
    qr = jnp.moveaxis(q.reshape(B, n, qc, Hkv, G, hd), 1, 0)  # (n,B,qc,Hkv,G,hd)
    kpos = jnp.arange(T)

    # rematerialized per chunk: the (qc, T) f32 score block is recomputed in
    # the backward instead of being stored for every chunk (flash-attention
    # memory semantics on the pure-XLA path).
    @jax.checkpoint
    def chunk_attn(i, qi):
        s = jnp.einsum("bqkgh,btkh->bkgqt", qi, k, preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_offset + i * qc + jnp.arange(qc)
        s = s + _mask_bias(qpos, kpos, causal=causal, window=window)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqt,btkh->bqkgh", p, v)

    def chunk_fn(_, inp):
        i, qi = inp
        return 0, chunk_attn(i, qi)

    _, outs = lax.scan(chunk_fn, 0, (jnp.arange(n), qr))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def decode_attention(
    q: jax.Array,                # (B, 1, H, hd) — already scaled
    k: jax.Array,                # (B, T, Hkv, hd) cache
    v: jax.Array,
    kv_positions: jax.Array,     # (T,) absolute token position per slot, -1 invalid
    pos: jax.Array,              # scalar current position
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qi = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qi, k, preferred_element_type=jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    ok = (kv_positions >= 0) & (kv_positions <= pos)
    if window:
        ok &= kv_positions > pos - window
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v)
    return o.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# attention layer (kinds: attn, local, enc, cross, and the attn part of dec)
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig, *, cross: bool = False, gated: bool = False) -> Dict[str, Any]:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s: Dict[str, Any] = {
        "wq": ParamDef((D, H * hd), ("embed", "heads"), init="lecun"),
        "wk": ParamDef((D, Hkv * hd), ("embed", "kv_heads"), init="lecun"),
        "wv": ParamDef((D, Hkv * hd), ("embed", "kv_heads"), init="lecun"),
        "wo": ParamDef((H * hd, D), ("heads", "embed"), init="lecun"),
    }
    if cfg.qkv_bias or cfg.mlp_bias:
        s["bq"] = ParamDef((H * hd,), ("heads",), init="zeros")
        s["bk"] = ParamDef((Hkv * hd,), ("kv_heads",), init="zeros")
        s["bv"] = ParamDef((Hkv * hd,), ("kv_heads",), init="zeros")
    if cfg.mlp_bias:
        s["bo"] = ParamDef((D,), (None,), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamDef((hd,), (None,), init="zeros" if cfg.rms_zero_centered else "ones")
        s["k_norm"] = ParamDef((hd,), (None,), init="zeros" if cfg.rms_zero_centered else "ones")
    if gated:  # llama-3.2-vision cross-attn gates
        s["gate_attn"] = ParamDef((1,), (None,), init="zeros")
    return s


def _qkv(p, x, mem, cfg: ModelConfig, mesh=None, decode=False):
    """Project q from x and k,v from mem (mem = x for self-attention).

    Sharding policy (DESIGN §7): attention params are ALWAYS stored sharded on
    the flat head dim (FSDP-style — storage and optimizer state shard evenly
    regardless of head count). Activations are explicitly constrained:
      · head count divisible by TP → heads sharded over "model" (Megatron TP);
      · otherwise → replicated over "model" (GSPMD then all-gathers the small
        WEIGHT rather than resharding big activations; attention compute is
        redundant across the model axis for these small-head archs — noted).
    """
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    tp = _tp_size(mesh)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", mem, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", mem, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, mem.shape[1], Hkv, hd)
    v = v.reshape(B, mem.shape[1], Hkv, hd)
    if tp > 1:
        if decode:
            # one-token tensors: replicate over model; the cache seq-sharding
            # carries the parallelism (flash-decode)
            q_ax = kv_ax = None
        else:
            q_ax = "model" if H % tp == 0 else None
            kv_ax = "model" if Hkv % tp == 0 else None
        q = _constrain(q, mesh, ("batch", None, q_ax, None))
        k = _constrain(k, mesh, ("batch", None, kv_ax, None))
        v = _constrain(v, mesh, ("batch", None, kv_ax, None))
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, cfg.rms_zero_centered)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps, cfg.rms_zero_centered)
    return q, k, v


def _q_scale(cfg: ModelConfig) -> float:
    if cfg.query_pre_attn_scalar:
        return cfg.query_pre_attn_scalar ** -0.5
    return cfg.hd ** -0.5


def _out_proj(p, o, x_dtype):
    B, S = o.shape[0], o.shape[1]
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"].astype(x_dtype))
    if "bo" in p:
        out = out + p["bo"].astype(x_dtype)
    return out


def attn_apply(p, x, ctx: LayerCtx, *, kind: str) -> jax.Array:
    """Full-sequence attention for kinds attn/local/enc. Returns output (B,S,D)."""
    cfg = ctx.cfg
    q, k, v = _qkv(p, x, x, cfg, ctx.mesh)
    cos, sin = rope_for(kind, ctx)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = q * _q_scale(cfg)
    if ctx.use_flash:
        from repro.kernels.flash_attention import ops as flash_ops
        o = flash_ops.flash_attention(
            q, k, v,
            causal=kind != "enc",
            window=cfg.window if kind == "local" else 0,
            softcap=cfg.attn_logit_softcap,
        )
    else:
        o = chunked_attention(
            q, k, v,
            causal=kind != "enc",
            window=cfg.window if kind == "local" else 0,
            softcap=cfg.attn_logit_softcap,
            q_chunk=ctx.q_chunk,
        )
    return _out_proj(p, o, x.dtype)


def cross_attn_apply(p, x, ctx: LayerCtx) -> jax.Array:
    """Cross-attention to ctx.memory. No rope, no causal mask."""
    cfg = ctx.cfg
    q, k, v = _qkv(p, x, ctx.memory.astype(x.dtype), cfg, ctx.mesh)
    q = q * _q_scale(cfg)
    o = chunked_attention(q, k, v, causal=False, q_chunk=ctx.q_chunk)
    out = _out_proj(p, o, x.dtype)
    if "gate_attn" in p:
        out = jnp.tanh(p["gate_attn"].astype(x.dtype)) * out
    return out


# --- caches ----------------------------------------------------------------

def attn_cache_schema(cfg: ModelConfig, batch: int, seq_len: int, *, kind: str,
                      tp: int = 16) -> Dict[str, ParamDef]:
    """Decode KV caches are SEQUENCE-sharded over "model" (flash-decode SP:
    per-shard partial softmax, psums of (B,H) stats only — head/hd sharding
    of GQA caches triggers GSPMD involuntary rematerialization instead).
    Ring (local-window) caches are small and stay replicated on model."""
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    is_ring = kind == "local" and cfg.window and cfg.window < seq_len
    T = cfg.window if is_ring else seq_len
    dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    spec = ("batch", None if is_ring else "seq_kv", None, None)
    return {
        "k": ParamDef((batch, T, Hkv, hd), spec, init="zeros", dtype=dt),
        "v": ParamDef((batch, T, Hkv, hd), spec, init="zeros", dtype=dt),
    }


def cross_cache_schema(cfg: ModelConfig, batch: int, mem_len: int,
                       tp: int = 16) -> Dict[str, ParamDef]:
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    spec = ("batch", None, None, None)
    return {
        "k": ParamDef((batch, mem_len, Hkv, hd), spec, init="zeros", dtype=dt),
        "v": ParamDef((batch, mem_len, Hkv, hd), spec, init="zeros", dtype=dt),
    }


def _ring_slots(pos: jax.Array, W: int) -> jax.Array:
    """Absolute token position held by each ring slot at decode position pos."""
    j = jnp.arange(W)
    return pos - ((pos - j) % W)


def attn_prefill(p, x, ctx: LayerCtx, *, kind: str, cache_len: int):
    """Full-seq attention that also returns the populated decode cache."""
    cfg = ctx.cfg
    q, k, v = _qkv(p, x, x, cfg, ctx.mesh)
    cos, sin = rope_for(kind, ctx)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = chunked_attention(
        q * _q_scale(cfg), k, v,
        causal=kind != "enc",
        window=cfg.window if kind == "local" else 0,
        softcap=cfg.attn_logit_softcap,
        q_chunk=ctx.q_chunk,
    )
    S = x.shape[1]
    if kind == "local" and cfg.window and cfg.window < cache_len:
        W = cfg.window
        last_k, last_v = k[:, S - W:], v[:, S - W:]
        slots = (S - W + jnp.arange(W)) % W
        ck = jnp.zeros_like(last_k).at[:, slots].set(last_k)
        cv = jnp.zeros_like(last_v).at[:, slots].set(last_v)
        cache = {"k": ck, "v": cv}
    else:
        pad = cache_len - S
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        }
    return _out_proj(p, o, x.dtype), cache


def attn_decode(p, x, cache, ctx: LayerCtx, *, kind: str):
    """One-token attention against the cache. x: (B,1,D)."""
    cfg = ctx.cfg
    pos = ctx.pos
    q, k, v = _qkv(p, x, x, cfg, ctx.mesh, decode=True)
    cos, sin = rope_for(kind, ctx)  # tables for the single current position
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    T = cache["k"].shape[1]
    is_ring = kind == "local" and cfg.window and cfg.window == T
    slot = (pos % T) if is_ring else pos
    ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    if is_ring:
        kv_pos = _ring_slots(pos, T)
    else:
        kv_pos = jnp.arange(T)
    o = decode_attention(
        q * _q_scale(cfg), ck, cv, kv_pos, pos,
        window=cfg.window if kind == "local" else 0,
        softcap=cfg.attn_logit_softcap,
    )
    return _out_proj(p, o, x.dtype), {"k": ck, "v": cv}


def cross_attn_decode(p, x, cache, ctx: LayerCtx):
    """Cross-attention during decode: static precomputed memory K/V."""
    cfg = ctx.cfg
    B, _, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, 1, H, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, cfg.rms_zero_centered)
    T = cache["k"].shape[1]
    o = decode_attention(
        q * _q_scale(cfg), cache["k"], cache["v"],
        jnp.arange(T), jnp.array(T, jnp.int32),
        softcap=0.0,
    )
    out = _out_proj(p, o, x.dtype)
    if "gate_attn" in p:
        out = jnp.tanh(p["gate_attn"].astype(x.dtype)) * out
    return out, cache


def cross_build_cache(p, memory, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder/vision memory."""
    B, M, _ = memory.shape
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"].astype(memory.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(memory.dtype)
        v = v + p["bv"].astype(memory.dtype)
    k = k.reshape(B, M, Hkv, hd)
    v = v.reshape(B, M, Hkv, hd)
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps, cfg.rms_zero_centered)
    dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    return {"k": k.astype(dt), "v": v.astype(dt)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None, *, gated_tag: bool = False) -> Dict[str, Any]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.mlp_gated:
        # §Perf C2: gate and up are FUSED into one (D, 2, F) projection — one
        # forward matmul and ONE backward dx all-reduce instead of two. The
        # gate/up split is on the UNSHARDED middle dim (a flat (D,2F) layout
        # resharded on split — measured +14% collectives; this layout is
        # split-free).
        s = {
            "w_gateup": ParamDef((D, 2, F), ("embed", None, "ff"), init="lecun"),
            "w_down": ParamDef((F, D), ("ff", "embed"), init="lecun"),
        }
    else:
        s = {
            "w_up": ParamDef((D, F), ("embed", "ff"), init="lecun"),
            "w_down": ParamDef((F, D), ("ff", "embed"), init="lecun"),
        }
        if cfg.mlp_bias:
            s["b_up"] = ParamDef((F,), ("ff",), init="zeros")
            s["b_down"] = ParamDef((D,), (None,), init="zeros")
    if gated_tag:  # llama-3.2-vision cross layers gate their FFN too
        s["gate_ffn"] = ParamDef((1,), (None,), init="zeros")
    return s


def _act(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp_apply(p, x, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_gated:
        gu = jnp.einsum("bsd,dtf->bstf", x, p["w_gateup"].astype(x.dtype))
        out = jnp.einsum("bsf,fd->bsd", _act(gu[:, :, 0], cfg.act) * gu[:, :, 1],
                         p["w_down"].astype(x.dtype))
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        if "b_up" in p:
            u = u + p["b_up"].astype(x.dtype)
        out = jnp.einsum("bsf,fd->bsd", _act(u, cfg.act), p["w_down"].astype(x.dtype))
        if "b_down" in p:
            out = out + p["b_down"].astype(x.dtype)
    if "gate_ffn" in p:
        out = jnp.tanh(p["gate_ffn"].astype(x.dtype)) * out
    return out
