"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
a_t = exp(-c · softplus(Λ) ⊙ sigmoid(r_t)),   c = 8

Full-sequence path uses ``lax.associative_scan`` (log-depth on TPU); decode is
a single fused update. The temporal block wraps the RG-LRU with the Griffin
gating: conv1d(4) on the x-branch, GeLU gate branch, output projection.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig
from repro.common.schema import ParamDef

_C = 8.0


def rglru_schema(cfg: ModelConfig) -> Dict[str, Any]:
    D = cfg.d_model
    W = cfg.lru_width or D
    return {
        "w_x": ParamDef((D, W), ("embed", "lru"), init="lecun"),
        "w_gate": ParamDef((D, W), ("embed", "lru"), init="lecun"),
        "conv_w": ParamDef((cfg.conv_kernel, W), (None, "lru"), init="lecun"),
        "conv_b": ParamDef((W,), ("lru",), init="zeros"),
        "w_rec_gate": ParamDef((W, W), ("lru", None), init="lecun"),
        "w_in_gate": ParamDef((W, W), ("lru", None), init="lecun"),
        "lam": ParamDef((W,), ("lru",), init="custom", custom="rglru_lambda"),
        "w_out": ParamDef((W, D), ("lru", "embed"), init="lecun"),
    }


def rglru_cache_schema(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    W = cfg.lru_width or cfg.d_model
    return {
        "h": ParamDef((batch, W), ("batch", "lru"), init="zeros", dtype=jnp.float32),
        "conv": ParamDef((batch, cfg.conv_kernel - 1, W), ("batch", None, "lru"),
                         init="zeros", dtype=jnp.float32),
    }


def _gates(p, xb):
    """Recurrence gate a and input gate i from the x-branch. float32."""
    x32 = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x32, p["w_rec_gate"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x32, p["w_in_gate"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * x32)


def _conv(xb, w, b, history=None):
    K = w.shape[0]
    B, S, W = xb.shape
    pad = (jnp.zeros((B, K - 1, W), xb.dtype) if history is None
           else history.astype(xb.dtype))
    xp = jnp.concatenate([pad, xb], axis=1)
    out = sum(xp[:, i:i + S] * w[i].astype(xb.dtype) for i in range(K))
    return out + b.astype(xb.dtype), xp[:, -(K - 1):]


def rglru_apply(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig,
                init_h=None, conv_history=None, return_cache: bool = False):
    """Full-sequence temporal block. x: (B,S,D) → (B,S,D)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(x.dtype)),
                       approximate=True)
    xb, hist = _conv(xb, p["conv_w"], p["conv_b"], conv_history)
    a, bx = _gates(p, xb)                      # (B,S,W) f32 each

    if init_h is not None:
        bx = bx.at[:, 0].add(a[:, 0] * init_h.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, h = lax.associative_scan(combine, (a, bx), axis=1)
    y = (h.astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(x.dtype))
    if return_cache:
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": hist.astype(jnp.float32)}
    return out


def rglru_decode(p: Dict[str, Any], x: jax.Array, cache: Dict[str, jax.Array],
                 cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-step update. x: (B,1,D)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))[:, 0]
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(x.dtype))[:, 0],
                       approximate=True)
    K = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(xb.dtype), xb[:, None, :]], axis=1)
    xb = jnp.sum(hist * p["conv_w"].astype(xb.dtype)[None], axis=1) + p["conv_b"].astype(xb.dtype)
    a, bx = _gates(p, xb)
    h = a * cache["h"] + bx
    y = h.astype(x.dtype) * gate
    out = jnp.einsum("bw,wd->bd", y, p["w_out"].astype(x.dtype))[:, None, :]
    return out, {"h": h, "conv": hist[:, 1:].astype(jnp.float32)}
