"""Vocab embedding with the CGTrans dataflow (DESIGN §2, §5).

The table is sharded over the ``model`` axis on the vocab dim — the "storage
tier". Two lookup dataflows:

* **cgtrans** (shard_map): every shard resolves only the ids it *owns*
  (CAM-match analogue: range-mask), gathers locally, and the only cross-shard
  traffic is a psum of the (B,S,D) *result* — aggregated-before-transmitted.
  The VJP is the exact mirror: output grads are scatter-added **at the owner
  shard** (the paper's in-SSD aggregation), no raw table movement.
* **baseline** (plain ``take`` on the sharded table): GSPMD resolves the
  gather by materializing/collecting table shards — the "ship raw features
  over the bus" dataflow. Kept for the collective-byte comparison benches.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.logical import batch_axes
from repro.compat import shard_map


def _model_axis(mesh: Optional[Mesh]) -> Optional[str]:
    if mesh is not None and "model" in mesh.axis_names and mesh.shape["model"] > 1:
        return "model"
    return None


def embed_lookup(table: jax.Array, ids: jax.Array, *, mesh: Optional[Mesh] = None,
                 cgtrans: bool = True, compute_dtype=jnp.bfloat16) -> jax.Array:
    """ids: (B, S) int32 → (B, S, D)."""
    axis = _model_axis(mesh)
    if not cgtrans or axis is None:
        return jnp.take(table, ids, axis=0).astype(compute_dtype)

    n = mesh.shape[axis]
    V = table.shape[0]
    if V % n:
        return jnp.take(table, ids, axis=0).astype(compute_dtype)
    shard = V // n
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if dp and ids.shape[0] % dp_size:
        dp = ()   # replicate ids when the (micro)batch doesn't split evenly

    def local(table_shard, ids_blk):
        lo = lax.axis_index(axis) * shard
        rel = ids_blk - lo
        ok = (rel >= 0) & (rel < shard)
        rel = jnp.clip(rel, 0, shard - 1)
        part = jnp.take(table_shard, rel, axis=0).astype(compute_dtype)
        part = part * ok[..., None].astype(compute_dtype)
        return lax.psum(part, axis)          # compressed transmission: (B,S,D)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis, None), P(dp if dp else None, None)),
        out_specs=P(dp if dp else None, None, None),
    )(table, ids)


def logits_matmul(x: jax.Array, table: jax.Array, *, softcap: float = 0.0,
                  valid_vocab: int = 0) -> jax.Array:
    """(…, D) @ (V, D)ᵀ → (…, V), f32 accumulation.

    ``valid_vocab``: mask padded table rows (≥ valid_vocab) to -inf so the
    vocab-padding used for even sharding never leaks into softmax/sampling.
    """
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if valid_vocab and valid_vocab < table.shape[0]:
        pad_mask = jnp.arange(table.shape[0]) >= valid_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def chunked_softmax_xent(
    x: jax.Array,          # (B, S, D) final hiddens
    table: jax.Array,      # (V, D) tied output embedding
    labels: jax.Array,     # (B, S) int32, -1 = padding
    *,
    softcap: float = 0.0,
    max_chunk: int = 512,
    byte_budget: int = 1 << 28,
    valid_vocab: int = 0,
    mesh: Optional[Mesh] = None,
):
    """Sequence-chunked CE so (B,S,V) f32 logits never materialize.

    Returns (sum_loss, n_valid). Chunk size adapts so the PER-DEVICE logits
    block (B/dp · chunk · V/tp · 4 bytes) stays under ``byte_budget`` — using
    global shapes here once produced a pathological 2048-step scan whose
    per-step embedding-grad all-reduces dominated the whole model's
    collectives. Each chunk step is rematerialized (logits recomputed in the
    backward) so the scan stores only the small per-chunk hiddens.
    """
    B, S, D = x.shape
    V = table.shape[0]
    dp = tp = 1
    if mesh is not None:
        from repro.common.logical import dp_size
        dp = dp_size(mesh)
        tp = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1
    dev_bytes = max((B // max(dp, 1)) * (V // max(tp, 1)) * 4, 1)
    chunk = max(1, min(max_chunk, byte_budget // dev_bytes))
    while S % chunk:
        chunk -= 1
    n = S // chunk
    xc = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def chunk_loss(xi, li):
        logits = logits_matmul(xi, table, softcap=softcap,
                               valid_vocab=valid_vocab)      # (B,chunk,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    def step(carry, inp):
        loss_sum, cnt = carry
        l, c = chunk_loss(*inp)
        return (loss_sum + l, cnt + c), None

    (loss_sum, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc))
    return loss_sum, cnt
