"""Vocab embedding with the CGTrans dataflow (DESIGN §2, §5).

The table is sharded over the ``model`` axis on the vocab dim — the "storage
tier". Two lookup dataflows:

* **cgtrans** (shard_map): every shard resolves only the ids it *owns*
  (CAM-match analogue: range-mask), gathers locally, and the only cross-shard
  traffic is a psum of the (B,S,D) *result* — aggregated-before-transmitted.
  The VJP is the exact mirror: output grads are scatter-added **at the owner
  shard** (the paper's in-SSD aggregation), no raw table movement. The two
  FAST-GAS knobs surface here too: ``impl="pallas"`` routes that owner-side
  grad scatter through the FAST-GAS kernel (a custom VJP — the forward
  gather is untouched), and ``request_chunk`` streams the token block
  through the lookup ``request_chunk`` tokens at a time (the SSD
  command-queue analogue), bounding the per-shard pre-psum partial at
  (chunk, D) instead of (B·S, D).
* **baseline** (plain ``take`` on the sharded table): GSPMD resolves the
  gather by materializing/collecting table shards — the "ship raw features
  over the bus" dataflow. Kept for the collective-byte comparison benches.

This module's forward-only custom VJP was the proof-of-pattern for the
differentiable FAST-GAS path: ``repro.core.gas`` now carries the same
backward-is-also-GAS rules for the graph aggregations themselves
(``gas_scatter_weighted``/``gas_gather``), which is what lets
``make_sage_train_step`` run ``impl="pallas"`` end-to-end.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.logical import batch_axes
from repro.compat import shard_map
from repro.core import gas
from repro.core.cgtrans import scan_request_chunks


def _model_axis(mesh: Optional[Mesh]) -> Optional[str]:
    if mesh is not None and "model" in mesh.axis_names and mesh.shape["model"] > 1:
        return "model"
    return None


def _rel_ok(ids_blk, lo, shard):
    rel = jnp.clip(ids_blk - lo, 0, shard - 1)
    ok = (ids_blk - lo >= 0) & (ids_blk - lo < shard)
    return rel, ok


def embed_lookup(table: jax.Array, ids: jax.Array, *, mesh: Optional[Mesh] = None,
                 cgtrans: bool = True, compute_dtype=jnp.bfloat16,
                 impl: str = "xla",
                 request_chunk: Optional[int] = None) -> jax.Array:
    """ids: (B, S) int32 → (B, S, D).

    ``impl`` selects the GAS backend for the owner-side embedding-grad
    scatter of the cgtrans dataflow; ``request_chunk`` streams the flattened
    token block through the lookup in chunks (SSD command-queue analogue).
    Both are inert on the baseline/unsharded paths.
    """
    axis = _model_axis(mesh)
    if not cgtrans or axis is None:
        return jnp.take(table, ids, axis=0).astype(compute_dtype)

    n = mesh.shape[axis]
    V = table.shape[0]
    if V % n:
        return jnp.take(table, ids, axis=0).astype(compute_dtype)
    shard = V // n
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if dp and ids.shape[0] % dp_size:
        dp = ()   # replicate ids when the (micro)batch doesn't split evenly

    def resolve(table_shard, rel, ok):
        part = jnp.take(table_shard, rel, axis=0).astype(compute_dtype)
        return lax.psum(part * ok[..., None].astype(compute_dtype), axis)

    def local(table_shard, ids_blk):
        lo = lax.axis_index(axis) * shard
        rel, ok = _rel_ok(ids_blk, lo, shard)
        if request_chunk is None:
            return resolve(table_shard, rel, ok)             # (B, S, D)

        # chunked request stream: issue the flattened token block to the
        # storage tier ``request_chunk`` tokens at a time (the scan/pad
        # machinery is cgtrans's — each token is a K=1 request row)
        B, S = ids_blk.shape
        out = scan_request_chunks(
            lambda rel_c, ok_c: resolve(table_shard, rel_c[:, 0], ok_c[:, 0]),
            rel.reshape(-1, 1), ok.reshape(-1, 1), request_chunk)
        return out.reshape(B, S, table_shard.shape[-1])

    def sharded_lookup(tab, ids_):
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis, None), P(dp if dp else None, None)),
            out_specs=P(dp if dp else None, None, None),
        )(tab, ids_)

    if impl != "pallas":
        return sharded_lookup(table, ids)

    # impl="pallas": same forward, but the VJP is the paper's in-SSD grad
    # aggregation — a forward-only shard_map in which every shard
    # GAS-scatters the output cotangent into its owned rows through the
    # FAST-GAS kernel, then psums over the batch axes. No transpose machinery
    # touches the kernel (pallas_call has no shard_map replication rule, and
    # the check-off transpose semantics are version-dependent), and no raw
    # table rows ever cross the bus.
    @jax.custom_vjp
    def lookup(tab, ids_):
        return sharded_lookup(tab, ids_)

    def lookup_fwd(tab, ids_):
        # the zero-size residual carries the table dtype into the bwd cast
        return sharded_lookup(tab, ids_), (ids_, jnp.zeros((0,), tab.dtype))

    def lookup_bwd(res, g):
        import numpy as np
        ids_, like = res

        def scatter_body(g_blk, ids_blk):
            lo = lax.axis_index(axis) * shard
            rel, ok = _rel_ok(ids_blk, lo, shard)
            gf = g_blk.reshape(-1, g_blk.shape[-1]).astype(jnp.float32)
            dtab = gas.gas_scatter_weighted(
                rel.reshape(-1), gf, jnp.ones((gf.shape[0],), jnp.float32),
                ok.reshape(-1), shard, op="add", impl="pallas")
            if dp:
                dtab = lax.psum(dtab, dp)   # table is dp-replicated
            return dtab

        dtab = shard_map(
            scatter_body, mesh=mesh,
            in_specs=(P(dp if dp else None, None, None),
                      P(dp if dp else None, None)),
            out_specs=P(axis, None), check_vma=False,
        )(g, ids_)
        return dtab.astype(like.dtype), np.zeros(ids_.shape, jax.dtypes.float0)

    lookup.defvjp(lookup_fwd, lookup_bwd)
    return lookup(table, ids)


def logits_matmul(x: jax.Array, table: jax.Array, *, softcap: float = 0.0,
                  valid_vocab: int = 0) -> jax.Array:
    """(…, D) @ (V, D)ᵀ → (…, V), f32 accumulation.

    ``valid_vocab``: mask padded table rows (≥ valid_vocab) to -inf so the
    vocab-padding used for even sharding never leaks into softmax/sampling.
    """
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if valid_vocab and valid_vocab < table.shape[0]:
        pad_mask = jnp.arange(table.shape[0]) >= valid_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def chunked_softmax_xent(
    x: jax.Array,          # (B, S, D) final hiddens
    table: jax.Array,      # (V, D) tied output embedding
    labels: jax.Array,     # (B, S) int32, -1 = padding
    *,
    softcap: float = 0.0,
    max_chunk: int = 512,
    byte_budget: int = 1 << 28,
    valid_vocab: int = 0,
    mesh: Optional[Mesh] = None,
):
    """Sequence-chunked CE so (B,S,V) f32 logits never materialize.

    Returns (sum_loss, n_valid). Chunk size adapts so the PER-DEVICE logits
    block (B/dp · chunk · V/tp · 4 bytes) stays under ``byte_budget`` — using
    global shapes here once produced a pathological 2048-step scan whose
    per-step embedding-grad all-reduces dominated the whole model's
    collectives. Each chunk step is rematerialized (logits recomputed in the
    backward) so the scan stores only the small per-chunk hiddens.
    """
    B, S, D = x.shape
    V = table.shape[0]
    dp = tp = 1
    if mesh is not None:
        from repro.common.logical import dp_size
        dp = dp_size(mesh)
        tp = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1
    dev_bytes = max((B // max(dp, 1)) * (V // max(tp, 1)) * 4, 1)
    chunk = max(1, min(max_chunk, byte_budget // dev_bytes))
    while S % chunk:
        chunk -= 1
    n = S // chunk
    xc = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def chunk_loss(xi, li):
        logits = logits_matmul(xi, table, softcap=softcap,
                               valid_vocab=valid_vocab)      # (B,chunk,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    def step(carry, inp):
        loss_sum, cnt = carry
        l, c = chunk_loss(*inp)
        return (loss_sum + l, cnt + c), None

    (loss_sum, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc))
    return loss_sum, cnt
