"""Version-portability layer for every JAX API this repo uses that drifted
across releases. All version-sensitive imports live HERE and nowhere else —
call sites import ``shard_map``/``make_mesh``/``AxisType``/``psum_scatter``
from ``repro.compat`` and never touch ``jax.shard_map``,
``jax.sharding.AxisType`` or ``axis_types=`` directly.

Covered drift (JAX 0.4.x → current):

* ``shard_map`` — promoted from ``jax.experimental.shard_map.shard_map`` to
  top-level ``jax.shard_map``; its replication-check kwarg was renamed
  ``check_rep`` → ``check_vma`` along the way. The wrapper takes the modern
  keyword-only signature and translates down.
* mesh construction — ``jax.make_mesh`` appeared in 0.4.35 and grew an
  ``axis_types=`` kwarg later; before either, meshes were built as
  ``Mesh(mesh_utils.create_device_mesh(shape), names)``. ``make_mesh`` here
  accepts ``axis_types`` always and silently drops it when the installed JAX
  cannot express it (pre-AxisType meshes behave as Auto everywhere, which is
  exactly what this repo requests).
* ``jax.sharding.AxisType`` — absent before sharding-in-types; a stub enum
  with the same member names keeps call sites one-sourced.
* ``lax.psum_scatter`` — present throughout the supported range but guarded
  anyway; the fallback is the semantically-identical (if uncompressed)
  psum + owned-slice, so CGTrans still *computes* correctly on a JAX that
  lacks the fused collective (the collective-bytes benches will simply show
  the all-reduce cost).

``FEATURES`` records what was detected; ``scripts/check_env.py`` prints it as
a support matrix and fails fast with an actionable message instead of letting
12 test modules error at collection/runtime.
"""

from __future__ import annotations

import inspect
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax import lax
from jax.experimental import mesh_utils
from jax.sharding import Mesh

FEATURES: Dict[str, object] = {"jax_version": jax.__version__}


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    FEATURES["shard_map_source"] = "jax.shard_map"
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    FEATURES["shard_map_source"] = "jax.experimental.shard_map"

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)
FEATURES["shard_map_check_kwarg"] = (
    "check_vma" if "check_vma" in _SHARD_MAP_PARAMS
    else "check_rep" if "check_rep" in _SHARD_MAP_PARAMS else None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """Version-portable ``shard_map``. Modern keyword-only calling convention;
    ``check_vma`` maps onto ``check_rep`` on older JAX (same meaning: verify
    the per-shard replication/varying-manual-axes annotation). ``None`` keeps
    the installed default."""
    kwargs = {}
    if check_vma is not None and FEATURES["shard_map_check_kwarg"]:
        kwargs[FEATURES["shard_map_check_kwarg"]] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

try:
    from jax.sharding import AxisType  # noqa: F401  (JAX ≥ 0.5-era)
    FEATURES["axis_type"] = "jax.sharding.AxisType"
except ImportError:
    import enum

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stub with the real member names; pre-AxisType meshes implicitly
        treat every axis as Auto, so dropping these is lossless for us."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    FEATURES["axis_type"] = "repro.compat stub"


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

_HAS_MAKE_MESH = hasattr(jax, "make_mesh")
_MAKE_MESH_AXIS_TYPES = (
    _HAS_MAKE_MESH and "axis_types" in inspect.signature(jax.make_mesh).parameters)
FEATURES["make_mesh"] = (
    "jax.make_mesh(axis_types=...)" if _MAKE_MESH_AXIS_TYPES
    else "jax.make_mesh" if _HAS_MAKE_MESH
    else "Mesh(mesh_utils.create_device_mesh(...))")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Tuple] = None, devices=None) -> Mesh:
    """Build a ``Mesh``, expressing ``axis_types`` only where the installed
    JAX can. Falls back to ``mesh_utils.create_device_mesh`` pre-0.4.35."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if _MAKE_MESH_AXIS_TYPES and axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, devices=devices)
    if _HAS_MAKE_MESH:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    dev = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return Mesh(dev, axis_names)


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------

if hasattr(lax, "psum_scatter"):
    psum_scatter = lax.psum_scatter
    FEATURES["psum_scatter"] = "lax.psum_scatter"
else:
    def psum_scatter(x, axis_name, *, scatter_dimension: int = 0,
                     tiled: bool = False):
        """Emulation: all-reduce then keep this shard's owned block. Same
        result (and gradient) as the fused reduce-scatter, without the
        bandwidth saving — correctness fallback only."""
        summed = lax.psum(x, axis_name)
        n = lax.psum(1, axis_name)          # static axis size
        i = lax.axis_index(axis_name)
        size = x.shape[scatter_dimension] // n if tiled else 1
        out = lax.dynamic_slice_in_dim(summed, i * size, size,
                                       axis=scatter_dimension)
        if not tiled:
            out = lax.squeeze(out, (scatter_dimension,))
        return out

    FEATURES["psum_scatter"] = "repro.compat psum+slice emulation"


# ---------------------------------------------------------------------------
# collective primitive NAMES (jaxpr spellings drift across JAX versions)
# ---------------------------------------------------------------------------

#: canonical collective name → every jaxpr primitive spelling that means it.
#: The *API* drift is handled above (``psum_scatter``); this is the *trace*
#: side of the same single-door rule: ``lax.psum_scatter`` lowers to a
#: primitive literally named ``reduce_scatter``, ``lax.ppermute`` to
#: ``ppermute`` or ``collective_permute`` depending on version, and
#: ``shard_map``'s replication checker rewrites ``psum`` to ``psum2``
#: (0.4.3x-era; later versions went back to ``psum``). Anything that reads
#: jaxprs (``launch/jaxpr_stats``, ``analysis/contracts``) counts under the
#: canonical key so committed budgets survive version bumps.
COLLECTIVE_ALIASES: Dict[str, Tuple[str, ...]] = {
    "all_to_all": ("all_to_all",),
    "all_gather": ("all_gather",),
    "psum": ("psum", "psum2"),
    "psum_scatter": ("psum_scatter", "reduce_scatter"),
    "ppermute": ("ppermute", "collective_permute"),
    "pmax": ("pmax",),
    "pmin": ("pmin",),
}

_SPELLING_TO_CANONICAL: Dict[str, str] = {
    spelling: canon
    for canon, spellings in COLLECTIVE_ALIASES.items()
    for spelling in spellings
}


def canonical_collective(primitive_name: str) -> Optional[str]:
    """Canonical collective name for a jaxpr primitive name, or ``None`` if
    the primitive is not a cross-shard collective."""
    return _SPELLING_TO_CANONICAL.get(primitive_name)


def feature_matrix() -> Dict[str, object]:
    """Snapshot of what the compat layer detected on the installed JAX."""
    return dict(FEATURES)
