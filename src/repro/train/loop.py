"""Fault-tolerant training loop.

Restart semantics: on entry the loop restores the newest committed checkpoint
(if any) and resumes from its step; the data pipeline is stateless-indexable
so the token stream realigns exactly. SIGTERM (preemption) triggers a final
synchronous checkpoint before exit. Straggler steps are flagged by the
StepMonitor; the hook logs (in a fleet deployment it would drain the host).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime import PreemptionGuard, StepMonitor


def train_loop(
    *,
    step_fn: Callable,
    state,
    batches: Iterable[Dict[str, np.ndarray]],
    total_steps: int,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    monitor: Optional[StepMonitor] = None,
    guard: Optional[PreemptionGuard] = None,
    log_fn: Callable[[str], None] = print,
):
    """Runs to total_steps (resuming if a checkpoint exists). Returns state."""
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state)
        log_fn(f"[resume] restored checkpoint at step {start_step}")

    monitor = monitor or StepMonitor()
    it = iter(batches)
    # fast-forward the (stateless) stream
    for _ in range(start_step):
        next(it)

    step = start_step
    for step in range(start_step, total_steps):
        batch = next(it)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["total_loss"] if "total_loss" in metrics
                              else jax.tree.leaves(metrics)[0])
        dt = time.perf_counter() - t0
        straggler = monitor.record(step, dt)
        if straggler:
            log_fn(f"[straggler] step {step} took {dt * 1e3:.1f} ms "
                   f"(ewma {monitor.snapshot()['ewma_s'] * 1e3:.1f} ms)")
        if log_every and step % log_every == 0:
            loss = float(metrics.get("total_loss", metrics.get("loss", np.nan)))
            log_fn(f"step {step:5d} loss {loss:8.4f} dt {dt * 1e3:7.1f} ms")
        done = step + 1
        if ckpt is not None and (done % ckpt_every == 0 or done == total_steps):
            ckpt.save_async(state, done)
        if guard is not None and guard.should_exit:
            log_fn(f"[preempt] SIGTERM at step {done}; checkpointing and exiting")
            if ckpt is not None:
                ckpt.wait()
                ckpt.save(state, done)
            return state, done
    if ckpt is not None:
        ckpt.wait()
    return state, step + 1
