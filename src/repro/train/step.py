"""Train / prefill / decode step builders (pjit-ready, schema-driven).

``make_train_step`` builds the jit-able (state, batch) → (state, metrics)
function: microbatched gradient accumulation (lax.scan — each microbatch's
backward psum overlaps the next microbatch's compute under XLA's latency-
hiding scheduler), AdamW, optional int8-EF gradient compression.

``state_schema``/``batch_structs``/``*_logical_specs`` produce the
ShapeDtypeStruct trees and logical sharding specs the launcher and the
multi-pod dry-run consume — no allocation anywhere on that path.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.common.config import ModelConfig, ShapeConfig, TrainConfig
from repro.common.schema import ParamDef, param_logical_specs, param_structs, tree_map_defs
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, opt_state_schema


# ---------------------------------------------------------------------------
# schemas / structs / specs
# ---------------------------------------------------------------------------

def state_schema(cfg: ModelConfig, tc: TrainConfig, *, max_seq: int = 0):
    ps = T.model_schema(cfg, max_seq=max_seq)
    return {
        "params": ps,
        "opt": opt_state_schema(ps, tc),
        "step": ParamDef((), (), init="zeros", dtype=jnp.int32),
    }


def batch_structs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    out = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
           "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), cdt)
    if cfg.vision_seq:
        out["vision"] = jax.ShapeDtypeStruct((B, cfg.vision_seq, cfg.d_model), cdt)
    return out


def batch_logical_specs(cfg: ModelConfig) -> Dict[str, tuple]:
    out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.is_encoder_decoder:
        out["frames"] = ("batch", "seq", "embed")
    if cfg.vision_seq:
        out["vision"] = ("batch", "seq", "embed")
    return out


def decode_structs(cfg: ModelConfig, shape: ShapeConfig, tp: int = 16):
    """(token, caches, pos) structs for a decode step at this shape."""
    B, S = shape.global_batch, shape.seq_len
    cache_schema = T.stack_cache_schema_for(cfg, B, S, tp)
    return (
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        param_structs(cache_schema),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def decode_logical_specs(cfg: ModelConfig, shape: ShapeConfig, tp: int = 16):
    cache_schema = T.stack_cache_schema_for(cfg, shape.global_batch, shape.seq_len, tp)
    return (
        ("batch", None),
        param_logical_specs(cache_schema),
        (),
    )


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tc: TrainConfig, *,
                    mesh: Optional[Mesh] = None, use_flash: bool = False,
                    param_shardings=None):
    def loss_fn(params, batch):
        return T.loss_fn(params, batch, cfg, mesh=mesh, use_flash=use_flash)

    def _like_params(tree):
        """Constrain a param-shaped tree to the param shardings — without
        this, GSPMD replicates the grad ACCUMULATOR of the microbatch scan
        (a full unsharded stacked-layer gradient per tensor: 5+ GB/buffer
        on the 90B config)."""
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, param_shardings)

    def train_step(state, batch):
        params = state["params"]
        if tc.microbatches > 1:
            mb = tc.microbatches

            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            mb_batch = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                g_acc = _like_params(
                    jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g))
                return (g_acc, l_acc + l), metrics

            zeros = _like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_fn, (zeros, jnp.float32(0.0)), mb_batch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss_val = loss_sum / mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss_val, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], tc)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {**metrics, **opt_metrics, "total_loss": loss_val}

    return train_step


def make_prefill_step(cfg: ModelConfig, *, cache_len: int,
                      mesh: Optional[Mesh] = None, use_flash: bool = False):
    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg, cache_len=cache_len, mesh=mesh,
                         use_flash=use_flash)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None):
    def decode_step(params, token, caches, pos):
        return T.decode_step(params, token, caches, pos, cfg, mesh=mesh)
    return decode_step


def init_state(cfg: ModelConfig, tc: TrainConfig, key, *, max_seq: int = 0):
    from repro.common.schema import init_params
    params = init_params(T.model_schema(cfg, max_seq=max_seq), key)
    return {"params": params, "opt": adamw_init(params, tc),
            "step": jnp.zeros((), jnp.int32)}
