from repro.train.loop import train_loop
from repro.train.pipeline import make_sage_train_step, pipelined_apply
from repro.train.step import (
    batch_logical_specs,
    batch_structs,
    decode_logical_specs,
    decode_structs,
    init_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    state_schema,
)

__all__ = [
    "train_loop", "batch_logical_specs", "batch_structs",
    "decode_logical_specs", "decode_structs", "init_state",
    "make_decode_step", "make_prefill_step", "make_sage_train_step",
    "make_train_step", "pipelined_apply", "state_schema",
]
