"""Training pipelines: GPipe stage parallelism + the graph-workload step.

Two entry points:

* ``pipelined_apply`` — pipeline parallelism over the ``pod`` axis (GPipe
  fill–drain schedule). At 512 chips none of the assigned configs *needs* PP
  (FSDP×TP fits them — see EXPERIMENTS §Dry-run), so this stage-parallel
  runner is off by default and exercised by tests. Stages = contiguous block
  ranges of the pattern-scan; the boundary transfer is a ``ppermute`` along
  ``pod``; microbatches stream through with a lax.scan (fill–drain = GPipe;
  jax autodiff differentiates through the ppermute, giving the reverse
  schedule for backward automatically). Composes with the data/model axes
  untouched: within a stage, everything keeps its FSDP×TP sharding.

* ``make_sage_train_step`` — the paper's workload as a jit-able pipeline
  stage: GraphSAGE + CGTrans loss/grad/AdamW against an owner-sharded
  feature table. This is where the FAST-GAS deployment knobs surface into
  training: ``cfg.impl`` (GAS backend for every per-shard aggregation),
  ``cfg.request_chunk`` (SSD command-queue depth for the sampled request
  stream), ``cfg.scheduled`` (the destination-binned locality pass that
  turns the kernel's idle-skip occupancy into a thin band; defaults on
  exactly when ``impl="pallas"``) and ``cfg.coalesce`` (the self-lookup +
  2-hop requests fused into ONE SSD command block — one all_to_all, one
  kernel gather, one backward cotangent scatter per step; on by default)
  ride in on the ``GCNConfig`` — all
  callers (``examples/train_graphsage.py``, the distributed test cases)
  build their step through here instead of hand-rolling the grad/update
  composition. The schedule serves forward AND backward: it is carried as a
  custom-VJP residual, so the reverse pass skips the same idle tiles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.config import ModelConfig, TrainConfig
from repro.compat import shard_map


def make_sage_train_step(cfg, tc: TrainConfig, *, feats,
                         mesh: Optional[Mesh] = None,
                         relabel=None) -> Callable:
    """(state, batch) → (state, metrics) for GraphSAGE + CGTrans training.

    ``cfg`` is a ``repro.core.gcn.GCNConfig`` — its ``dataflow``, ``impl``,
    ``request_chunk`` and ``scheduled`` fields select the transmission
    dataflow, the GAS backend, the request-stream chunking and the
    idle-skip locality scheduling for every aggregation in the step. ``feats`` is the owner-sharded (P, part, F) feature table (the
    storage tier); ``state`` is ``{"params", "opt", "step"}``.

    ``impl="pallas"`` trains end-to-end: the FAST-GAS kernel carries custom
    VJPs (``repro.core.gas``) whose backward is itself in-SSD GAS work — a
    backward scatter through the kernel for the gathers, a masked weighted
    gather for the scatter — so the reverse pass never leaves the regime
    the forward models. Per-step gradient parity with ``impl="xla"`` is
    locked in by ``tests/test_cgtrans_grad.py``.

    With ``cfg.partition="island"``, ``feats`` must be the islandized table
    (``IslandPartition.relabel_rows`` order) and ``relabel`` the old→new id
    map; every batch's caller-visible ids are translated at the
    ``sage_loss`` entry (islandized ≡ interval bit-exact, grads included).
    """
    from repro.core.gcn import sage_loss
    from repro.optim import adamw_update

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: sage_loss(p, feats, batch, cfg, mesh=mesh,
                                relabel=relabel),
            has_aux=True)(state["params"])
        new_p, new_opt, om = adamw_update(state["params"], grads,
                                          state["opt"], tc)
        return ({"params": new_p, "opt": new_opt, "step": state["step"] + 1},
                {**metrics, **om, "total_loss": loss})

    return train_step


def split_stages(n_blocks: int, n_stages: int) -> Tuple[Tuple[int, int], ...]:
    """Contiguous block ranges per stage, balanced to ±1."""
    base, extra = divmod(n_blocks, n_stages)
    out = []
    start = 0
    for s in range(n_stages):
        size = base + (1 if s < extra else 0)
        out.append((start, start + size))
        start += size
    return tuple(out)


def pipelined_apply(
    block_fn: Callable,      # (x, block_params) -> x
    params_stacked,          # pytree, leading dim = n_blocks
    x: jax.Array,            # (n_micro, mB, S, D) microbatched activations
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the stacked blocks as a pipeline over ``axis``.

    Every pod holds ALL stacked params (they are already FSDP-sharded over
    data; the pod axis replicates them) but only *executes* its own stage's
    slice, selected by ``lax.axis_index``. Schedule: n_micro + n_stages - 1
    ticks; at each tick a pod processes the microbatch it holds (if valid)
    and ppermutes its output to the next pod.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_blocks = jax.tree.leaves(params_stacked)[0].shape[0]
    ranges = split_stages(n_blocks, n_stages)
    max_len = max(e - s for s, e in ranges)

    def stage_fn(xi, stage_idx):
        """Run this pod's block range on one microbatch."""
        def body(x, i):
            bp = jax.tree.map(lambda a: a[i], params_stacked)
            return block_fn(x, bp), None

        start = jnp.asarray([r[0] for r in ranges])[stage_idx]
        length = jnp.asarray([r[1] - r[0] for r in ranges])[stage_idx]

        def step(carry, j):
            x = carry
            i = start + jnp.minimum(j, length - 1)
            bp = jax.tree.map(lambda a: a[i], params_stacked)
            y = block_fn(x, bp)
            x = jnp.where(j < length, y, x)
            return x, None

        xi, _ = lax.scan(step, xi, jnp.arange(max_len))
        return xi

    def shard_fn(params_stacked, x):
        stage = lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x[0])
        outs = jnp.zeros_like(x)

        def tick(carry, t):
            buf, outs = carry
            mb_in = t                     # microbatch entering stage 0 at tick t
            take = (stage == 0) & (mb_in < n_micro)
            inp = jnp.where(take, x[jnp.minimum(mb_in, n_micro - 1)], buf)
            # valid iff this pod currently holds microbatch (t - stage)
            holds = (t >= stage) & (t - stage < n_micro)
            y = stage_fn(inp, stage)
            y = jnp.where(holds, y, inp)
            # last stage writes its finished microbatch
            done_mb = t - stage
            write = holds & (stage == n_stages - 1)
            outs = lax.cond(
                write,
                lambda o: lax.dynamic_update_slice(
                    o, y[None], (jnp.maximum(done_mb, 0),) + (0,) * y.ndim),
                lambda o: o, outs)
            # pass forward along the pipeline
            nxt = lax.ppermute(y, axis,
                               [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (_, outs), _ = lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage wrote results; psum broadcasts them to all pods
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return lax.psum(outs, axis)

    other = tuple(a for a in mesh.axis_names if a != axis)
    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P()),   # params + activations replicated over pod
        out_specs=P(),
        check_vma=False,
    )(params_stacked, x)
