from repro.runtime.health import Heartbeat, PreemptionGuard, StepMonitor

__all__ = ["Heartbeat", "PreemptionGuard", "StepMonitor"]
