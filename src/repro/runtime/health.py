"""Fleet-runtime health machinery: stragglers, heartbeats, preemption.

* ``StepMonitor`` — EWMA step-time tracker; flags straggler steps (z-score
  over a robust MAD estimate). In a multi-host deployment each host runs one
  and the controller compares `snapshot()`s; slow hosts get drained (the hook
  is ``on_straggler``).
* ``Heartbeat``   — liveness file for an external supervisor (touch every K
  seconds; supervisor restarts the job if stale).
* ``PreemptionGuard`` — converts SIGTERM into a cooperative "checkpoint and
  exit" flag the training loop polls (TPU preemption notice pattern).
"""

from __future__ import annotations

import collections
import os
import signal
import threading
import time
from typing import Callable, Deque, Dict, Optional


class StepMonitor:
    def __init__(self, *, window: int = 64, z_threshold: float = 4.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.window = window
        self.z = z_threshold
        self.times: Deque[float] = collections.deque(maxlen=window)
        self.on_straggler = on_straggler
        self.flagged = 0
        self.steps = 0
        self._ewma: Optional[float] = None

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.steps += 1
        is_bad = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            mad = sorted(abs(t - med) for t in self.times)[len(self.times) // 2]
            # A window of identical step times has MAD = 0; flooring sigma at
            # only 1e-6 would then flag ANY nanosecond of jitter as a
            # straggler. Floor at a fraction of the median too, so "slow"
            # always means slow relative to the typical step.
            sigma = max(1.4826 * mad, 0.05 * med, 1e-6)
            if (seconds - med) / sigma > self.z:
                is_bad = True
                self.flagged += 1
                if self.on_straggler:
                    self.on_straggler(step, seconds)
        self.times.append(seconds)
        a = 0.1
        self._ewma = seconds if self._ewma is None else a * seconds + (1 - a) * self._ewma
        return is_bad

    def snapshot(self) -> Dict[str, float]:
        return {"ewma_s": self._ewma or 0.0, "flagged": self.flagged,
                "steps": self.steps}


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        def beat():
            while not self._stop.wait(self.interval):
                self._touch()
        self._touch()
        self._thread = threading.Thread(target=beat, daemon=True)
        self._thread.start()

    def _touch(self) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def touch(self) -> None:
        """Synchronous liveness update — for event-driven loops (the serving
        engine beats once per dispatch) instead of the timer thread."""
        self._touch()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)

    @staticmethod
    def is_alive(path: str, stale_after_s: float = 60.0) -> bool:
        try:
            with open(path) as f:
                return time.time() - float(f.read()) < stale_after_s
        except (OSError, ValueError):
            return False


class PreemptionGuard:
    """SIGTERM → cooperative shutdown flag (poll ``should_exit``)."""

    def __init__(self, install: bool = True):
        self._flag = threading.Event()
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not on the main thread (tests)

    def _handler(self, signum, frame):
        self._flag.set()

    def trigger(self) -> None:  # tests / manual drain
        self._flag.set()

    @property
    def should_exit(self) -> bool:
        return self._flag.is_set()
