"""Naive full-materialization attention oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """q: (B,S,H,hd) pre-scaled; k,v: (B,T,Hkv,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qr = q.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qr, k, preferred_element_type=jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)
    kpos = jnp.arange(T)
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window:
        ok &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return o.reshape(B, S, H, hd)
