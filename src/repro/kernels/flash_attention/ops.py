"""jit'd public wrapper for the flash-attention kernel.

Accepts the model's (B, S, H, hd) layout, flattens heads, pads sequence
lengths to block multiples, dispatches interpret-mode off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B,S,H,hd) pre-scaled; k,v: (B,T,Hkv,hd) → (B,S,H,hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]

    pad_s = (-S) % K.BLOCK_Q
    pad_t = (-T) % K.BLOCK_K
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, T, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, T, hd)
    if pad_s:
        qf = jnp.pad(qf, ((0, 0), (0, pad_s), (0, 0)))
    if pad_t:
        kf = jnp.pad(kf, ((0, 0), (0, pad_t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_t), (0, 0)))

    out = K.flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, softcap=softcap,
        kv_len=T, n_kv_heads=Hkv, interpret=interpret)
    out = out[:, :S].reshape(B, H, S, hd)
    return jnp.moveaxis(out, 1, 2)


__all__ = ["flash_attention", "flash_attention_ref"]
