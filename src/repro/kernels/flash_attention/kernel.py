"""Blocked flash attention (Pallas/TPU) with causal/local-window/softcap.

Grid (batch·q_heads, q_blocks, kv_blocks), kv innermost. Online-softmax
running stats (m, l) and the output accumulator live in VMEM scratch and are
finalized at the last kv block. GQA is expressed in the k/v BlockSpec index
maps (q head → kv head), so grouped heads share kv tiles without replication.

Block-level masking: kv blocks fully outside the causal/window band are
skipped with ``pl.when`` (no matmul, no DMA cost on TPU thanks to the
revisited output block) — the same skip idea the GAS kernel uses for
occupancy, applied to the attention band structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38
BLOCK_Q = 128
BLOCK_K = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 causal: bool, window: int, softcap: float, kv_len: int):
    qb, kb = pl.program_id(1), pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qb * BLOCK_Q
    k_start = kb * BLOCK_K
    # band check: does this kv block intersect the visible band of this q block?
    visible = jnp.bool_(True)
    if causal:
        visible &= k_start <= q_start + BLOCK_Q - 1
    if window:
        # newest query is q_start+BQ-1; oldest visible key is q_pos-window+1
        visible &= k_start + BLOCK_K - 1 > q_start - window

    @pl.when(visible)
    def _block():
        q = q_ref[0]                                    # (BQ, hd)
        k = k_ref[0]                                    # (BK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (BQ, BK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 1)
        ok = kpos < kv_len                              # padded tail keys
        if causal:
            ok &= qpos >= kpos
        if window:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                             # (BQ,)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kb == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "kv_len", "n_kv_heads", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, window: int, softcap: float,
                           kv_len: int, n_kv_heads: int,
                           interpret: bool = False) -> jax.Array:
    """q: (B·H, S, hd); k,v: (B·Hkv, T, hd); S,T multiples of block sizes.

    Head layout is flattened [b-major, h-minor]; q head i maps to kv head
    i // (H / Hkv) within its batch row.
    """
    BH, S, hd = q.shape
    BK_, T, _ = k.shape
    H = BH // (BK_ // n_kv_heads)
    G = H // n_kv_heads

    def kv_index(bh, qb, kb):
        b, h = bh // H, bh % H
        return (b * n_kv_heads + h // G, kb, 0)

    grid = (BH, S // BLOCK_Q, T // BLOCK_K)
    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, softcap=softcap, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, hd), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, BLOCK_K, hd), kv_index),
            pl.BlockSpec((1, BLOCK_K, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, hd), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, hd), jnp.float32),   # acc
            pltpu.VMEM((BLOCK_Q,), jnp.float32),      # m (running max)
            pltpu.VMEM((BLOCK_Q,), jnp.float32),      # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
