"""FAST-GAS scatter kernel (Pallas/TPU).

The paper's engine: CAM matches edge destinations against resident rows and
the match lines clock row-parallel updates in FAST SRAM; an idle-skip buffer
skips rounds with no match. TPU re-expression (DESIGN §2):

  * the accumulator row-block is the VMEM-resident "FAST SRAM" tile, pinned
    across the edge-tile grid dimension (BlockSpec index ignores ``e``);
  * the CAM match is an equality compare of the edge tile's dst ids against
    the row block's iota — producing the match-line matrix;
  * for sum-aggregation the match matrix is contracted with the value tile on
    the MXU (one-hot matmul): irregular scatter → dense matmul;
  * idle-skip is a per-(row-block × edge-tile) occupancy bitmap computed on
    the host side of the op; ``pl.when`` skips the whole round — compute AND
    the value-tile traffic — exactly the paper's clock-gating.

Grid: (row_blocks, feat_blocks, edge_tiles); edge innermost so the output
block is revisited (stays resident in VMEM while edges stream through).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# hardware-aligned tiles: rows/features on 128 (MXU dim), edges per round on
# 128 for the add path (matmul) and 32 for the compare-reduce max/min path.
ROW_BLOCK = 128
FEAT_BLOCK = 128
EDGE_TILE_ADD = 128
EDGE_TILE_CMP = 32


def _gas_add_kernel(occ_ref, dst_ref, val_ref, out_ref):
    r, e = pl.program_id(0), pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(occ_ref[0, 0] > 0)          # idle-skip: no CAM match → no round
    def _round():
        rel = dst_ref[...] - r * ROW_BLOCK               # (E,)
        rows = jax.lax.broadcasted_iota(jnp.int32, (ROW_BLOCK, rel.shape[0]), 0)
        match = (rows == rel[None, :]).astype(val_ref.dtype)   # CAM match lines
        # row-parallel update: one-hot contraction on the MXU
        out_ref[...] += jax.lax.dot(
            match, val_ref[...], preferred_element_type=out_ref.dtype)


def _gas_cmp_kernel(occ_ref, dst_ref, val_ref, out_ref, *, op: str):
    r, e = pl.program_id(0), pl.program_id(2)
    init = -jnp.inf if op == "max" else jnp.inf

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, init)

    @pl.when(occ_ref[0, 0] > 0)
    def _round():
        rel = dst_ref[...] - r * ROW_BLOCK
        rows = jax.lax.broadcasted_iota(jnp.int32, (ROW_BLOCK, rel.shape[0]), 0)
        match = rows == rel[None, :]                      # (R, E) bool
        contrib = jnp.where(match[..., None], val_ref[...][None, :, :], init)
        red = jnp.max(contrib, axis=1) if op == "max" else jnp.min(contrib, axis=1)
        cur = out_ref[...]
        out_ref[...] = jnp.maximum(cur, red) if op == "max" else jnp.minimum(cur, red)


@functools.partial(jax.jit, static_argnames=("n_rows", "op", "interpret"))
def gas_scatter_pallas(dst: jax.Array, values: jax.Array, occupancy: jax.Array,
                       n_rows: int, *, op: str = "add",
                       interpret: bool = False) -> jax.Array:
    """dst: (E,) int32 (pre-padded to tile multiple, dead rows ≥ n_rows_padded);
    values: (E, F) f32 (pre-padded); occupancy: (row_blocks, edge_tiles) int32.
    n_rows must be a multiple of ROW_BLOCK; F a multiple of FEAT_BLOCK."""
    E, F = values.shape
    et = EDGE_TILE_ADD if op == "add" else EDGE_TILE_CMP
    assert E % et == 0 and F % FEAT_BLOCK == 0 and n_rows % ROW_BLOCK == 0
    grid = (n_rows // ROW_BLOCK, F // FEAT_BLOCK, E // et)

    kernel = _gas_add_kernel if op == "add" else functools.partial(_gas_cmp_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda r, f, e: (r, e)),            # occupancy
            pl.BlockSpec((et,), lambda r, f, e: (e,)),               # dst ids
            pl.BlockSpec((et, FEAT_BLOCK), lambda r, f, e: (e, f)),  # values
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, FEAT_BLOCK), lambda r, f, e: (r, f)),
        out_shape=jax.ShapeDtypeStruct((n_rows, F), values.dtype),
        interpret=interpret,
    )(occupancy, dst, values)
