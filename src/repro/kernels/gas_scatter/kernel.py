"""FAST-GAS scatter kernel (Pallas/TPU).

The paper's engine: CAM matches edge destinations against resident rows and
the match lines clock row-parallel updates in FAST SRAM; an idle-skip buffer
skips rounds with no match. TPU re-expression (DESIGN §2):

  * the accumulator row-block is the VMEM-resident "FAST SRAM" tile, pinned
    across the edge-tile grid dimension (BlockSpec index ignores ``e``);
  * the CAM match is an equality compare of the edge tile's dst ids against
    the row block's iota — producing the match-line matrix;
  * for sum-aggregation the match matrix is contracted with the value tile on
    the MXU (one-hot matmul): irregular scatter → dense matmul. Edge weights
    fuse here for free: scaling the match lines by ``w`` BEFORE the
    contraction makes the same matmul compute the weighted scatter, so no
    ``values * weights`` edge-stream is ever materialized in HBM;
  * idle-skip is a per-(row-block × edge-tile) occupancy bitmap computed on
    the host side of the op; ``pl.when`` skips the whole round — compute AND
    the value-tile traffic — exactly the paper's clock-gating. The skip only
    pays off when edges arrive destination-binned (``ops.schedule_edges``):
    binned tiles touch one or two row blocks, so the bitmap is a thin band
    instead of dense.

Grid: (row_blocks, feat_blocks, edge_tiles); edge innermost so the output
block is revisited (stays resident in VMEM while edges stream through).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# hardware-aligned tiles: rows/features on 128 (MXU dim). Edges per round are
# 128 on every path now — the compare path used to cap at 32 because it
# materialized a full (R, E, F) select intermediate; it accumulates in
# CMP_CHUNK-wide slabs instead, so its VMEM peak is (R, CMP_CHUNK, F)
# regardless of the edge tile. EDGE_TILE_INTERPRET is the interpret-mode
# (CPU differential/benchmark tier) width — kept at the hardware value by
# default (a knob for tiling studies, not a divergence); note that on a
# binned stream the live rounds are ≤ T + row_blocks − 1 regardless of tile
# width (the staircase argument), so the scheduled walk's round count is
# tile-size-robust.
ROW_BLOCK = 128
FEAT_BLOCK = 128
EDGE_TILE = 128
EDGE_TILE_ADD = EDGE_TILE
EDGE_TILE_CMP = EDGE_TILE
EDGE_TILE_INTERPRET = 128
CMP_CHUNK = 32


def edge_tile(op: str, interpret: bool) -> int:
    """The edge-tile width a dispatch will use — schedules must be built
    with the same width (``ops.schedule_edges`` resolves it identically)."""
    if interpret:
        return EDGE_TILE_INTERPRET
    return EDGE_TILE_ADD if op == "add" else EDGE_TILE_CMP


def _add_round(rel, val_ref, out_ref, w=None):
    """One scatter-add round shared by all four add kernels: CAM match
    lines from the relative dst ids, optionally scaled by the edge weights
    (the fused form of ``values * weights[:, None]`` followed by the
    unweighted scatter), contracted with the value tile on the MXU."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (ROW_BLOCK, rel.shape[0]), 0)
    match = (rows == rel[None, :]).astype(val_ref.dtype)   # CAM match lines
    if w is not None:
        match = match * w[None, :].astype(val_ref.dtype)
    # row-parallel update: one-hot contraction on the MXU
    out_ref[...] += jax.lax.dot(
        match, val_ref[...], preferred_element_type=out_ref.dtype)


def _gas_add_kernel(occ_ref, dst_ref, val_ref, out_ref):
    r, e = pl.program_id(0), pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(occ_ref[0, 0] > 0)          # idle-skip: no CAM match → no round
    def _round():
        _add_round(dst_ref[...] - r * ROW_BLOCK, val_ref, out_ref)


def _gas_addw_kernel(occ_ref, dst_ref, w_ref, val_ref, out_ref):
    r, e = pl.program_id(0), pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _round():
        _add_round(dst_ref[...] - r * ROW_BLOCK, val_ref, out_ref,
                   w=w_ref[...])


def _cmp_round(rel, val, acc, *, op: str, chunk: int):
    """Select-on-match ACCUMULATION shared by both cmp kernels: the edge
    tile streams through ``chunk``-wide slabs, each slab's (R, chunk, F)
    select reduced into the running (R, F) extremum before the next slab
    loads — the full (R, E, F) ``contrib`` intermediate of the old kernel
    never exists, which is what lets the cmp edge tile sit at 128 (VMEM
    peak is (R, chunk, F) regardless of tile width). Interpret mode uses a
    single full-width slab: no VMEM to respect, fewer emulated ops."""
    init = -jnp.inf if op == "max" else jnp.inf
    rows = jax.lax.broadcasted_iota(jnp.int32, (ROW_BLOCK, chunk), 0)
    for c in range(rel.shape[0] // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        match = rows == rel[sl][None, :]                  # (R, C) match lines
        contrib = jnp.where(match[..., None], val[sl][None, :, :], init)
        red = (jnp.max(contrib, axis=1) if op == "max"
               else jnp.min(contrib, axis=1))
        acc = jnp.maximum(acc, red) if op == "max" else jnp.minimum(acc, red)
    return acc


def _gas_cmp_kernel(occ_ref, dst_ref, val_ref, out_ref, *, op: str,
                    chunk: int):
    r, e = pl.program_id(0), pl.program_id(2)
    init = -jnp.inf if op == "max" else jnp.inf

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, init)

    @pl.when(occ_ref[0, 0] > 0)
    def _round():
        rel = dst_ref[...] - r * ROW_BLOCK                # (E,)
        out_ref[...] = _cmp_round(rel, val_ref[...], out_ref[...],
                                  op=op, chunk=chunk)


# ---------------------------------------------------------------------------
# the banded (scheduled) walk: grid = each row block's own tile range
# ---------------------------------------------------------------------------
#
# With a destination-binned edge stream the live (row-block × edge-tile)
# pairs form a staircase of ≤ T + R - 1 cells. Instead of scanning the full
# R×T grid and ``pl.when``-skipping the idle cells (each skipped cell still
# pays a grid-step round), the scheduled dispatch walks ONLY the live band:
# a scalar-prefetch work list (W, 4) of [row_block, tile, live, init] rows
# drives data-dependent BlockSpec index maps — the paper's idle-skip buffer
# consumed as a work queue rather than a gate. Work items are ordered by
# row block, so the output block's revisits stay consecutive (the TPU
# revisiting contract); ``init`` marks the first visit of each row block
# (empty blocks get one init-only step so every output row is defined).

def _sched_live(wk_ref, w, feat_skip: bool):
    """Is this work item live for THIS feature block? Column 2 is the edge
    schedule's tile liveness; with ``feat_skip`` the work row additionally
    carries one occupancy flag per feature block (columns 4…4+nfb — the
    compressed-sparse metadata riding the same scalar-prefetch list), so an
    all-zero value block skips its round exactly like an idle tile.
    Skipping is exact for add: a zero block contributes the additive
    identity (and ``x + (-0.0) ≡ x``, so signed zeros can't leak)."""
    live = wk_ref[w, 2] == 1
    if feat_skip:
        live = jnp.logical_and(live, wk_ref[w, 4 + pl.program_id(0)] == 1)
    return live


def _sched_add_kernel(wk_ref, dst_ref, val_ref, out_ref, *,
                      feat_skip: bool = False):
    w = pl.program_id(1)

    @pl.when(wk_ref[w, 3] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(_sched_live(wk_ref, w, feat_skip))
    def _round():
        _add_round(dst_ref[...] - wk_ref[w, 0] * ROW_BLOCK, val_ref, out_ref)


def _sched_addw_kernel(wk_ref, dst_ref, w_ref, val_ref, out_ref, *,
                       feat_skip: bool = False):
    w = pl.program_id(1)

    @pl.when(wk_ref[w, 3] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(_sched_live(wk_ref, w, feat_skip))
    def _round():
        _add_round(dst_ref[...] - wk_ref[w, 0] * ROW_BLOCK, val_ref, out_ref,
                   w=w_ref[...])


def _sched_cmp_kernel(wk_ref, dst_ref, val_ref, out_ref, *, op: str,
                      chunk: int):
    w = pl.program_id(1)
    init = -jnp.inf if op == "max" else jnp.inf

    @pl.when(wk_ref[w, 3] == 1)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, init)

    @pl.when(wk_ref[w, 2] == 1)
    def _round():
        rel = dst_ref[...] - wk_ref[w, 0] * ROW_BLOCK
        out_ref[...] = _cmp_round(rel, val_ref[...], out_ref[...],
                                  op=op, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("n_rows", "op", "interpret"))
def gas_scatter_banded(work: jax.Array, dst: jax.Array, values: jax.Array,
                       n_rows: int, *, op: str = "add",
                       weights: jax.Array | None = None,
                       interpret: bool = False) -> jax.Array:
    """Scheduled FAST-GAS dispatch: the grid walks the live band only.

    work: (W, 4) int32 scalar-prefetch rows [row_block, tile, live, init],
    ordered by row_block (see ``ops.schedule_edges``); dst/values/weights as
    in ``gas_scatter_pallas`` and already destination-binned. An add-op
    work list may carry ``F // fb`` extra columns of per-(tile, feature
    block) value occupancy (``ops`` derives them from the value stream) —
    the kernel then skips all-zero feature blocks the way it skips idle
    tiles, so scheduled rounds track the values' ACTUAL nonzero blocks.
    """
    E, F = values.shape
    et = edge_tile(op, interpret)
    fb = F if interpret else FEAT_BLOCK
    assert E % et == 0 and F % fb == 0 and n_rows % ROW_BLOCK == 0
    grid = (F // fb, work.shape[0])
    feat_skip = work.shape[1] > 4
    assert work.shape[1] in (4, 4 + F // fb), work.shape

    in_specs = [pl.BlockSpec((et,), lambda f, w, wk: (wk[w, 1],))]   # dst
    operands = [dst]
    if op == "add":
        if weights is None:
            kernel = functools.partial(_sched_add_kernel,
                                       feat_skip=feat_skip)
        else:
            kernel = functools.partial(_sched_addw_kernel,
                                       feat_skip=feat_skip)
            in_specs.append(pl.BlockSpec((et,), lambda f, w, wk: (wk[w, 1],)))
            operands.append(weights)
    else:
        assert weights is None, "compare ops do not consume edge weights"
        kernel = functools.partial(_sched_cmp_kernel, op=op,
                                   chunk=et if interpret else CMP_CHUNK)
    in_specs.append(pl.BlockSpec((et, fb), lambda f, w, wk: (wk[w, 1], f)))
    operands.append(values)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ROW_BLOCK, fb), lambda f, w, wk: (wk[w, 0], f)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, F), values.dtype),
        interpret=interpret,
    )(work, *operands)


@functools.partial(jax.jit, static_argnames=("n_rows", "op", "interpret"))
def gas_scatter_pallas(dst: jax.Array, values: jax.Array, occupancy: jax.Array,
                       n_rows: int, *, op: str = "add",
                       weights: jax.Array | None = None,
                       interpret: bool = False) -> jax.Array:
    """dst: (E,) int32 (pre-padded to tile multiple, dead rows ≥ n_rows_padded);
    values: (E, F) f32 (pre-padded); occupancy: (row_blocks, edge_tiles) int32;
    weights: optional (E,) edge weights fused into the add path's match lines
    (compare ops never consume weights — pass None).
    n_rows must be a multiple of ROW_BLOCK; F a multiple of FEAT_BLOCK."""
    E, F = values.shape
    et = edge_tile(op, interpret)
    # feature block: the 128-lane MXU tile on hardware; in interpret mode
    # (CPU differential tier) there is no lane constraint, so one block spans
    # the whole (8-aligned) width — lane-padding a narrow F to 128 would
    # multiply every round's slice/accumulate traffic by 128/F for nothing.
    fb = F if interpret else FEAT_BLOCK
    assert E % et == 0 and F % fb == 0 and n_rows % ROW_BLOCK == 0
    grid = (n_rows // ROW_BLOCK, F // fb, E // et)

    in_specs = [
        pl.BlockSpec((1, 1), lambda r, f, e: (r, e)),            # occupancy
        pl.BlockSpec((et,), lambda r, f, e: (e,)),               # dst ids
    ]
    operands = [occupancy, dst]
    if op == "add":
        if weights is None:
            kernel = _gas_add_kernel
        else:
            kernel = _gas_addw_kernel
            in_specs.append(pl.BlockSpec((et,), lambda r, f, e: (e,)))  # w
            operands.append(weights)
    else:
        assert weights is None, "compare ops do not consume edge weights"
        kernel = functools.partial(_gas_cmp_kernel, op=op,
                                   chunk=et if interpret else CMP_CHUNK)
    in_specs.append(pl.BlockSpec((et, fb), lambda r, f, e: (e, f)))
    operands.append(values)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ROW_BLOCK, fb), lambda r, f, e: (r, f)),
        out_shape=jax.ShapeDtypeStruct((n_rows, F), values.dtype),
        interpret=interpret,
    )(*operands)
