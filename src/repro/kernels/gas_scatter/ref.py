"""Pure-jnp oracles for the FAST-GAS scatter kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gas_scatter_ref(dst: jax.Array, values: jax.Array, n_rows: int, *,
                    op: str = "add") -> jax.Array:
    """dst: (E,) int32 row ids; values: (E, F). Returns (n_rows, F).

    Out-of-range dst (e.g. the dead-row convention) contribute nothing.
    max/min leave ∓inf in untouched rows (mask with a count if needed).
    """
    ok = (dst >= 0) & (dst < n_rows)
    safe = jnp.where(ok, dst, n_rows)
    if op == "add":
        vals = jnp.where(ok[:, None], values, 0)
        return jax.ops.segment_sum(vals, safe, num_segments=n_rows + 1)[:n_rows]
    if op == "max":
        vals = jnp.where(ok[:, None], values, -jnp.inf)
        return jax.ops.segment_max(vals, safe, num_segments=n_rows + 1)[:n_rows]
    if op == "min":
        vals = jnp.where(ok[:, None], values, jnp.inf)
        return jax.ops.segment_min(vals, safe, num_segments=n_rows + 1)[:n_rows]
    raise ValueError(op)


def gas_scatter_weighted_ref(dst: jax.Array, values: jax.Array,
                             weights: Optional[jax.Array],
                             mask: Optional[jax.Array], n_rows: int, *,
                             op: str = "add") -> jax.Array:
    """Oracle for ``ops.gas_scatter_fused``: masked, weighted scatter-reduce.

    Weights scale contributions only under ``op="add"`` (compare ops take
    the raw value); masked edges contribute nothing on any op.
    """
    ok = (dst >= 0) & (dst < n_rows)
    if mask is not None:
        ok = ok & mask
    if op == "add" and weights is not None:
        values = values * weights[:, None].astype(values.dtype)
    return gas_scatter_ref(jnp.where(ok, dst, n_rows), values, n_rows, op=op)
