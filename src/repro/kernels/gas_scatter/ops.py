"""jit'd public wrappers for the FAST-GAS scatter kernel.

Three layers:

* ``schedule_edges`` — the locality pass (paper Fig 11(c)): a stable
  counting-sort of the edge stream by destination row block. Binned edges
  make each edge tile touch only one or two row blocks, so the idle-skip
  occupancy map collapses from an arbitrary bitmap to a thin band described
  by per-tile (min, max) block bounds — and ``pl.when`` actually skips.
* ``occupancy_map`` — the unscheduled fallback's exact bitmap, computed by a
  bincount over (block, tile) pairs: O(E + R·T), replacing the old
  O(R·T·edge_tile) broadcast-compare that was re-traced per shard.
* ``gas_scatter`` / ``gas_scatter_fused`` — padding + dispatch. The fused
  entry takes mask and edge weights INTO the kernel (mask via the dead-row
  convention, weights via match-line scaling), so no ``values * weights`` or
  mask-fill edge stream is ever staged as a full E×F array in HBM.
"""

from __future__ import annotations

import contextlib
import functools
from collections import Counter
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gas_scatter import kernel as K
from repro.kernels.gas_scatter.ref import gas_scatter_ref


# ---------------------------------------------------------------------------
# dispatch counting (the deterministic "how many kernel calls" view)
# ---------------------------------------------------------------------------

_DISPATCH_COUNTS: Optional[Counter] = None


@contextlib.contextmanager
def count_dispatches():
    """Count GAS dispatches at TRACE time while the context is active.

    The public wrappers below tick a shared counter from plain (un-jit'd)
    Python before entering their jitted bodies, so every *dispatch site* is
    counted exactly once per trace — immune to jit caching of the inner
    functions and to XLA's combiner/DCE passes. Trace the program under
    test inside the context (``jax.make_jaxpr(fn)(*args)``, or an eager
    call) and read the Counter:

        with count_dispatches() as counts:
            jax.make_jaxpr(jax.grad(loss))(x)
        assert counts["kernel_scatter"] == 1

    Keys ticked here: ``kernel_scatter`` (one per pallas scatter dispatch —
    plain or fused). ``repro.core.gas`` ticks the engine-level keys
    ``find`` (table gathers) and ``reduce`` (weighted scatter reductions,
    either backend) into the same counter. Like jaxpr counting, a scan body
    counts once, not once per iteration. Contexts nest: the innermost
    counter receives the ticks.
    """
    global _DISPATCH_COUNTS
    prev = _DISPATCH_COUNTS
    _DISPATCH_COUNTS = Counter()
    try:
        yield _DISPATCH_COUNTS
    finally:
        _DISPATCH_COUNTS = prev


def _tick(kind: str) -> None:
    if _DISPATCH_COUNTS is not None:
        _DISPATCH_COUNTS[kind] += 1


def _pad_to(x: jax.Array, mult: int, axis: int, fill):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _feat_mult(interpret: bool) -> int:
    """Feature-axis padding granule: 128 lanes on hardware; 8 in interpret
    mode, where the kernel runs a single full-width feature block and
    lane-padding a narrow F to 128 would inflate every round's traffic."""
    return 8 if interpret else K.FEAT_BLOCK


# ---------------------------------------------------------------------------
# the edge schedule: destination-binned order + banded idle-skip bounds
# ---------------------------------------------------------------------------

class EdgeSchedule(NamedTuple):
    """Destination-binned edge schedule for one (partition, batch).

    ``perm`` reorders the edge stream so destinations ascend by row block
    (stable within a block, so intra-block edge order is preserved); dead
    edges (masked / out-of-range) sort to the end. ``blk_min``/``blk_max``
    are the per-edge-tile live row-block bounds of the PERMUTED, tile-padded
    stream — the banded form of the idle-skip buffer: tile ``t`` can only
    match row blocks in ``[blk_min[t], blk_max[t]]`` (``blk_max < blk_min``
    marks an all-dead tile). ``work`` is those bounds compiled into the
    kernel's walk order — (W, 4) rows of [row_block, tile, live, init],
    W = T + 2·row_blocks statically, covering every live (row-block, tile)
    pair exactly once plus one init-only row per empty block — so the
    scheduled grid iterates each row block's own tile range instead of
    R×T. Computed once per (partition, batch) and reused across layers,
    feature blocks, and the backward pass.
    """
    perm: jax.Array      # (E,) int32
    blk_min: jax.Array   # (T,) int32; T = tile-padded E // EDGE_TILE
    blk_max: jax.Array   # (T,) int32; -1 on all-dead tiles
    work: jax.Array      # (W, 4) int32 [row_block, tile, live, init]


def _edge_bins(dst: jax.Array, mask: Optional[jax.Array], n_rows: int):
    """Row-block bin per edge; dead edges get the one-past-the-end bin."""
    n_blocks = -(-n_rows // K.ROW_BLOCK)
    ok = (dst >= 0) & (dst < n_rows)
    if mask is not None:
        ok = ok & mask
    bins = jnp.where(ok, dst // K.ROW_BLOCK, n_blocks)
    return bins.astype(jnp.int32), n_blocks


def _tile_bounds(bins: jax.Array, n_blocks: int, edge_tile: int):
    """Per-tile (min, max) live block of a (padded) bin stream."""
    t = _pad_to(bins, edge_tile, 0, n_blocks).reshape(-1, edge_tile)
    live = t < n_blocks
    blk_min = jnp.min(jnp.where(live, t, n_blocks), axis=1).astype(jnp.int32)
    blk_max = jnp.max(jnp.where(live, t, -1), axis=1).astype(jnp.int32)
    return blk_min, blk_max


def _work_list(blk_min: jax.Array, blk_max: jax.Array,
               n_blocks: int) -> jax.Array:
    """Compile per-tile band bounds into the banded kernel's walk order.

    Returns (W, 4) int32 rows [row_block, tile, live, init] ordered by row
    block (output revisits stay consecutive), where each row block's run is
    its own contiguous tile range. W = T + 2·n_blocks is a static bound: on
    a binned stream the live pairs form a staircase (Σ spans ≤ T + n_blocks
    − 1) and each empty row block adds one init-only row. Trailing rows are
    dead filler pinned to the last block.
    """
    T = blk_min.shape[0]
    W = T + 2 * n_blocks
    dead = blk_max < 0
    # monotone envelopes: interior all-dead tiles (possible on
    # assume_sorted streams with interleaved masks) inherit neighbor
    # bounds, restoring the ascending order searchsorted needs — visiting
    # such a tile is a zero-match no-op, never a miss
    hi_env = jax.lax.cummax(jnp.where(dead, -1, blk_max))
    lo_env = jax.lax.cummin(
        jnp.where(dead, n_blocks, blk_min)[::-1])[::-1]
    r = jnp.arange(n_blocks, dtype=jnp.int32)
    t_lo = jnp.searchsorted(hi_env, r, side="left")      # first tile ∋ r
    t_hi = jnp.maximum(jnp.searchsorted(lo_env, r, side="right"), t_lo)
    cnt = jnp.maximum(t_hi - t_lo, 1)                    # empty block: init
    offs = jnp.concatenate([jnp.zeros((1,), cnt.dtype), jnp.cumsum(cnt)])
    w = jnp.arange(W)
    rb = jnp.searchsorted(offs[1:], w, side="right")     # block of step w
    rb_c = jnp.minimum(rb, n_blocks - 1)
    j = w - offs[rb_c]
    tile = jnp.clip(t_lo[rb_c] + j, 0, T - 1)
    live = (rb < n_blocks) & (j < (t_hi - t_lo)[rb_c])
    init = (rb < n_blocks) & (j == 0)
    return jnp.stack(
        [rb_c, tile, live.astype(jnp.int32), init.astype(jnp.int32)],
        axis=1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("n_rows", "edge_tile", "assume_sorted"))
def schedule_edges(dst: jax.Array, mask: Optional[jax.Array], n_rows: int, *,
                   edge_tile: Optional[int] = None,
                   assume_sorted: bool = False) -> EdgeSchedule:
    """Bin the edge stream by destination row block (stable counting sort).

    ``dst``: (E,) destination rows in ``[0, n_rows)``; entries that are
    masked or out of range are treated as dead and sort last. The sort key
    is ``dst // ROW_BLOCK`` only, so edges of one block keep their relative
    order (the gather stream stays as sequential as the input allows).

    ``assume_sorted=True`` skips the sort (``perm`` is the identity) and
    only derives the banded bounds — for streams that are binned by
    construction, e.g. the sampled path's ``repeat(arange(R), K)`` seeds.

    ``edge_tile`` defaults to the width the kernel dispatch on this backend
    will use (``kernel.edge_tile``) — pass it explicitly only to study
    other tilings.
    """
    if edge_tile is None:
        interp = jax.default_backend() != "tpu"
        edge_tile = K.edge_tile("add", interp)
        # the schedule is op-independent, so its default width is only safe
        # while every op dispatches the same tile; fail loudly if the cmp
        # width is ever re-split from the add width (it was 32 before)
        assert edge_tile == K.edge_tile("max", interp), (
            "add/cmp edge tiles diverged — schedule_edges needs an explicit "
            "edge_tile per op")
    bins, n_blocks = _edge_bins(dst, mask, n_rows)
    iota = jnp.arange(dst.shape[0], dtype=jnp.int32)
    if assume_sorted:
        sorted_bins, perm = bins, iota
    else:
        sorted_bins, perm = jax.lax.sort((bins, iota), num_keys=1,
                                         is_stable=True)
    blk_min, blk_max = _tile_bounds(sorted_bins, n_blocks, edge_tile)
    return EdgeSchedule(perm, blk_min, blk_max,
                        _work_list(blk_min, blk_max, n_blocks))


def schedule_skip_stats(sched: EdgeSchedule):
    """(live_rounds, total_rounds) of a schedule — how many (row-block ×
    edge-tile) rounds the banded walk executes vs the dense R×T grid. The
    difference is the idle-skip win (paper Fig 11(c)), measurable without
    running the kernel."""
    n_blocks = int(sched.work[:, 0].max()) + 1
    total = n_blocks * sched.blk_min.shape[0]
    return int(sched.work[:, 2].sum()), total


def dense_skip_stats(dst: jax.Array, mask: Optional[jax.Array],
                     n_rows: int):
    """(live_rounds, total_rounds) of the UNSCHEDULED dense grid for the
    same edge stream — the dead-row routing and tile padding reproduce
    exactly what ``gas_scatter_fused`` dispatches without a schedule, so
    benchmarks and tests count the grid the kernel actually runs."""
    et = K.edge_tile("add", jax.default_backend() != "tpu")
    R = ((n_rows + K.ROW_BLOCK - 1) // K.ROW_BLOCK) * K.ROW_BLOCK
    ok = (dst >= 0) & (dst < n_rows)
    if mask is not None:
        ok = ok & mask
    dstp = _pad_to(jnp.where(ok, dst, R), et, 0, R)
    occ = occupancy_map(dstp, R // K.ROW_BLOCK, et)
    return int(occ.sum()), int(occ.size)


def occupancy_map(dst: jax.Array, n_row_blocks: int, edge_tile: int) -> jax.Array:
    """(row_blocks, edge_tiles) int32: does edge tile e touch row block r?

    This is the idle-skip buffer content (paper Fig 11(c)) for an UNBINNED
    edge stream — computed once per (graph partition, batch) and reused
    across feature blocks. One bincount over (block, tile) pairs:
    O(E + R·T), never the O(R·T·edge_tile) dense compare.
    """
    E = dst.shape[0]
    T = E // edge_tile
    blk = dst // K.ROW_BLOCK
    dead = (blk < 0) | (blk >= n_row_blocks)
    idx = jnp.where(dead, n_row_blocks, blk)                 # overflow bin
    flat = idx * T + jnp.arange(E, dtype=dst.dtype) // edge_tile
    counts = jnp.zeros(((n_row_blocks + 1) * T,), jnp.int32).at[flat].add(1)
    return (counts[: n_row_blocks * T].reshape(n_row_blocks, T) > 0
            ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# dispatch wrappers
# ---------------------------------------------------------------------------

def gas_scatter(dst: jax.Array, values: jax.Array, n_rows: int, *,
                op: str = "add", interpret: bool | None = None) -> jax.Array:
    """Scatter-reduce ``values`` (E, F) into (n_rows, F) by ``dst`` (E,).

    Matches ``ref.gas_scatter_ref`` exactly (out-of-range dst ignored).
    One public call = one kernel dispatch (the or/1-D rewrites happen
    inside), ticked into ``count_dispatches``.
    """
    _tick("kernel_scatter")
    return _gas_scatter_jit(dst, values, n_rows, op=op, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_rows", "op", "interpret"))
def _gas_scatter_jit(dst: jax.Array, values: jax.Array, n_rows: int, *,
                     op: str = "add",
                     interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if op == "or":
        # boolean-or over {0,1} = max with an or-identity of 0 for empty
        # rows. The dtype rewrite happens exactly ONCE, before the ndim
        # dispatch: rewriting after the 1-D recursion re-entered the public
        # wrapper with op="or" still set, sending 1-D int values through the
        # float32 max round-trip at both recursion depths.
        out = _gas_scatter_jit(dst, values.astype(jnp.float32), n_rows,
                               op="max", interpret=interpret)
        return jnp.maximum(out, 0).astype(values.dtype)
    if values.ndim == 1:
        return _gas_scatter_jit(dst, values[:, None], n_rows, op=op,
                                interpret=interpret)[:, 0]

    E, F = values.shape
    et = K.edge_tile(op, interpret)
    R = ((n_rows + K.ROW_BLOCK - 1) // K.ROW_BLOCK) * K.ROW_BLOCK

    # dead-row padding: invalid/padded edges target row R (outside all blocks)
    ok = (dst >= 0) & (dst < n_rows)
    dstp = jnp.where(ok, dst, R)
    dstp = _pad_to(dstp, et, 0, R)
    fill = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}[op]
    valp = jnp.where(ok[:, None], values, fill)
    valp = _pad_to(valp, et, 0, fill)
    valp = _pad_to(valp, _feat_mult(interpret), 1, fill)

    occ = occupancy_map(dstp, R // K.ROW_BLOCK, et)
    out = K.gas_scatter_pallas(dstp, valp, occ, R, op=op, interpret=interpret)
    return out[:n_rows, :F]


def gas_scatter_fused(dst: jax.Array, values: jax.Array,
                      weights: Optional[jax.Array], mask: Optional[jax.Array],
                      n_rows: int, *, op: str = "add", schedule=None,
                      interpret: bool | None = None) -> jax.Array:
    """Masked, weighted scatter-reduce in ONE kernel dispatch.

    The paper's aggregation atom without the XLA staging: the mask folds
    into the dead-row convention (a masked edge's dst becomes the padded
    row block past the end, so its CAM match lines are all zero — its value
    is never filled, only never matched), and for ``op="add"`` the weights
    ride into the kernel and scale the match lines before the MXU
    contraction. Compare ops ignore ``weights`` (pass None). ``values`` at
    masked positions must be finite (they are zero-matched, not replaced —
    a NaN times a zero match line would still poison a sum).

    ``schedule``: an ``EdgeSchedule`` — its ``work`` list swaps the dense
    R×T grid for the banded walk (each row block iterates only its own tile
    range; idle rounds are never even visited). The CALLER guarantees
    ``dst``/``values``/``weights``/``mask`` are already in ``schedule.perm``
    order — this wrapper never permutes (the dataflow permutes the edge
    LIST once, so gathered values arrive binned for free).

    One public call = one kernel dispatch, ticked into
    ``count_dispatches``.
    """
    _tick("kernel_scatter")
    return _gas_scatter_fused_jit(dst, values, weights, mask, n_rows, op=op,
                                  schedule=schedule, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_rows", "op", "interpret"))
def _gas_scatter_fused_jit(dst: jax.Array, values: jax.Array,
                           weights: Optional[jax.Array],
                           mask: Optional[jax.Array],
                           n_rows: int, *, op: str = "add", schedule=None,
                           interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert op in ("add", "max", "min"), op
    if values.ndim == 1:
        return _gas_scatter_fused_jit(dst, values[:, None], weights, mask,
                                      n_rows, op=op, schedule=schedule,
                                      interpret=interpret)[:, 0]

    E, F = values.shape
    et = K.edge_tile(op, interpret)
    R = ((n_rows + K.ROW_BLOCK - 1) // K.ROW_BLOCK) * K.ROW_BLOCK

    ok = (dst >= 0) & (dst < n_rows)
    if mask is not None:
        ok = ok & mask
    dstp = _pad_to(jnp.where(ok, dst, R), et, 0, R)
    valp = _pad_to(_pad_to(values, et, 0, 0), _feat_mult(interpret), 1, 0)
    wp = None
    if op == "add" and weights is not None:
        wp = _pad_to(weights, et, 0, 0)

    n_blocks = R // K.ROW_BLOCK
    if schedule is None:
        occ = occupancy_map(dstp, n_blocks, et)
        out = K.gas_scatter_pallas(dstp, valp, occ, R, op=op, weights=wp,
                                   interpret=interpret)
    else:
        T = dstp.shape[0] // et
        assert schedule.blk_min.shape[0] == T, (
            f"schedule has {schedule.blk_min.shape[0]} tile bounds but the "
            f"padded edge stream has {T} tiles — was the schedule built for "
            f"a different edge count or tile size?")
        assert schedule.work.shape[0] == T + 2 * n_blocks, (
            f"schedule work list sized for a different row space: "
            f"{schedule.work.shape[0]} != {T} + 2·{n_blocks}")
        work = schedule.work
        if op == "add":
            # feature-block liveness rides the work list: per (edge tile ×
            # feature block) value occupancy, gathered onto each work row by
            # its tile index. The kernel then skips all-zero feature blocks
            # exactly like idle tiles — safe for add only (zero is its
            # identity and x + (-0.0) ≡ x, so skipping a zero block is
            # bit-exact). Derived from the value STREAM at dispatch time, so
            # sparse gathers (repro.core.sparse) shrink the round count with
            # no schedule or VJP changes — the backward pass re-derives it
            # from the fresh cotangent values.
            work = jnp.concatenate(
                [work, _feat_liveness(valp, work[:, 1], et, interpret)],
                axis=1)
        out = K.gas_scatter_banded(work, dstp, valp, R, op=op,
                                   weights=wp, interpret=interpret)
    return out[:n_rows, :F]


def _feat_liveness(valp: jax.Array, tiles: jax.Array, et: int,
                   interpret: bool) -> jax.Array:
    """(W, F//fb) int32: does work row w's edge tile have any nonzero value
    in feature block f? ``valp`` is the tile- and feature-padded value
    stream the kernel consumes."""
    T, Fp = valp.shape[0] // et, valp.shape[1]
    fb = Fp if interpret else K.FEAT_BLOCK
    tile_live = (valp.reshape(T, et, Fp // fb, fb) != 0).any(axis=(1, 3))
    return jnp.take(tile_live.astype(jnp.int32), tiles, axis=0)


def feat_skip_stats(schedule: EdgeSchedule, values: jax.Array, *,
                    interpret: bool | None = None):
    """(live_rounds, band_rounds) of a scheduled add dispatch over these
    values — how many (row-block × edge-tile × feature-block) rounds the
    feature-skipping walk executes vs the banded walk without value
    occupancy (band rounds × feature blocks). The gap is the compressed-
    sparse win one level below the byte counters: rounds scale with the
    values' measured block density. Counted, not clocked."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    et = K.edge_tile("add", interpret)
    valp = _pad_to(_pad_to(values, et, 0, 0), _feat_mult(interpret), 1, 0)
    feat = _feat_liveness(valp, schedule.work[:, 1], et, interpret)
    live = schedule.work[:, 2] == 1
    return (int((feat * live[:, None].astype(jnp.int32)).sum()),
            int(live.sum()) * feat.shape[1])


__all__ = ["EdgeSchedule", "count_dispatches", "dense_skip_stats",
           "feat_skip_stats", "gas_scatter", "gas_scatter_fused",
           "gas_scatter_ref", "occupancy_map", "schedule_edges",
           "schedule_skip_stats"]
