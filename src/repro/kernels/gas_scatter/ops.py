"""jit'd public wrapper for the FAST-GAS scatter kernel.

Handles padding to hardware tiles, builds the idle-skip occupancy bitmap, and
dispatches: Pallas (TPU, or interpret-mode on CPU) vs the jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gas_scatter import kernel as K
from repro.kernels.gas_scatter.ref import gas_scatter_ref


def _pad_to(x: jax.Array, mult: int, axis: int, fill):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def occupancy_map(dst: jax.Array, n_row_blocks: int, edge_tile: int) -> jax.Array:
    """(row_blocks, edge_tiles) int32: does edge tile e touch row block r?

    This is the idle-skip buffer content (paper Fig 11(c)) — computed once
    per (graph partition, batch) and reused across feature blocks.
    """
    E = dst.shape[0]
    tiles = dst.reshape(E // edge_tile, edge_tile)
    blk = tiles // K.ROW_BLOCK                                  # (T, et)
    r = jnp.arange(n_row_blocks, dtype=jnp.int32)
    hit = (blk[None, :, :] == r[:, None, None]).any(-1)         # (R, T)
    return hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_rows", "op", "interpret"))
def gas_scatter(dst: jax.Array, values: jax.Array, n_rows: int, *,
                op: str = "add", interpret: bool | None = None) -> jax.Array:
    """Scatter-reduce ``values`` (E, F) into (n_rows, F) by ``dst`` (E,).

    Matches ``ref.gas_scatter_ref`` exactly (out-of-range dst ignored).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if op == "or":
        # boolean-or over {0,1} = max with an or-identity of 0 for empty
        # rows. The dtype rewrite happens exactly ONCE, before the ndim
        # dispatch: rewriting after the 1-D recursion re-entered the public
        # wrapper with op="or" still set, sending 1-D int values through the
        # float32 max round-trip at both recursion depths.
        out = gas_scatter(dst, values.astype(jnp.float32), n_rows, op="max",
                          interpret=interpret)
        return jnp.maximum(out, 0).astype(values.dtype)
    if values.ndim == 1:
        return gas_scatter(dst, values[:, None], n_rows, op=op,
                           interpret=interpret)[:, 0]

    E, F = values.shape
    et = K.EDGE_TILE_ADD if op == "add" else K.EDGE_TILE_CMP
    R = ((n_rows + K.ROW_BLOCK - 1) // K.ROW_BLOCK) * K.ROW_BLOCK

    # dead-row padding: invalid/padded edges target row R (outside all blocks)
    ok = (dst >= 0) & (dst < n_rows)
    dstp = jnp.where(ok, dst, R)
    dstp = _pad_to(dstp, et, 0, R)
    fill = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}[op]
    valp = jnp.where(ok[:, None], values, fill)
    valp = _pad_to(valp, et, 0, fill)
    valp = _pad_to(valp, K.FEAT_BLOCK, 1, fill)

    occ = occupancy_map(dstp, R // K.ROW_BLOCK, et)
    out = K.gas_scatter_pallas(dstp, valp, occ, R, op=op, interpret=interpret)
    return out[:n_rows, :F]


__all__ = ["gas_scatter", "gas_scatter_ref", "occupancy_map"]
