from repro.kernels.gas_scatter import ops, ref
from repro.kernels.gas_scatter.ops import gas_scatter, occupancy_map
from repro.kernels.gas_scatter.ref import gas_scatter_ref

__all__ = ["ops", "ref", "gas_scatter", "occupancy_map", "gas_scatter_ref"]
