from repro.kernels.gas_scatter import ops, ref
from repro.kernels.gas_scatter.ops import (EdgeSchedule, count_dispatches,
                                           dense_skip_stats,
                                           gas_scatter, gas_scatter_fused,
                                           occupancy_map, schedule_edges,
                                           schedule_skip_stats)
from repro.kernels.gas_scatter.ref import (gas_scatter_ref,
                                           gas_scatter_weighted_ref)

__all__ = ["EdgeSchedule", "count_dispatches", "dense_skip_stats", "ops",
           "ref", "gas_scatter", "gas_scatter_fused",
           "gas_scatter_ref", "gas_scatter_weighted_ref", "occupancy_map",
           "schedule_edges", "schedule_skip_stats"]
