"""Roofline term extraction from compiled (dry-run) artifacts.

Terms (per DESIGN §8; seconds, per device — post-SPMD HLO is per-device):

  T_compute = flops / peak_bf16        (197 TFLOP/s)
  T_memory  = bytes_accessed / hbm_bw  (819 GB/s)
  T_coll    = collective_bytes / link  (50 GB/s per ICI link)

``cost_analysis()`` provides flops + bytes accessed. Collective bytes are NOT
in cost_analysis: we parse the optimized HLO and sum, per collective op, the
max of (operand bytes, result bytes) — the ring-serialized wire volume is
within 2×(n-1)/n of that for every collective family, and the convention is
applied uniformly to every case (what matters for the perf loop is the
delta).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

from repro.common.hw import V5E, ChipSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: {count, bytes} summed over ops (per device).

    For each collective instruction line, bytes = max(sum of operand shape
    bytes, sum of result shape bytes).
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        m = re.match(r"\s*(?:ROOT\s+)?%?([a-zA-Z0-9_.-]+)", lhs)
        if not m:
            continue
        kind = None
        rhs_stripped = rhs.lstrip()
        # result shapes come first in rhs, then "op-name(operands...)"
        opm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                        r"collective-permute)(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        kind = opm.group(1)
        if "-done(" in rhs:   # avoid double counting start/done pairs
            continue
        paren = rhs.index("(", opm.start())
        result_part = rhs[:opm.start()]
        operand_part = rhs[paren:]
        res_bytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_part))
        opd_bytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operand_part))
        out[kind]["count"] += 1
        out[kind]["bytes"] += float(max(res_bytes, opd_bytes))
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HBM traffic
    collective_bytes: float      # per-device wire bytes
    collectives: Dict[str, Dict[str, float]]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float = 0.0     # 6·N·D (train) / 2·N·D (fwd) per device
    useful_ratio: float = 0.0    # model_flops / HLO flops

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(flops: float, bytes_accessed: float,
                   collectives: Dict[str, Dict[str, float]],
                   *, chip: ChipSpec = V5E,
                   model_flops: float = 0.0) -> RooflineTerms:
    cbytes = sum(v["bytes"] for v in collectives.values())
    tc = flops / chip.peak_flops_bf16
    tm = bytes_accessed / chip.hbm_bw
    tl = cbytes / chip.ici_link_bw
    dom = max((tc, "compute"), (tm, "memory"), (tl, "collective"))[1]
    return RooflineTerms(
        flops=flops, bytes_accessed=bytes_accessed, collective_bytes=cbytes,
        collectives=collectives, t_compute=tc, t_memory=tm, t_collective=tl,
        dominant=dom, model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0)


def model_flops_estimate(n_params: int, n_active_params: int, shape_kind: str,
                         tokens_per_device: float) -> float:
    """6·N·D (train) or 2·N·D (fwd/decode) using ACTIVE params for MoE."""
    n = n_active_params or n_params
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens_per_device


def active_params(cfg, n_params: int) -> int:
    """Approximate active-per-token params for MoE archs (top-k + shared)."""
    if cfg.n_experts:
        expert = 3 * cfg.d_model * cfg.d_ff
        moe_layers = sum(1 for k in cfg.layer_kinds() if k == "moe")
        routed_total = moe_layers * cfg.n_experts * expert
        routed_active = moe_layers * cfg.top_k * expert
        return n_params - routed_total + routed_active
    return n_params
