"""Dry-run case assembly: (arch × shape × mesh) → step fn + structs + shardings.

``input_specs`` follows the shannon/kernels pattern: ShapeDtypeStruct stand-ins
for every input — weak-type-correct, shardable, zero device allocation.

Per-shape logical rule overrides:
  * long_500k (global_batch=1): "batch" resolves to no axis; the KV-cache
    sequence dim ("seq_shard") takes ("pod","data") — 32-way sequence
    parallelism so the 524288-token cache fits per chip.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.common.config import SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.common.logical import DEFAULT_RULES, to_physical
from repro.common.schema import param_logical_specs, param_structs
from repro.train import step as S

LONG_CONTEXT_RULES = dict(
    DEFAULT_RULES,
    batch=(),                      # B=1: nothing to shard
    seq_shard=("pod", "data"),     # SP over the full fleet
)

# Per-arch gradient-accumulation for train_4k: keeps per-microbatch
# activations (stored once per remat block) within v5e HBM. Verified via
# compiled.memory_analysis() in the dry-run.
TRAIN_MICROBATCHES = {
    "llama-3.2-vision-90b": 8,
    "gemma2-2b": 2,
    "recurrentgemma-2b": 2,
    "phi3-medium-14b": 4,
    "gemma3-12b": 4,   # §Perf G1: 24.6 GB → fit
    "moonshot-v1-16b-a3b": 2,
    "deepseek-moe-16b": 2,
    "mamba2-780m": 4,
}


# §Perf C1 (REFUTED, reverted): disabling FSDP for small models predicted a
# ~23% collective cut (attributing the per-layer all-gathers to FSDP weight
# gathers); measured −2.5% only — the gathers are model-axis attention weight
# gathers inherent to replicated-attention small-head archs, not FSDP. FSDP
# stays on uniformly (it also carries the long_500k table sharding).


def rules_for(shape: ShapeConfig, cfg: ModelConfig = None) -> dict:
    return LONG_CONTEXT_RULES if shape.name == "long_500k" else DEFAULT_RULES


@dataclasses.dataclass
class DryRunCase:
    arch: str
    shape: str
    fn: Callable                   # positional-args step function
    arg_structs: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate: Tuple[int, ...] = ()
    out_shardings: Any = None      # None → let GSPMD infer


def _shardings(tree_specs, mesh: Mesh, rules: dict):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, to_physical(s, mesh, rules)),
        tree_specs,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))


def build_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               tc: Optional[TrainConfig] = None) -> DryRunCase:
    if tc is None:
        tc = TrainConfig(microbatches=TRAIN_MICROBATCHES.get(cfg.name, 1))
    rules = rules_for(shape, cfg)
    max_seq = shape.seq_len if cfg.is_encoder_decoder else 0

    if shape.kind == "train":
        schema = S.state_schema(cfg, tc, max_seq=max_seq)
        state_structs = param_structs(schema)
        state_shard = _shardings(param_logical_specs(schema), mesh, rules)
        b_structs = S.batch_structs(cfg, shape)
        b_shard = _shardings(S.batch_logical_specs(cfg), mesh, rules)
        fn = S.make_train_step(cfg, tc, mesh=mesh,
                               param_shardings=state_shard["params"])
        return DryRunCase(cfg.name, shape.name, fn,
                          (state_structs, b_structs),
                          (state_shard, b_shard), donate=(0,))

    # serving lowers with bf16 params (production deployment dtype)
    import jax.numpy as jnp
    from repro.common.schema import tree_map_defs
    raw = S.T.model_schema(cfg, max_seq=max_seq)
    bf16 = tree_map_defs(
        lambda d: dataclasses.replace(d, dtype=jnp.bfloat16)
        if d.dtype == jnp.float32 else d, raw)
    pschema = {"params": bf16}
    p_structs = param_structs(pschema)["params"]
    p_shard = _shardings(param_logical_specs(pschema), mesh, rules)["params"]

    tok_spec, cache_spec, pos_spec = S.decode_logical_specs(cfg, shape)
    cache_shard = _shardings(cache_spec, mesh, rules)

    if shape.kind == "prefill":
        b_structs = S.batch_structs(cfg, shape)
        # prefill has no labels input
        b_structs.pop("labels")
        b_spec = S.batch_logical_specs(cfg)
        b_spec.pop("labels")
        b_shard = _shardings(b_spec, mesh, rules)
        fn = S.make_prefill_step(cfg, cache_len=shape.seq_len, mesh=mesh)
        # the built cache must come out SHARDED like the decode input cache
        # (otherwise GSPMD materializes replicated multi-GB cache outputs)
        logits_shard = _shardings(("batch", None), mesh, rules)
        return DryRunCase(cfg.name, shape.name, fn,
                          (p_structs, b_structs), (p_shard, b_shard),
                          out_shardings=(logits_shard, cache_shard))

    if shape.kind == "decode":
        tok, caches, pos = S.decode_structs(cfg, shape)
        shard = _shardings({"t": tok_spec, "p": pos_spec}, mesh, rules)
        fn = S.make_decode_step(cfg, mesh=mesh)
        logits_shard = _shardings(("batch", None), mesh, rules)
        return DryRunCase(cfg.name, shape.name, fn,
                          (p_structs, tok, caches, pos),
                          (p_shard, shard["t"], cache_shard, shard["p"]),
                          donate=(2,),
                          out_shardings=(logits_shard, cache_shard))

    raise ValueError(shape.kind)
