import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (the two lines above lock jax to 512 fake CPU
devices before any other import). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --list

For each cell: jit(step).lower(structs).compile() on the (16,16) single-pod
mesh AND the (2,16,16) multi-pod mesh; records memory_analysis(),
cost_analysis() and the HLO-parsed collective bytes into
results/dryrun/<arch>__<shape>__<mesh>.json (incremental cache keyed by a
code-version stamp — re-runs skip green cells).
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro import configs
from repro.common.config import SHAPES, TrainConfig
from repro.launch import hlo_analysis as H
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_case

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")
VERSION = "v16"  # bump to invalidate cached cells after code changes


def input_specs(arch: str, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    return build_case(cfg, shape, mesh)


def _mem_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = getattr(ma, k, None)
    args = out.get("argument_size_in_bytes") or 0
    temp = out.get("temp_size_in_bytes") or 0
    outb = out.get("output_size_in_bytes") or 0
    alias = out.get("alias_size_in_bytes") or 0
    out["peak_bytes_per_device"] = args + temp + outb - alias
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             results_dir: str = RESULTS_DIR, force: bool = False,
             verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(results_dir, exist_ok=True)
    path = os.path.join(results_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if not force and os.path.exists(path):
        with open(path) as f:
            prev = json.load(f)
        if prev.get("version") == VERSION and prev.get("ok"):
            if verbose:
                print(f"[cache] {arch} × {shape_name} × {mesh_name}")
            return prev

    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "version": VERSION, "ok": False}
    try:
        case = build_case(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                             out_shardings=case.out_shardings,
                             donate_argnums=case.donate)
            lowered = jitted.lower(*case.arg_structs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = _mem_stats(compiled)
        # exact per-device argument residency (weights + opt state + caches):
        # struct bytes divided by the shards of its PartitionSpec
        import numpy as _np
        def _arg_bytes(struct, shard):
            spec = shard.spec
            div = 1
            for entry in spec:
                for ax in ((entry,) if isinstance(entry, str) else (entry or ())):
                    div *= mesh.shape[ax]
            return int(_np.prod(struct.shape)) * struct.dtype.itemsize / div
        mem["args_bytes_per_device_exact"] = float(sum(
            _arg_bytes(s, sh) for s, sh in zip(
                jax.tree.leaves(case.arg_structs),
                jax.tree.leaves(case.in_shardings))))
        hlo_text = compiled.as_text()
        # CPU backend emulates bf16 dots via f32 operand conversion (hoisted
        # out of scans) — buffers a TPU compile would never materialize.
        emu = H.bf16_emulation_bytes(hlo_text)
        mem["cpu_bf16_emulation_bytes"] = emu
        mem["peak_bytes_adjusted"] = mem["peak_bytes_per_device"] - emu
        ca = compiled.cost_analysis() or {}
        # HLO-text analysis with while trip-count multiplicities — XLA's own
        # cost_analysis counts scan bodies once (recorded raw for reference).
        summary = H.analyze(hlo_text)
        flops = summary.dot_flops
        bytes_acc = summary.hbm_bytes
        colls = summary.collectives

        from repro.common.schema import count_params
        from repro.models.transformer import model_schema
        n_params = count_params(model_schema(
            cfg, max_seq=shape.seq_len if cfg.is_encoder_decoder else 0))
        n_active = R.active_params(cfg, n_params)
        n_dev = mesh.size
        toks_per_dev = (shape.tokens if shape.kind != "decode"
                        else shape.global_batch) / n_dev
        mflops = R.model_flops_estimate(n_params, n_active, shape.kind, toks_per_dev)
        terms = R.roofline_terms(flops, bytes_acc, colls, model_flops=mflops)

        rec.update(ok=True,
                   n_devices=n_dev,
                   n_params=n_params,
                   n_active_params=n_active,
                   lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2),
                   memory=mem,
                   cost_analysis_raw={
                       "flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                       "note": "XLA counts while bodies once; roofline uses "
                               "trip-corrected HLO parse instead"},
                   roofline=terms.as_dict())
        if verbose:
            peak = mem["peak_bytes_adjusted"] / 1e9
            print(f"[ok] {arch} × {shape_name} × {mesh_name}: "
                  f"{peak:.2f} GB/dev (raw {mem['peak_bytes_per_device'] / 1e9:.1f}), "
                  f"{flops / 1e9:.1f} GFLOP/dev, "
                  f"coll {terms.collective_bytes / 1e6:.1f} MB/dev, "
                  f"dominant={terms.dominant} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} × {mesh_name}: {rec['error']}")

    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    args = ap.parse_args(argv)

    cell_list = configs.cells()
    if args.list:
        for a, s in cell_list:
            print(f"{a:24s} {s}")
        print(f"{len(cell_list)} runnable cells "
              f"({len(configs.SKIP_CELLS)} documented skips)")
        return 0

    archs = configs.ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            if (arch, shape) in configs.SKIP_CELLS:
                print(f"[skip] {arch} × {shape}: {configs.SKIP_CELLS[(arch, shape)]}")
                continue
            for mp in meshes:
                rec = run_cell(arch, shape, mp, results_dir=args.results_dir,
                               force=args.force)
                failures += 0 if rec.get("ok") else 1
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
