"""Trip-count-aware analysis of post-SPMD optimized HLO.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scanned layer stacks. This module parses the optimized HLO text instead:

  1. two-pass parse: first collect every instruction's result shape (operands
     appear as %name references, resolved against this table), then build per
     computation instruction lists;
  2. build the call graph (while bodies/conditions via ``body=``/
     ``condition=`` with ``known_trip_count`` from backend_config, fusions via
     ``calls=``, reducers via ``to_apply=``) and propagate execution
     multiplicities from ENTRY;
  3. aggregate, weighted by multiplicity:
       · dot FLOPs — exact: 2 · prod(result dims) · prod(lhs contracting dims)
       · HBM traffic — post-fusion model: every top-level op reads its
         (non-tuple) operands and writes its results once; fusions therefore
         count only their real inputs/outputs — what fusion means for HBM;
       · collective bytes by kind (max of operand/result bytes per op).

Validated against unrolled compiles (tests/test_dryrun_small.py): scanned and
unrolled lowerings agree on dot FLOPs.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# HBM traffic WHITELIST: ops that move data on a TPU compile. CPU HLO leaves
# elementwise chains as hundreds of top-level ops (each would count its
# operands+results → 10-100× inflation); on TPU they fuse into the adjacent
# matmul/fusion kernels, so only matmuls, explicit fusions, data movement and
# gathers/scatters are charged.
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "dynamic-update-slice", "dynamic-slice",
    "gather", "scatter", "copy", "sort", "reduce-window", "cholesky",
    "triangular-solve", "fft", "concatenate", "pad",
}


def _bytes_of(dt: str, dims: str) -> float:
    size = _DTYPE_BYTES.get(dt)
    if size is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * size)


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: float
    result_is_tuple: bool
    result_dims: List[int]
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    calls: List[Tuple[str, float]]   # (callee, multiplier)


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line.startswith(" "):
            header = _HEADER_RE.match(line)
            if header:
                name = header.group(2)
                cur = Computation(name, [], [])
                comps[name] = cur
                if header.group(1):
                    entry = name
                continue
            if line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        eq = s.find(" = ")
        if eq < 0:
            continue
        iname = s[:eq].strip().lstrip("%")
        rhs = s[eq + 3:]
        opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        if not opm:
            continue
        opcode = opm.group(1)
        paren = rhs.index("(", opm.start())
        depth, end = 0, paren
        for i in range(paren, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        result_part = rhs[:opm.start()]
        operand_part = rhs[paren:end + 1]
        attrs = rhs[end + 1:]

        shapes = _SHAPE_RE.findall(result_part)
        res_bytes = sum(_bytes_of(d, dims) for d, dims in shapes)
        is_tuple = result_part.lstrip().startswith("(") or len(shapes) > 1
        dims0 = ([int(d) for d in shapes[0][1].split(",") if d] if shapes else [])
        operands = _OPERAND_RE.findall(operand_part)
        cur.instrs.append(Instr(iname, opcode, res_bytes, is_tuple, dims0,
                                operands, attrs))

        if opcode == "while":
            trip = 1.0
            tm = re.search(r'known_trip_count[^0-9]*(\d+)', attrs)
            if tm:
                trip = float(tm.group(1))
            bm = re.search(r"body=%?([\w.\-]+)", attrs)
            cm = re.search(r"condition=%?([\w.\-]+)", attrs)
            if bm:
                cur.calls.append((bm.group(1), trip))
            if cm:
                cur.calls.append((cm.group(1), trip + 1))
        else:
            for pat in (r"calls=%?([\w.\-]+)", r"to_apply=%?([\w.\-]+)",
                        r"true_computation=%?([\w.\-]+)",
                        r"false_computation=%?([\w.\-]+)"):
                m = re.search(pat, attrs)
                if m:
                    cur.calls.append((m.group(1), 1.0))
    return comps, entry


def multiplicities(comps: Dict[str, Computation], entry: Optional[str]) -> Dict[str, float]:
    mult: Dict[str, float] = {}
    if entry is None:
        return mult
    stack = [(entry, 1.0)]
    while stack:
        name, m = stack.pop()
        mult[name] = mult.get(name, 0.0) + m
        comp = comps.get(name)
        if comp is None:
            continue
        for callee, k in comp.calls:
            stack.append((callee, m * k))
    return mult


@dataclasses.dataclass
class HloSummary:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    collectives: Dict[str, Dict[str, float]]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def bf16_emulation_bytes(text: str, min_bytes: float = 128e6) -> float:
    """CPU-backend artifact detector: XLA CPU emulates bf16 dots by
    f32-converting whole operands (and hoists the convert of scan-invariant
    stacks out of the loop). A TPU compile feeds bf16 straight to the MXU —
    these buffers would not exist. Returns the summed bytes of large
    f32-convert-of-bf16 results so memory reports can show an adjusted
    (TPU-realistic) peak alongside the raw CPU number."""
    dtype: Dict[str, str] = {}
    # first pass: map instruction name -> result dtype
    for line in text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        eq = s.find(" = ")
        if eq < 0:
            continue
        name = s[:eq].strip().lstrip("%")
        m = _SHAPE_RE.search(s[eq + 3:])
        if m:
            dtype[name] = m.group(1)
    total = 0.0
    seen = set()
    for line in text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?([\w.\-]+) = f32\[([\d,]+)\][^=]*? convert\(%([\w.\-]+)\)", s)
        if not m:
            continue
        if dtype.get(m.group(3)) != "bf16":
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        b = n * 4.0
        if b >= min_bytes and m.group(2) not in seen:
            seen.add(m.group(2))   # count each distinct shape once (aliases)
            total += b
    return total


def analyze(text: str) -> HloSummary:
    comps, entry = parse_hlo(text)
    mult = multiplicities(comps, entry)

    # global name → (bytes, is_tuple, dims)
    table: Dict[str, Tuple[float, bool, List[int]]] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            table[ins.name] = (ins.result_bytes, ins.result_is_tuple, ins.result_dims)

    def operand_bytes(ins: Instr, cap: float = 0.0) -> float:
        """Sum operand bytes; with ``cap``, each operand's contribution is
        bounded — fusions embedding dynamic-slice read only a slice of big
        scan-invariant operands, so charging the full buffer per iteration
        inflates loop-body traffic ~100×."""
        total = 0.0
        for o in ins.operands:
            b, is_tup, _ = table.get(o, (0.0, False, []))
            if not is_tup:
                total += min(b, cap) if cap else b
        return total

    def dot_flops(ins: Instr) -> float:
        if ins.opcode not in ("dot", "convolution"):
            return 0.0
        out = 1
        for d in ins.result_dims:
            out *= d
        contract = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        if m and ins.operands:
            _, _, lhs_dims = table.get(ins.operands[0], (0.0, False, []))
            if m.group(1) and lhs_dims:
                for i in m.group(1).split(","):
                    idx = int(i)
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
        return 2.0 * out * contract

    flops = 0.0
    hbm = 0.0
    colls = {k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ins in comp.instrs:
            flops += m * dot_flops(ins)
            base = next((c for c in _COLLECTIVES if ins.opcode.startswith(c)), None)
            if base is not None:
                if ins.opcode.endswith("-done"):
                    continue
                colls[base]["count"] += m
                colls[base]["bytes"] += m * max(ins.result_bytes, operand_bytes(ins))
                continue
            if ins.opcode in _TRAFFIC_OPS:
                cap = max(4.0 * ins.result_bytes, 32e6) if ins.opcode == "fusion" else 0.0
                opsum = operand_bytes(ins, cap)
                if ins.opcode == "dynamic-slice":
                    # reads+writes only the slice, not the source buffer
                    hbm += m * 2 * ins.result_bytes
                elif ins.opcode == "dynamic-update-slice" or (
                        ins.opcode == "fusion" and "dynamic_update_slice" in ins.attrs):
                    # in-place update: traffic = the update slice (r+w), not
                    # the whole (aliased) stacked buffer
                    max_op = 0.0
                    for o in ins.operands:
                        b, tup, _ = table.get(o, (0.0, True, []))
                        if not tup:
                            max_op = max(max_op, b)
                    hbm += m * 2 * max(opsum - max_op, 0.0)
                else:
                    hbm += m * (ins.result_bytes + opsum)
    return HloSummary(
        dot_flops=flops, hbm_bytes=hbm,
        collective_bytes=sum(v["bytes"] for v in colls.values()),
        collectives=colls)
