"""Serving launcher: batched prefill + greedy decode on local devices.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.common.schema import init_params
    from repro.models import transformer as T
    from repro.train import make_decode_step, make_prefill_step

    cfg = configs.smoke_config(args.arch) if args.reduced else configs.get_config(args.arch)
    cache_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)
    params = init_params(T.model_schema(cfg, max_seq=cache_len), key)

    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.vision_seq:
        batch["vision"] = jax.random.normal(key, (args.batch, cfg.vision_seq, cfg.d_model))

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in "
          f"{t_prefill * 1e3:.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = args.batch * (args.gen - 1)
    print(f"decode: {toks} tokens in {dt * 1e3:.1f} ms "
          f"({toks / max(dt, 1e-9):.1f} tok/s batch, "
          f"{dt * 1e3 / max(args.gen - 1, 1):.2f} ms/step)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("generated ids[0]:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
