"""Serving launcher: two workloads behind one front door.

* ``--workload lm`` (the default): batched prefill + greedy decode on
  local devices —

      PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \\
          --reduced --batch 4 --prompt-len 32 --gen 16

* ``--workload graph``: the online GraphSAGE serving engine
  (``repro.serving``) under synthetic multi-tenant traffic — concurrent
  callers with zipf-skewed seed popularity enqueue into the
  size-or-deadline ``RequestQueue``, every drain fuses the pending
  requests into ONE ``aggregate_multi`` SSD command block (tenant-tagged
  segments scatter results back to their callers), the hot-vertex cache
  absorbs repeat self-row lookups, and the run closes with the engine's
  health snapshot (finds-per-query, StepMonitor stats, cache hit rate) —

      PYTHONPATH=src python -m repro.launch.serve --workload graph \\
          --requests 48 --tenants 4 --cache 32 --batch 8
"""

from __future__ import annotations

import argparse
import sys
import time


def _main_lm(args) -> int:
    if not args.arch:
        print("--workload lm requires --arch", file=sys.stderr)
        return 2

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.common.schema import init_params
    from repro.models import transformer as T
    from repro.train import make_decode_step, make_prefill_step

    cfg = configs.smoke_config(args.arch) if args.reduced else configs.get_config(args.arch)
    cache_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)
    params = init_params(T.model_schema(cfg, max_seq=cache_len), key)

    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.vision_seq:
        batch["vision"] = jax.random.normal(key, (args.batch, cfg.vision_seq, cfg.d_model))

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in "
          f"{t_prefill * 1e3:.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = args.batch * (args.gen - 1)
    print(f"decode: {toks} tokens in {dt * 1e3:.1f} ms "
          f"({toks / max(dt, 1e-9):.1f} tok/s batch, "
          f"{dt * 1e3 / max(args.gen - 1, 1):.2f} ms/step)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("generated ids[0]:", gen[0].tolist())
    return 0


def _main_graph(args) -> int:
    import numpy as np

    from repro.graph import uniform_graph
    from repro.serving import ServingEngine

    rng = np.random.default_rng(args.seed)
    V = args.vertices
    g = uniform_graph(V, args.degree * V, seed=args.seed,
                      n_features=args.features)
    indptr, indices, _ = g.to_csr()

    eng = ServingEngine(g.features, indptr, indices, fanout=args.fanout,
                        max_batch=args.batch,
                        max_delay_s=args.max_delay_ms / 1e3,
                        cache_capacity=args.cache, sample_seed=args.seed)
    print(f"graph serving: V={V} E={args.degree * V} F={args.features} "
          f"fanout={args.fanout} | batch={args.batch} "
          f"deadline={args.max_delay_ms}ms cache={args.cache} "
          f"tenants={args.tenants}")

    # zipf-skewed seed popularity over a permuted rank order — the hot-set
    # concentration the hot-vertex cache exploits (I-GCN's islandization)
    order = rng.permutation(V)
    p = np.empty(V)
    p[order] = 1.0 / (np.arange(V) + 1.0)
    p /= p.sum()

    served = 0
    per_tenant = [0] * args.tenants
    t0 = time.perf_counter()
    for i in range(args.requests):
        n_seeds = int(rng.integers(1, 4))
        seeds = rng.choice(V, n_seeds, p=p)
        tenant = i % args.tenants
        eng.submit(seeds, tenant=tenant)
        per_tenant[tenant] += 1
        served += eng.poll()          # dispatches when size/deadline fires
    served += eng.flush()
    dt = time.perf_counter() - t0

    snap = eng.health_snapshot()
    stats = snap["stats"]
    print(f"served {served}/{args.requests} requests "
          f"({', '.join(f't{t}:{n}' for t, n in enumerate(per_tenant))}) "
          f"in {dt * 1e3:.1f} ms")
    print(f"command blocks: {stats['command_blocks']} "
          f"({stats['queries'] / max(stats['command_blocks'], 1):.1f} "
          f"queries/block) | finds: {stats['find']} "
          f"({snap['finds_per_query']:.3f}/query vs 1.000 naive)")
    if "cache" in snap:
        c = snap["cache"]
        print(f"hot cache: {c['hits']}/{c['hits'] + c['misses']} lookups hit "
              f"(rate {c['hit_rate']:.2f}), {c['resident']}/{c['capacity']} "
              f"rows resident, {c['evictions']} evictions")
    mon = snap["monitor"]
    print(f"health: {mon['steps']} dispatches recorded "
          f"({mon['flagged']} flagged), ewma "
          f"{mon['ewma_s'] * 1e3:.1f} ms/dispatch, "
          f"queue depth {snap['queue_depth']}")
    return 0 if served == args.requests else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "graph"), default="lm")
    # lm workload
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="lm: prefill batch; graph: queue max_batch")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    # graph workload
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--vertices", type=int, default=256)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--features", type=int, default=16)
    ap.add_argument("--fanout", type=int, default=10)
    ap.add_argument("--cache", type=int, default=32,
                    help="hot-vertex cache capacity (0 disables)")
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    return _main_graph(args) if args.workload == "graph" else _main_lm(args)


if __name__ == "__main__":
    sys.exit(main())
