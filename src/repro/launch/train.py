"""Training launcher.

Two modes:
  --dry-run     lower+compile the production (16,16)/(2,16,16) case (no data)
  (default)     actually train a --reduced config on the local devices with
                the full fault-tolerant loop (checkpoint/resume/straggler)

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --shape train_4k --dry-run
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced same-family config locally")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    args = ap.parse_args(argv)

    if args.dry_run:
        # separate process: the 512-device flag must precede jax init
        import subprocess
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--mesh", "multi" if args.multi_pod else "single"]
        return subprocess.call(cmd, env={
            **os.environ,
            "PYTHONPATH": os.pathsep.join(
                [os.path.join(os.path.dirname(__file__), "..", ".."),
                 os.environ.get("PYTHONPATH", "")])})

    import jax
    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.common.config import TrainConfig
    from repro.data import TokenStream
    from repro.runtime import PreemptionGuard, StepMonitor
    from repro.train import init_state, make_train_step, train_loop

    cfg = configs.smoke_config(args.arch) if args.reduced else configs.get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps, grad_compression=args.grad_compression)
    stream = TokenStream(
        vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len,
        with_frames=cfg.enc_seq if cfg.is_encoder_decoder else 0,
        with_vision=cfg.vision_seq, d_model=cfg.d_model)
    state = init_state(cfg, tc, jax.random.PRNGKey(tc.seed), max_seq=args.seq_len)
    step = jax.jit(make_train_step(cfg, tc))

    import jax.numpy as jnp
    def batches():
        for b in stream:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state, n = train_loop(step_fn=step, state=state, batches=batches(),
                          total_steps=args.steps, ckpt=ckpt, ckpt_every=25,
                          monitor=StepMonitor(), guard=PreemptionGuard(),
                          log_every=10)
    print(f"finished at step {n}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
