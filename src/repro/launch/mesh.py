"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. Shapes per the assignment:
(16, 16) = one v5e pod (256 chips), (2, 16, 16) = two pods over DCN.

All mesh construction goes through ``repro.compat.make_mesh`` so the same
code lowers on JAX 0.4.x (no ``axis_types=``) and current JAX alike.
"""

from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for CI on fake CPU devices."""
    return make_mesh((n_data, n_model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def make_data_mesh(n: int):
    """1-D storage-tier mesh (graph engine tests/examples)."""
    return make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
