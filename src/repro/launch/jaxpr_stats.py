"""Deterministic primitive counters over traced jaxprs.

``hlo_analysis`` measures what XLA *compiled* (bytes, FLOPs) — but compiled
HLO is downstream of optimization passes (collective combiners, DCE,
fusion), so "how many collectives does this dataflow ISSUE?" is better
answered one level up, on the jaxpr the program traces to. This module
counts primitive equations recursively through every sub-jaxpr (``pjit``,
``shard_map``, ``scan``/``while`` bodies, ``custom_vjp`` branches, …), which
makes the counts

* **deterministic** — a pure function of the traced program, independent of
  backend, optimization level, or combiner passes;
* **complete** — a collective inside a ``shard_map`` body or a kernel
  dispatch inside a custom-VJP backward is counted exactly like a top-level
  one.

Counts are *static dispatch sites*: a ``lax.scan`` body is counted once, not
once per iteration (the chunked request stream issues its collectives per
chunk at run time but traces them once — exactly the "command block" view
the coalescing work optimizes).

Used by ``tests/test_cgtrans_coalesce.py`` and
``benchmarks/collective_bytes.py`` to assert the request-coalescing claim:
the coalesced sampled dataflow issues ONE ``all_to_all`` + ONE ``all_gather``
(+ one kernel gather) where the separate two-stream form issued two of each.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterable, Optional

import jax

from repro.compat import COLLECTIVE_ALIASES, canonical_collective

#: the cross-shard communication primitives of the CGTrans dataflows, by
#: CANONICAL name — the jaxpr spellings drift across JAX versions (``psum``
#: traces as ``psum2`` under some shard_map replication checkers,
#: ``lax.psum_scatter`` lowers to a primitive named ``reduce_scatter``,
#: ``ppermute`` to ``collective_permute``), so the version-sensitive alias
#: table lives in ``repro.compat`` per the single-door rule and every count
#: this module reports is folded onto the canonical key.
COLLECTIVE_PRIMITIVES = tuple(COLLECTIVE_ALIASES)


def canonicalize_collectives(counts: Counter) -> Counter:
    """Fold version-specific collective spellings onto their canonical names
    (``psum2`` → ``psum``, ``reduce_scatter`` → ``psum_scatter``, …);
    non-collective primitive names pass through unchanged."""
    out: Counter = Counter()
    for name, n in counts.items():
        out[canonical_collective(name) or name] += n
    return out


def _sub_jaxprs(value):
    """Yield every jaxpr reachable from one eqn-param value (duck-typed so
    it works across JAX versions that moved ``Jaxpr``/``ClosedJaxpr``)."""
    if hasattr(value, "eqns"):                       # a raw Jaxpr
        yield value
    elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr                            # a ClosedJaxpr
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _sub_jaxprs(v)


def count_primitives(jaxpr) -> Counter:
    """Counter of primitive-name → static occurrence count, recursing into
    every sub-jaxpr. Accepts a ``Jaxpr`` or ``ClosedJaxpr``."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    counts: Counter = Counter()
    stack = [jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:       # pjit caches share jaxpr objects — count once
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                stack.extend(_sub_jaxprs(v))
    return counts


def primitive_counts(fn, *args, keys: Optional[Iterable[str]] = None,
                     **kwargs) -> Counter:
    """Trace ``fn(*args, **kwargs)`` and count its primitives.

    ``keys`` restricts the result (missing keys read 0 from the Counter
    anyway; restricting just keeps reports small). The trace is exactly what
    ``jax.jit`` would stage, so the counts describe the program XLA receives
    — before any combiner/DCE pass can blur the picture. Collective
    spellings are canonicalized (see ``canonicalize_collectives``), so
    ``keys`` should use canonical names.
    """
    counts = canonicalize_collectives(
        count_primitives(jax.make_jaxpr(fn)(*args, **kwargs)))
    if keys is not None:
        return Counter({k: counts[k] for k in keys})
    return counts


def collective_counts(fn, *args, **kwargs) -> Counter:
    """``primitive_counts`` restricted to the cross-shard collectives
    (canonical names)."""
    return primitive_counts(fn, *args, keys=COLLECTIVE_PRIMITIVES, **kwargs)
