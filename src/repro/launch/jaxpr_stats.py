"""Deterministic primitive counters over traced jaxprs.

``hlo_analysis`` measures what XLA *compiled* (bytes, FLOPs) — but compiled
HLO is downstream of optimization passes (collective combiners, DCE,
fusion), so "how many collectives does this dataflow ISSUE?" is better
answered one level up, on the jaxpr the program traces to. This module
counts primitive equations recursively through every sub-jaxpr (``pjit``,
``shard_map``, ``scan``/``while`` bodies, ``custom_vjp`` branches, …), which
makes the counts

* **deterministic** — a pure function of the traced program, independent of
  backend, optimization level, or combiner passes;
* **complete** — a collective inside a ``shard_map`` body or a kernel
  dispatch inside a custom-VJP backward is counted exactly like a top-level
  one.

Counts are *static dispatch sites*: a ``lax.scan`` body is counted once, not
once per iteration (the chunked request stream issues its collectives per
chunk at run time but traces them once — exactly the "command block" view
the coalescing work optimizes).

Used by ``tests/test_cgtrans_coalesce.py`` and
``benchmarks/collective_bytes.py`` to assert the request-coalescing claim:
the coalesced sampled dataflow issues ONE ``all_to_all`` + ONE ``all_gather``
(+ one kernel gather) where the separate two-stream form issued two of each.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterable, Optional

import jax

#: the cross-shard communication primitives of the CGTrans dataflows
COLLECTIVE_PRIMITIVES = (
    "all_to_all", "all_gather", "psum", "psum_scatter", "reduce_scatter",
    "ppermute", "pmax", "pmin",
)


def _sub_jaxprs(value):
    """Yield every jaxpr reachable from one eqn-param value (duck-typed so
    it works across JAX versions that moved ``Jaxpr``/``ClosedJaxpr``)."""
    if hasattr(value, "eqns"):                       # a raw Jaxpr
        yield value
    elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr                            # a ClosedJaxpr
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _sub_jaxprs(v)


def count_primitives(jaxpr) -> Counter:
    """Counter of primitive-name → static occurrence count, recursing into
    every sub-jaxpr. Accepts a ``Jaxpr`` or ``ClosedJaxpr``."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    counts: Counter = Counter()
    stack = [jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:       # pjit caches share jaxpr objects — count once
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                stack.extend(_sub_jaxprs(v))
    return counts


def primitive_counts(fn, *args, keys: Optional[Iterable[str]] = None,
                     **kwargs) -> Counter:
    """Trace ``fn(*args, **kwargs)`` and count its primitives.

    ``keys`` restricts the result (missing keys read 0 from the Counter
    anyway; restricting just keeps reports small). The trace is exactly what
    ``jax.jit`` would stage, so the counts describe the program XLA receives
    — before any combiner/DCE pass can blur the picture.
    """
    counts = count_primitives(jax.make_jaxpr(fn)(*args, **kwargs))
    if keys is not None:
        return Counter({k: counts[k] for k in keys})
    return counts


def collective_counts(fn, *args, **kwargs) -> Counter:
    """``primitive_counts`` restricted to the cross-shard collectives."""
    return primitive_counts(fn, *args, keys=COLLECTIVE_PRIMITIVES, **kwargs)
