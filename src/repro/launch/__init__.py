from repro.launch.mesh import make_data_mesh, make_production_mesh, make_test_mesh

__all__ = ["make_data_mesh", "make_production_mesh", "make_test_mesh"]
