"""AST-level repo lint (the mechanical half of the static analysis).

Four invariant families, each previously enforced only by review:

* ``compat-door`` — the ROADMAP's standing single-door rule: every
  version-sensitive JAX API (``shard_map``, ``make_mesh``, ``AxisType``,
  ``psum_scatter``, anything under ``jax.experimental``) is imported from
  ``repro.compat`` and nowhere else. The two pallas ``kernel.py`` files are
  the one allowlisted exception (``jax.experimental.pallas`` IS their
  subject matter), and ``compat.py`` itself is the door.
* ``pallas-call-site`` / ``collective-site`` / ``unticked-dispatch`` —
  dispatch-site coverage. Raw ``pallas_call`` sites live only in the kernel
  modules; cross-shard collective calls live only in the contract-covered
  dataflow modules (``analysis/contracts.py`` budgets every one of them);
  and any function outside the kernel modules that reaches a raw kernel
  entry (``gas_scatter_pallas``/``gas_scatter_banded``) must either be a
  private impl (reached via a ticking public wrapper) or tick
  ``count_dispatches`` itself. The AST layer catches *new, uncovered* sites
  appearing; the jaxpr layer (contracts) catches covered sites drifting in
  count — together a dispatch can neither appear nor multiply unnoticed.
* ``unknown-marker`` — every ``pytest.mark.<x>`` in tests must be
  registered in pyproject (CI runs ``-W
  error::pytest.PytestUnknownMarkWarning``; this fails at lint time with a
  file:line instead of at collection time in one lane).
* ``f64-literal`` — no ``float64``/x64 literals outside tests (the
  dtype-flow jaxpr rule catches *traced* promotions; this catches the
  source-level seeds of them). Host-side float64 test oracles are
  legitimate, hence the scope.

A violating line can be suppressed with an inline justification::

    from jax.experimental import pallas  # lint: allow(compat-door): kernel module

The justification text is REQUIRED — a bare ``allow()`` does not suppress.
"""

from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

#: every rule this module can emit
RULES = ("compat-door", "pallas-call-site", "collective-site",
         "unticked-dispatch", "unknown-marker", "f64-literal")

#: the single door itself — exempt from the compat/collective rules
COMPAT_DOOR = "src/repro/compat.py"

#: the raw-kernel modules: ``jax.experimental.pallas`` + ``pallas_call``
#: allowed, and their ``gas_*`` entries are the raw dispatches others wrap
PALLAS_KERNEL_ALLOWLIST = (
    "src/repro/kernels/gas_scatter/kernel.py",
    "src/repro/kernels/flash_attention/kernel.py",
)

#: modules allowed to issue cross-shard collectives — exactly the set the
#: dataflow contracts budget (a collective elsewhere is uncounted traffic)
COLLECTIVE_SITE_ALLOWLIST = (
    COMPAT_DOOR,
    "src/repro/core/cgtrans.py",
    "src/repro/models/embedding.py",
    "src/repro/train/pipeline.py",
)

#: version-sensitive attribute paths that must route through repro.compat
_COMPAT_ONLY_ATTRS = ("jax.shard_map", "jax.make_mesh", "lax.psum_scatter",
                      "jax.lax.psum_scatter", "jax.sharding.AxisType")

#: collective API names (call sites; the jaxpr layer counts what they trace)
_COLLECTIVE_CALLS = ("psum", "psum_scatter", "all_to_all", "all_gather",
                     "ppermute", "pmax", "pmin")

#: raw kernel entries — referencing these outside kernel.py requires a tick
_RAW_DISPATCHES = ("gas_scatter_pallas", "gas_scatter_banded", "pallas_call")

#: pytest's built-in marks (never registered in pyproject)
_BUILTIN_MARKS = frozenset({
    "parametrize", "skip", "skipif", "xfail", "usefixtures",
    "filterwarnings",
})

_ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\(([\w\s,-]+)\)\s*[:—-]\s*(\S.*)")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str          # repo-relative, posix
    line: int          # 1-based
    rule: str          # one of RULES
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.psum_scatter' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _allowed_lines(source: str) -> Dict[int, Tuple[str, ...]]:
    """line → rules suppressed there (justified ``lint: allow`` comments)."""
    out: Dict[int, Tuple[str, ...]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if m:
            out[i] = tuple(r.strip() for r in m.group(1).split(","))
    return out


def registered_markers(pyproject_path: Path) -> frozenset:
    """Marker names registered under [tool.pytest.ini_options].markers."""
    text = pyproject_path.read_text()
    try:
        import tomllib
    except ImportError:                       # Python 3.10: no tomllib —
        # regex-parse the markers list so the rule neither crashes nor
        # false-positives every registered marker
        return frozenset(re.findall(r'^\s*"([\w-]+)\s*:', text, re.M))
    data = tomllib.loads(text)
    markers = (data.get("tool", {}).get("pytest", {})
               .get("ini_options", {}).get("markers", []))
    return frozenset(m.split(":")[0].strip() for m in markers)


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, *, markers: frozenset):
        self.rel = rel
        self.markers = markers
        self.violations: List[Violation] = []
        self.func_stack: List[ast.FunctionDef] = []
        # function → (is_private, ticks, raw-dispatch refs [(line, name)])
        self.func_info: List[Tuple[ast.FunctionDef, bool, bool,
                                   List[Tuple[int, str]]]] = []

        self.is_compat = rel == COMPAT_DOOR
        self.is_kernel = rel in PALLAS_KERNEL_ALLOWLIST
        self.in_src = rel.startswith("src/repro/")
        self.in_tests = rel.startswith("tests/")
        self.collectives_ok = rel in COLLECTIVE_SITE_ALLOWLIST

    def _flag(self, node: ast.AST, rule: str, msg: str):
        self.violations.append(
            Violation(self.rel, getattr(node, "lineno", 0), rule, msg))

    # -- compat single door -------------------------------------------------

    def visit_Import(self, node: ast.Import):
        if not self.is_compat:
            for alias in node.names:
                if alias.name.startswith("jax.experimental"):
                    if not (self.is_kernel
                            and alias.name.startswith("jax.experimental.pallas")):
                        self._flag(node, "compat-door",
                                   f"import {alias.name} — version-sensitive "
                                   f"APIs come from repro.compat")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        if not self.is_compat:
            if mod.startswith("jax.experimental"):
                pallas = (mod.startswith("jax.experimental.pallas")
                          or (mod == "jax.experimental"
                              and all(a.name == "pallas" for a in node.names)))
                if not (self.is_kernel and pallas):
                    self._flag(node, "compat-door",
                               f"from {mod} import … — version-sensitive "
                               f"APIs come from repro.compat")
            if mod == "jax.sharding":
                for alias in node.names:
                    if alias.name == "AxisType":
                        self._flag(node, "compat-door",
                                   "AxisType comes from repro.compat (stubbed "
                                   "on pre-AxisType JAX)")
            if mod in ("jax", "jax.lax") or mod.endswith(".lax"):
                for alias in node.names:
                    if alias.name in ("shard_map", "make_mesh", "psum_scatter"):
                        self._flag(node, "compat-door",
                                   f"{alias.name} comes from repro.compat")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        name = _dotted(node)
        if name and not self.is_compat:
            if name in _COMPAT_ONLY_ATTRS or name.endswith(".shard_map"):
                if name.startswith(("jax.", "lax.")):
                    self._flag(node, "compat-door",
                               f"{name} — use the repro.compat wrapper")
        if (name and name.split(".")[-1] == "pallas_call"
                and not self.is_kernel):
            self._flag(node, "pallas-call-site",
                       "pallas_call outside the kernel modules — wrap it in "
                       "a ticked dispatch (kernels/*/ops.py pattern)")
        self._note_raw_dispatch(node, name)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if node.id == "pallas_call" and not self.is_kernel:
            self._flag(node, "pallas-call-site",
                       "pallas_call outside the kernel modules")
        self._note_raw_dispatch(node, node.id)
        self.generic_visit(node)

    # -- dispatch coverage --------------------------------------------------

    def _note_raw_dispatch(self, node: ast.AST, name: Optional[str]):
        if not name or self.is_kernel:
            return
        leaf = name.split(".")[-1]
        if leaf in _RAW_DISPATCHES and leaf != "pallas_call":
            if self.func_stack:
                self.func_info[-1][3].append((node.lineno, leaf))
            else:
                self._flag(node, "unticked-dispatch",
                           f"module-level reference to raw kernel entry "
                           f"{leaf}")

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.func_stack.append(node)
        self.func_info.append(
            (node, node.name.startswith("_"), False, []))
        idx = len(self.func_info) - 1
        self.generic_visit(node)
        self.func_stack.pop()
        fn, private, _, refs = self.func_info[idx]
        ticks = any(
            isinstance(n, ast.Call) and (
                (isinstance(n.func, ast.Name) and n.func.id == "_tick")
                or (isinstance(n.func, ast.Attribute)
                    and n.func.attr == "_tick"))
            for n in ast.walk(fn))
        if refs and not private and not ticks:
            line, leaf = refs[0]
            self.violations.append(Violation(
                self.rel, line, "unticked-dispatch",
                f"public function {fn.name!r} reaches raw kernel entry "
                f"{leaf} without a count_dispatches tick — tick it or make "
                f"it a private impl behind a ticked wrapper"))

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- collective call sites ----------------------------------------------

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        if name and self.in_src and not self.collectives_ok:
            parts = name.split(".")
            leaf = parts[-1]
            base_ok = len(parts) == 1 or parts[-2] in ("lax", "jax", "compat")
            if leaf in _COLLECTIVE_CALLS and base_ok:
                self._flag(node, "collective-site",
                           f"collective {leaf}() outside the contract-covered "
                           f"modules {COLLECTIVE_SITE_ALLOWLIST[1:]} — every "
                           f"collective site must carry a DataflowContract "
                           f"budget")
        self.generic_visit(node)

    # -- marker registration + f64 literals ---------------------------------

    def _check_marker(self, name: str, node: ast.AST):
        if name not in self.markers and name not in _BUILTIN_MARKS:
            self._flag(node, "unknown-marker",
                       f"pytest.mark.{name} is not registered in "
                       f"[tool.pytest.ini_options].markers")

    def visit_Module(self, node: ast.Module):
        self.generic_visit(node)
        if self.in_tests:
            for n in ast.walk(node):
                name = _dotted(n) if isinstance(n, ast.Attribute) else None
                if name and name.startswith("pytest.mark."):
                    self._check_marker(name.split(".")[2], n)

    def visit_Constant(self, node: ast.Constant):
        if not self.in_tests and isinstance(node.value, str):
            if node.value in ("float64", "jax_enable_x64"):  # lint: allow(f64-literal): the rule that bans them must name them
                self._flag(node, "f64-literal",
                           f"{node.value!r} literal — the stack is f32; "
                           f"x64/f64 belongs only in test oracles")
        self.generic_visit(node)


def _f64_attrs(tree: ast.AST, linter: _Linter):
    if linter.in_tests:
        return
    for n in ast.walk(tree):
        if isinstance(n, ast.Attribute) and n.attr == "float64":  # lint: allow(f64-literal): the rule that bans it must name it
            linter._flag(n, "f64-literal",
                         "float64 attribute — the stack is f32 end-to-end "
                         "(dtype_flow traces the consequences; fix the seed)")


def lint_file(path: Path, root: Path, *,
              markers: Optional[frozenset] = None) -> List[Violation]:
    """Lint one file; ``root`` anchors the repo-relative path the role rules
    key on. ``markers``: registered pytest markers (parsed from
    ``root/pyproject.toml`` when omitted)."""
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    if markers is None:
        markers = registered_markers(root / "pyproject.toml")
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    linter = _Linter(rel, markers=markers)
    linter.visit(tree)
    _f64_attrs(tree, linter)
    allowed = _allowed_lines(source)
    return [v for v in linter.violations
            if v.rule not in allowed.get(v.line, ())]


def lint_repo(root: Path) -> List[Violation]:
    """Lint every analyzable source file in the repo: ``src/repro``,
    ``scripts``, ``benchmarks``, ``tests`` — excluding the planted-violation
    corpus ``tests/_lint_fixtures`` (the fixture tests lint those
    explicitly and assert the violations ARE caught)."""
    root = root.resolve()
    markers = registered_markers(root / "pyproject.toml")
    violations: List[Violation] = []
    for sub in ("src/repro", "scripts", "benchmarks", "tests"):
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if "_lint_fixtures" in path.parts:
                continue
            violations.extend(lint_file(path, root, markers=markers))
    return violations


def main(argv: Sequence[str] = ()) -> int:
    root = Path(argv[0]) if argv else Path.cwd()
    vs = lint_repo(root)
    for v in vs:
        print(v, file=sys.stderr)
    return 1 if vs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
