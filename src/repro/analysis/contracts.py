"""Declarative dataflow contracts: the communication/dispatch budget of every
public entrypoint, committed as data and verified against an abstract trace.

A ``DataflowContract`` pins, for one entrypoint configuration
(dataflow × impl × coalesce × scheduled):

* the exact **collective counts** its trace issues — canonical primitive
  names via ``repro.compat`` (``psum_scatter`` whatever the installed JAX
  spells it, ``psum`` even when the shard_map checker rewrites it), counted
  by ``launch/jaxpr_stats`` so combiner/DCE passes can't blur them;
* the exact **GAS dispatch budget** — ``find`` (table gathers), ``reduce``
  (seed reductions), ``kernel_scatter`` (pallas dispatches), via the
  trace-time ``gas.count_dispatches`` counters;
* the **forward vs. forward+backward split** — ``forward`` budgets the
  plain trace, ``fwd_bwd`` budgets ``jax.grad`` through it (the backward of
  the in-SSD dataflow is also in-SSD work: its scatters and collectives are
  part of the claim);
* the **dtype waivers** — which ``analysis.dtype_flow`` rules this
  entrypoint intentionally relaxes, with the justification in ``note``
  (e.g. ``embed_lookup``'s bf16 transport).

Verification is ABSTRACT: ``build()`` returns the function plus
``jax.ShapeDtypeStruct`` arguments, and ``verify_contract`` runs
``jax.make_jaxpr`` — no FLOP executes, no mesh hardware is needed beyond
the fake-device topology (``XLA_FLAGS=--xla_force_host_platform_device_count
=8``, which ``scripts/lint.py`` sets before importing jax). Budgets are
EXACT including implicit zeros: a collective the budget doesn't name must
not appear at all.

The ``SAGE_FETCH_*`` tables double as the single source of truth for the
request-coalescing claim — ``tests/test_cgtrans_coalesce.py``,
``tests/distributed_cases.py`` and
``benchmarks/collective_bytes.py::check_coalesce_rows`` import them instead
of repeating the numbers. Amending a budget is a one-line diff here, seen
by every consumer at once (see README "Static contracts" for when that's
legitimate).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.analysis.dtype_flow import check_dtype_flow

#: trace-time GAS dispatch counters (see ``repro.core.gas``)
DISPATCH_KEYS = ("find", "reduce", "kernel_scatter")

# ---------------------------------------------------------------------------
# the coalescing headline budgets (imported by tests + benches)
# ---------------------------------------------------------------------------

#: collectives per step of the sage-shaped fetch (K=1 self-lookup + 2-hop
#: block) on the sharded cgtrans dataflow: the separate two-stream form vs
#: the coalesced ``aggregate_multi`` command block — the "one SSD command
#: block" claim, 2 → 1 of each kind
SAGE_FETCH_COLLECTIVES: Dict[str, Dict[str, int]] = {
    "separate": {"all_gather": 2, "all_to_all": 2},
    "coalesced": {"all_gather": 1, "all_to_all": 1},
}

#: forward GAS dispatches of the same pair: finds 2 → 1 (one combined table
#: gather); the K=1 segment stays a pure find either way, so exactly one
#: seed reduction runs in both forms
SAGE_FETCH_DISPATCH: Dict[str, Dict[str, int]] = {
    "separate": {"find": 2, "reduce": 1},
    "coalesced": {"find": 1, "reduce": 1},
}

#: pallas forward+backward kernel dispatches: the separate form pays one
#: fused forward scatter + TWO backward cotangent scatters (one per
#: gather); coalesced pays one forward + ONE backward
SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD: Dict[str, int] = {
    "separate": 3, "coalesced": 2,
}

#: the SERVING headline (``repro.serving.ServingEngine``): one drained batch
#: of N concurrent requests — each a K=1 self-row lookup segment + a fan-out
#: aggregation segment, tenant-tagged — fuses into ONE command block whose
#: collective count is INDEPENDENT of N, where the one-query-one-dispatch
#: baseline pays the same pair PER QUERY. Collectives-per-query: 1/N vs 1.
SERVE_FETCH_COLLECTIVES: Dict[str, Dict[str, int]] = {
    "fused": {"all_gather": 1, "all_to_all": 1},          # per DRAIN, any N
    "naive_per_query": {"all_gather": 1, "all_to_all": 1},  # per QUERY
}

#: GAS finds of the same pair: the fused drain issues ONE combined table
#: gather for every segment of every caller; the naive baseline issues one
#: per query. (Each caller's fan-out segment still reduces separately —
#: reduces scale with N in BOTH forms, finds do not.)
SERVE_FETCH_FINDS: Dict[str, int] = {
    "fused": 1,                 # per drain, any N
    "naive_per_query": 1,       # per query
}

#: concurrency of the committed serving contract fixtures (the bench and the
#: serving tier assert the same N so the three surfaces can't drift)
SERVE_CONTRACT_N = 8


@dataclasses.dataclass(frozen=True)
class DataflowContract:
    """One entrypoint configuration's committed budget.

    ``build`` is lazy (imports the dataflow modules, constructs the mesh and
    the abstract arguments) and returns ``(fn, args)``; gradients for
    ``fwd_bwd`` are taken with respect to ``args[0]`` through the summed
    float outputs. ``forward``/``fwd_bwd`` map canonical collective names
    and ``DISPATCH_KEYS`` to exact counts — unnamed keys mean ZERO.
    """
    name: str
    build: Callable[[], Tuple[Callable, tuple]]
    forward: Mapping[str, int]
    fwd_bwd: Optional[Mapping[str, int]] = None
    dtype_waivers: Tuple[str, ...] = ()
    note: str = ""

    def __post_init__(self):
        from repro.launch.jaxpr_stats import COLLECTIVE_PRIMITIVES
        legal = set(COLLECTIVE_PRIMITIVES) | set(DISPATCH_KEYS)
        for tag, budget in (("forward", self.forward),
                            ("fwd_bwd", self.fwd_bwd)):
            for k in (budget or {}):
                if k not in legal:
                    raise ValueError(
                        f"{self.name}: unknown budget key {k!r} in {tag} "
                        f"(canonical collectives: "
                        f"{sorted(COLLECTIVE_PRIMITIVES)}; dispatches: "
                        f"{DISPATCH_KEYS})")


def _scalarize(fn):
    """Sum every inexact output leaf to a f32 scalar so ``jax.grad`` can
    differentiate an arbitrary entrypoint with respect to ``args[0]``."""
    import jax
    import jax.numpy as jnp

    def loss(*args):
        leaves = jax.tree_util.tree_leaves(fn(*args))
        return sum(jnp.sum(leaf.astype(jnp.float32)) for leaf in leaves
                   if jnp.issubdtype(leaf.dtype, jnp.inexact))
    return loss


def verify_contract(contract: DataflowContract) -> List[str]:
    """Trace the entrypoint abstractly and check it against its budget.

    Returns failure strings (empty = the contract holds). Each failure names
    the contract, the pass (forward / fwd+bwd), and the key with
    expected-vs-observed — that exact line is what a refactor that adds a
    collective will see in CI.
    """
    import jax

    from repro.core import gas
    from repro.launch.jaxpr_stats import (COLLECTIVE_PRIMITIVES,
                                          canonicalize_collectives,
                                          count_primitives)

    fn, args = contract.build()
    failures: List[str] = []
    for tag, budget in (("forward", contract.forward),
                        ("fwd+bwd", contract.fwd_bwd)):
        if budget is None:
            continue
        target = fn if tag == "forward" else jax.grad(_scalarize(fn))
        try:
            with gas.count_dispatches() as disp:
                jaxpr = jax.make_jaxpr(target)(*args)
        except Exception as e:  # noqa: BLE001 — a non-tracing entrypoint is
            failures.append(f"{contract.name} [{tag}] failed to trace: {e!r}")
            continue            # itself a contract violation, not a crash
        observed = canonicalize_collectives(count_primitives(jaxpr))
        for key in COLLECTIVE_PRIMITIVES:
            want, got = int(budget.get(key, 0)), int(observed[key])
            if want != got:
                failures.append(
                    f"{contract.name} [{tag}] collective {key}: "
                    f"budget {want}, traced {got}")
        for key in DISPATCH_KEYS:
            want, got = int(budget.get(key, 0)), int(disp[key])
            if want != got:
                failures.append(
                    f"{contract.name} [{tag}] dispatch {key}: "
                    f"budget {want}, counted {got}")
        for issue in check_dtype_flow(jaxpr, waive=contract.dtype_waivers):
            failures.append(f"{contract.name} [{tag}] dtype {issue}")
    return failures


def verify_all(names: Optional[Sequence[str]] = None
               ) -> Dict[str, List[str]]:
    """Verify every registered contract (or the named subset); returns
    name → failures for the ones that failed."""
    out: Dict[str, List[str]] = {}
    for name in (names if names is not None else CONTRACTS):
        fails = verify_contract(CONTRACTS[name])
        if fails:
            out[name] = fails
    return out


# ---------------------------------------------------------------------------
# abstract argument builders (shared shapes; ShapeDtypeStructs are passed as
# ARGUMENTS of the traced function, never closed over — closing over an
# abstract value breaks tracing inside jnp.where et al.)
# ---------------------------------------------------------------------------

_WAYS = 8                 # the fake-device data mesh every sharded budget
_PART, _F = 32, 64        # uses (scripts/lint.py forces the topology)
_B, _K1, _K2 = 8, 3, 10
_R1 = _B * (1 + _K1)      # rows of the sage-shaped 2-hop block


def _sds(shape, dtype):
    import jax
    return jax.ShapeDtypeStruct(shape, dtype)


def _fetch_blocks():
    """The sage-shaped request pair: K=1 all-valid self-lookup + fan-out
    2-hop block (the exact pair ``sage_forward`` coalesces)."""
    import jax.numpy as jnp
    feats = _sds((_WAYS, _PART, _F), jnp.float32)
    b1 = (_sds((_WAYS, _R1, 1), jnp.int32), _sds((_WAYS, _R1, 1), jnp.bool_))
    b2 = (_sds((_WAYS, _R1, _K2), jnp.int32),
          _sds((_WAYS, _R1, _K2), jnp.bool_))
    return feats, b1, b2


#: static packed width of the sparse contract fixtures: 16 + 2 bitmap words
#: < _F=64, so the ``sparse_fits`` gate passes and the sparse path traces
_SPARSE_CAP = 16


def _build_sampled(flow: str, impl: str, scheduled: bool, wire: str = "f32",
                   features: str = "dense"):
    def build():
        from repro.core import cgtrans
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        feats, _, (nb2, mk2) = _fetch_blocks()

        def fn(f, nb, mk):
            return cgtrans.aggregate_sampled(
                f, nb, mk, mesh=mesh, dataflow=flow, impl=impl,
                scheduled=scheduled, wire=wire, features=features,
                sparse_capacity=_SPARSE_CAP if features == "sparse" else None)
        return fn, (feats, nb2, mk2)
    return build


def _build_multi(flow: str, impl: str, scheduled: bool, wire: str = "f32",
                 features: str = "dense"):
    def build():
        from repro.core import cgtrans
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        feats, b1, b2 = _fetch_blocks()

        def fn(f, blocks):
            return cgtrans.aggregate_multi(
                f, blocks, mesh=mesh, dataflow=flow, impl=impl,
                scheduled=scheduled, wire=wire, features=features,
                sparse_capacity=_SPARSE_CAP if features == "sparse" else None)
        return fn, (feats, (b1, b2))
    return build


def _build_separate_fetch(flow: str, impl: str):
    """The UN-coalesced twin of ``_build_multi``: the same request pair
    issued as two ``aggregate_sampled`` streams — the baseline side of the
    2 → 1 claim, contracted so the *pair* of budgets is pinned."""
    def build():
        from repro.core import cgtrans
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        feats, b1, b2 = _fetch_blocks()

        def fn(f, blocks):
            (nb1, mk1), (nb2, mk2) = blocks
            return (cgtrans.aggregate_sampled(f, nb1, mk1, mesh=mesh,
                                              dataflow=flow, impl=impl),
                    cgtrans.aggregate_sampled(f, nb2, mk2, mesh=mesh,
                                              dataflow=flow, impl=impl))
        return fn, (feats, (b1, b2))
    return build


def _serve_blocks(n_requests: int):
    """The serving-engine drain fixture: ``n_requests`` concurrent
    single-seed callers, each contributing a K=1 self-row lookup segment +
    a fan-out aggregation segment (the exact block layout
    ``ServingEngine._build_blocks`` emits, one row per shard after its
    pad-to-shard-multiple step)."""
    import jax.numpy as jnp
    feats = _sds((_WAYS, _PART, _F), jnp.float32)
    blocks = []
    for _ in range(n_requests):
        blocks.append((_sds((_WAYS, 1, 1), jnp.int32),
                       _sds((_WAYS, 1, 1), jnp.bool_)))
        blocks.append((_sds((_WAYS, 1, _K2), jnp.int32),
                       _sds((_WAYS, 1, _K2), jnp.bool_)))
    return feats, tuple(blocks)


def _build_serving_fused(impl: str, n_requests: int, wire: str = "f32"):
    def build():
        from repro.core import cgtrans
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        feats, blocks = _serve_blocks(n_requests)

        def fn(f, blocks_):
            return cgtrans.aggregate_multi(f, blocks_, mesh=mesh,
                                           dataflow="cgtrans", impl=impl,
                                           wire=wire)
        return fn, (feats, blocks)
    return build


def _build_serving_naive(impl: str, n_requests: int):
    """The one-query-one-dispatch twin: the SAME segment pairs issued as
    one command block per caller."""
    def build():
        from repro.core import cgtrans
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        feats, blocks = _serve_blocks(n_requests)

        def fn(f, blocks_):
            outs = []
            for j in range(n_requests):
                outs.extend(cgtrans.aggregate_multi(
                    f, blocks_[2 * j:2 * j + 2], mesh=mesh,
                    dataflow="cgtrans", impl=impl))
            return tuple(outs)
        return fn, (feats, blocks)
    return build


def _sage_cfg_batch(impl: str, coalesce: bool, scheduled: bool):
    import jax
    import jax.numpy as jnp
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema
    B, K1, K2, F = 4, 3, 5, 16
    cfg = GCNConfig(n_features=F, hidden=8, n_classes=4, fanout=K2,
                    impl=impl, coalesce=coalesce, scheduled=scheduled)
    params = jax.tree_util.tree_map(
        lambda a: _sds(jnp.shape(a), a.dtype),
        init_params(gcn_schema(cfg), jax.random.PRNGKey(0)))
    batch = {
        "seeds": _sds((_WAYS, B), jnp.int32),
        "nbrs1": _sds((_WAYS, B, K1), jnp.int32),
        "mask1": _sds((_WAYS, B, K1), jnp.bool_),
        "nbrs2": _sds((_WAYS, B * (1 + K1), K2), jnp.int32),
        "mask2": _sds((_WAYS, B * (1 + K1), K2), jnp.bool_),
    }
    feats = _sds((_WAYS, _PART, F), jnp.float32)
    return cfg, params, feats, batch


def _build_sage(impl: str, coalesce: bool, scheduled: bool):
    def build():
        from repro.core.gcn import sage_forward
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        cfg, params, feats, batch = _sage_cfg_batch(impl, coalesce, scheduled)

        def fn(p, f, b):
            return sage_forward(p, f, b, cfg, mesh=mesh)
        return fn, (params, feats, batch)
    return build


def _build_train_step(impl: str, coalesce: bool, scheduled: bool):
    def build():
        import jax
        import jax.numpy as jnp
        from repro.common.config import TrainConfig
        from repro.common.schema import init_params
        from repro.core.gcn import GCNConfig, gcn_schema
        from repro.launch.mesh import make_data_mesh
        from repro.optim import adamw_init
        from repro.train import make_sage_train_step
        mesh = make_data_mesh(_WAYS)
        cfg, _, _, batch = _sage_cfg_batch(impl, coalesce, scheduled)
        batch = dict(batch, labels=_sds((_WAYS, 4), jnp.int32))
        tc = TrainConfig(learning_rate=1e-3)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        state = jax.tree_util.tree_map(
            lambda a: _sds(jnp.shape(a), jnp.result_type(a)),
            {"params": params, "opt": adamw_init(params, tc),
             "step": jnp.zeros((), jnp.int32)})
        # feats closes over as a CONCRETE constant (the API takes it that
        # way); zeros are fine — nothing executes under make_jaxpr
        step = make_sage_train_step(
            cfg, tc, feats=jnp.zeros((_WAYS, _PART, cfg.n_features)),
            mesh=mesh)
        return step, (state, batch)
    return build


def _build_embed(cgtrans: bool, impl: str):
    def build():
        import jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.models.embedding import embed_lookup
        mesh = make_test_mesh(2, 4)          # data=2 × model=4 storage tier
        table = _sds((64, 16), jnp.float32)  # vocab 64 → 16/model-shard
        ids = _sds((4, 8), jnp.int32)

        def fn(tab, ids_):
            return embed_lookup(tab, ids_, mesh=mesh, cgtrans=cgtrans,
                                impl=impl)
        return fn, (table, ids)
    return build


def _build_edges(flow: str, impl: str, op: str, wire: str = "f32",
                 features: str = "dense"):
    def build():
        import jax.numpy as jnp
        from repro.core import cgtrans
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(_WAYS)
        E = 512
        args = (_sds((_WAYS, _PART, _F), jnp.float32),
                _sds((_WAYS, E), jnp.int32), _sds((_WAYS, E), jnp.int32),
                _sds((_WAYS, E), jnp.float32), _sds((_WAYS, E), jnp.bool_))

        def fn(f, src, dst, w, m):
            return cgtrans.aggregate_edges(
                f, src, dst, w, m, mesh=mesh, dataflow=flow, impl=impl,
                op=op, wire=wire, features=features,
                sparse_capacity=_SPARSE_CAP if features == "sparse" else None)
        return fn, args
    return build


# ---------------------------------------------------------------------------
# the registry: dataflow × impl × coalesce × scheduled
# ---------------------------------------------------------------------------

def _merge(*parts: Mapping[str, int]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for p in parts:
        for k, v in p.items():
            out[k] = out.get(k, 0) + v
    return out


CONTRACTS: Dict[str, DataflowContract] = {}


def _register(c: DataflowContract):
    if c.name in CONTRACTS:
        raise ValueError(f"duplicate contract {c.name}")
    CONTRACTS[c.name] = c


# -- aggregate_sampled: one fan-out-K request stream -------------------------
# cgtrans: ONE all_gather (request broadcast) + ONE all_to_all (compressed
# result shipment). baseline ships raw rows: one extra all_to_all. The
# backward retraces the forward collectives and adds the cotangent
# shipment; pallas adds the kernel-scatter dispatches (fwd fused scatter +
# bwd cotangent scatter) and the tie-count psums of the max/min-capable VJP.
_SAMPLED_FWD = {
    "cgtrans": {"all_gather": 1, "all_to_all": 1, "find": 1, "reduce": 1},
    "baseline": {"all_gather": 1, "all_to_all": 2, "find": 1, "reduce": 1},
}
_SAMPLED_BWD = {       # fwd+bwd budgets, xla backend
    "cgtrans": {"all_gather": 1, "all_to_all": 2, "find": 1, "reduce": 1},
    "baseline": {"all_gather": 1, "all_to_all": 3, "find": 1, "reduce": 1},
}
_SAMPLED_BWD_PALLAS = {
    "cgtrans": {"all_gather": 1, "all_to_all": 2, "psum": 2,
                "find": 1, "reduce": 2, "kernel_scatter": 2},
    "baseline": {"all_gather": 1, "all_to_all": 3, "psum": 2,
                 "find": 1, "reduce": 2, "kernel_scatter": 2},
}

for _flow in ("cgtrans", "baseline"):
    for _impl in ("xla", "pallas"):
        _ks = {"kernel_scatter": 1} if _impl == "pallas" else {}
        for _sched in ((False, True) if _impl == "pallas" else (False,)):
            _register(DataflowContract(
                name=(f"aggregate_sampled/{_flow}/{_impl}"
                      + ("/sched" if _sched else "")),
                build=_build_sampled(_flow, _impl, _sched),
                forward=_merge(_SAMPLED_FWD[_flow], _ks),
                fwd_bwd=(None if _sched else
                         _SAMPLED_BWD_PALLAS[_flow] if _impl == "pallas"
                         else _SAMPLED_BWD[_flow]),
                note="scheduled is collective- and dispatch-neutral: the "
                     "banded walk reorders kernel rounds, never traffic"
                     if _sched else ""))

# -- aggregate_multi: the coalesced SSD command block ------------------------
# budgets COMPOSED from the exported SAGE_FETCH tables so the registry and
# the external consumers can never disagree
_MULTI_BWD = {          # fwd+bwd, xla: forward collectives + cotangent a2a
    "cgtrans": {"all_gather": 1, "all_to_all": 2, "find": 1, "reduce": 1},
    "baseline": {"all_gather": 1, "all_to_all": 3, "find": 1, "reduce": 2},
}
_MULTI_BWD_PALLAS = {
    "cgtrans": _merge({"all_gather": 1, "all_to_all": 2, "psum": 2},
                      {"find": 1, "reduce": 2},
                      {"kernel_scatter":
                       SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD["coalesced"]}),
    "baseline": {"all_gather": 1, "all_to_all": 3, "psum": 3,
                 "find": 1, "reduce": 3, "kernel_scatter": 3},
}
_MULTI_FWD = {
    "cgtrans": _merge(SAGE_FETCH_COLLECTIVES["coalesced"],
                      SAGE_FETCH_DISPATCH["coalesced"]),
    "baseline": {"all_gather": 1, "all_to_all": 2, "find": 1, "reduce": 2},
}
_SEP_FWD = {
    "cgtrans": _merge(SAGE_FETCH_COLLECTIVES["separate"],
                      SAGE_FETCH_DISPATCH["separate"]),
    "baseline": {"all_gather": 2, "all_to_all": 4, "find": 2, "reduce": 2},
}

for _flow in ("cgtrans", "baseline"):
    for _impl in ("xla", "pallas"):
        _ks1 = {"kernel_scatter": 1 if _flow == "cgtrans" else 2} \
            if _impl == "pallas" else {}
        for _sched in ((False, True) if _impl == "pallas" else (False,)):
            _register(DataflowContract(
                name=(f"aggregate_multi/{_flow}/{_impl}"
                      + ("/sched" if _sched else "")),
                build=_build_multi(_flow, _impl, _sched),
                forward=_merge(_MULTI_FWD[_flow], _ks1),
                fwd_bwd=(None if _sched else
                         _MULTI_BWD_PALLAS[_flow] if _impl == "pallas"
                         else _MULTI_BWD[_flow])))
        _register(DataflowContract(
            name=f"separate_fetch/{_flow}/{_impl}",
            build=_build_separate_fetch(_flow, _impl),
            forward=_merge(_SEP_FWD[_flow],
                           {"kernel_scatter": 1 if _flow == "cgtrans" else 2}
                           if _impl == "pallas" else {}),
            fwd_bwd=None,
            note="the UN-coalesced twin of aggregate_multi — the pair pins "
                 "the 2 → 1 coalescing claim as two committed budgets"))

# -- serving_fetch: the cross-request fused drain ----------------------------
# the online engine's headline as a lint-time budget: a drain of
# SERVE_CONTRACT_N concurrent callers traces ONE all_gather + ONE
# all_to_all + ONE find — collectives- and finds-per-query 1/N — while the
# one-query-one-dispatch twin pays the full pair N times. Reduces (and
# pallas kernel scatters) are per fan-out segment in BOTH forms: batching
# amortizes the *transmission*, never the per-caller aggregation math.
# Forward-only: serving is inference (no training family differentiates it).
for _impl in ("xla", "pallas"):
    _ksN = ({"kernel_scatter": SERVE_CONTRACT_N}
            if _impl == "pallas" else {})
    _register(DataflowContract(
        name=f"serving_fetch/fused/{_impl}",
        build=_build_serving_fused(_impl, SERVE_CONTRACT_N),
        forward=_merge(SERVE_FETCH_COLLECTIVES["fused"],
                       {"find": SERVE_FETCH_FINDS["fused"],
                        "reduce": SERVE_CONTRACT_N}, _ksN),
        note=f"one drain of N={SERVE_CONTRACT_N} tenant-tagged request "
             f"pairs — the collective pair is N-independent"))
    _register(DataflowContract(
        name=f"serving_fetch/naive/{_impl}",
        build=_build_serving_naive(_impl, SERVE_CONTRACT_N),
        forward=_merge(
            {k: v * SERVE_CONTRACT_N
             for k, v in SERVE_FETCH_COLLECTIVES["naive_per_query"].items()},
            {"find": SERVE_FETCH_FINDS["naive_per_query"] * SERVE_CONTRACT_N,
             "reduce": SERVE_CONTRACT_N}, _ksN),
        note="the one-query-one-dispatch twin: every caller pays the full "
             "collective pair — the fused/naive budgets pin the serving "
             "ratio as committed data"))

# -- sage_forward: the deployed 2-layer fetch --------------------------------
_SAGE_FWD = {
    True: _merge(SAGE_FETCH_COLLECTIVES["coalesced"],
                 SAGE_FETCH_DISPATCH["coalesced"]),
    False: _merge(SAGE_FETCH_COLLECTIVES["separate"],
                  SAGE_FETCH_DISPATCH["separate"]),
}
for _coal in (True, False):
    _form = "coalesced" if _coal else "separate"
    for _impl in ("xla", "pallas"):
        # only the fan-out segment scatters forward (the K=1 self-lookup
        # stays a pure find), so BOTH forms pay exactly one fwd dispatch
        _ks = {"kernel_scatter": 1} if _impl == "pallas" else {}
        for _sched in ((False, True) if _impl == "pallas" else (False,)):
            _register(DataflowContract(
                name=(f"sage_forward/{_form}/{_impl}"
                      + ("/sched" if _sched else "")),
                build=_build_sage(_impl, _coal, _sched),
                forward=_merge(_SAGE_FWD[_coal], _ks),
                # grad w.r.t. PARAMS (args[0]) — the feature cotangent is
                # never requested, so the backward re-ships nothing and the
                # fwd+bwd budget equals the forward one (same invariant the
                # train-step contracts pin)
                fwd_bwd=None if _sched else _merge(_SAGE_FWD[_coal], _ks)))

# -- make_sage_train_step: the full step (grad + AdamW inside) ---------------
# the step differentiates with respect to PARAMS only — feats is a closed
# constant — so the backward adds no fetch collectives: the forward fetch
# budget IS the step budget (plus the pallas forward kernel scatter)
_TRAIN = {
    (True, "xla"): _SAGE_FWD[True],
    (False, "xla"): _SAGE_FWD[False],
    (True, "pallas"): _merge(_SAGE_FWD[True], {"kernel_scatter": 1}),
    (False, "pallas"): _merge(_SAGE_FWD[False], {"kernel_scatter": 1}),
}
for _coal in (True, False):
    _form = "coalesced" if _coal else "separate"
    for _impl in ("xla", "pallas"):
        for _sched in ((False, True) if _impl == "pallas" else (False,)):
            _register(DataflowContract(
                name=(f"train_step/{_form}/{_impl}"
                      + ("/sched" if _sched else "")),
                build=_build_train_step(_impl, _coal, _sched),
                forward=_TRAIN[(_coal, _impl)],
                note="grad w.r.t. params only — feats is a closed-over "
                     "constant, so the backward re-ships nothing"))

# -- embed_lookup: the model-axis storage tier -------------------------------
_register(DataflowContract(
    name="embed_lookup/cgtrans/xla",
    build=_build_embed(True, "xla"),
    forward={"psum": 1},
    fwd_bwd={"psum": 2},
    dtype_waivers=("accum", "narrow-wire"),
    note="bf16 transport by design (compute_dtype=bfloat16): the psum of "
         "bf16 partials is the compressed-wire precursor the ROADMAP "
         "tracks — transport narrow, accumulate-at-owner; waiver documents "
         "it instead of hiding it"))
_register(DataflowContract(
    name="embed_lookup/cgtrans/pallas",
    build=_build_embed(True, "pallas"),
    forward={"psum": 1},
    fwd_bwd={"psum": 2, "reduce": 1, "kernel_scatter": 1},
    dtype_waivers=("accum", "narrow-wire"),
    note="same bf16-transport waiver; the VJP GAS-scatters the cotangent "
         "at the owner shard through the FAST-GAS kernel"))
_register(DataflowContract(
    name="embed_lookup/baseline/xla",
    build=_build_embed(False, "xla"),
    forward={},
    dtype_waivers=("accum",),
    note="plain sharded take — GSPMD materializes table shards at compile "
         "time, so the jaxpr carries zero explicit collectives (the bytes "
         "show up in the HLO benches instead)"))

# -- aggregate_edges: the full-graph COO dataflow ----------------------------
# cgtrans add rides the fused reduce-scatter (canonical name psum_scatter
# WHATEVER the installed JAX calls the primitive); compare ops ship
# per-destination partials over all_to_all; baseline ships all three edge
# streams raw (3 all_gathers)
_EDGES_FWD = {
    ("cgtrans", "add"): {"psum_scatter": 1, "find": 1, "reduce": 1},
    ("cgtrans", "max"): {"all_to_all": 1, "find": 1, "reduce": 1},
    ("baseline", "add"): {"all_gather": 3, "find": 1, "reduce": 1},
    ("baseline", "max"): {"all_gather": 3, "find": 1, "reduce": 1},
}
for _flow in ("cgtrans", "baseline"):
    for _op in ("add", "max"):
        for _impl in ("xla", "pallas"):
            _ks = {"kernel_scatter": 1} if _impl == "pallas" else {}
            _register(DataflowContract(
                name=f"aggregate_edges/{_flow}/{_op}/{_impl}",
                build=_build_edges(_flow, _impl, _op),
                forward=_merge(_EDGES_FWD[(_flow, _op)], _ks)))

# -- compressed wire variants (repro.core.wire) ------------------------------
# the narrow wire changes BYTES, never budgets: each variant's collective
# and dispatch counts equal its f32 twin's (the codec wraps the same one
# all_to_all, forward and backward — custom_vjp, cotangents take the same
# wire; the delta-encoded id stream rides the same one all_gather). The ONE
# exception is aggregate_edges op="add": quantized codes cannot sum on a
# psum_scatter wire (int8 codes carry per-row scales), so the narrow wire
# ships over all_to_all and accumulates in f32 locally — psum_scatter 1→0,
# all_to_all 0→1, pinned here as its own budget. Every variant declares its
# narrowness via the narrow-wire waiver — extend the waiver, never the rule.
_WIRE_NOTE = ("narrow transport by design (repro.core.wire): int16 delta "
              "ids on the all_gather, {w} partials on the all_to_all, f32 "
              "accumulation on arrival — same budget as the f32 twin")
for _w in ("bf16", "int8"):
    _register(DataflowContract(
        name=f"aggregate_sampled/cgtrans/xla/{_w}",
        build=_build_sampled("cgtrans", "xla", False, wire=_w),
        forward=_SAMPLED_FWD["cgtrans"],
        fwd_bwd=_SAMPLED_BWD["cgtrans"],
        dtype_waivers=("narrow-wire",),
        note=_WIRE_NOTE.format(w=_w)))
    _register(DataflowContract(
        name=f"aggregate_multi/cgtrans/xla/{_w}",
        build=_build_multi("cgtrans", "xla", False, wire=_w),
        forward=_MULTI_FWD["cgtrans"],
        fwd_bwd=_MULTI_BWD["cgtrans"],
        dtype_waivers=("narrow-wire",),
        note=_WIRE_NOTE.format(w=_w)))
    _register(DataflowContract(
        name=f"aggregate_edges/cgtrans/add/xla/{_w}",
        build=_build_edges("cgtrans", "xla", "add", wire=_w),
        forward={"all_to_all": 1, "find": 1, "reduce": 1},
        dtype_waivers=("narrow-wire",),
        note="the one budget a narrow wire changes: quantized partials "
             "cannot sum ON the wire, so psum_scatter 1→0 / all_to_all "
             "0→1 with local f32 accumulation — same bytes shape, ÷2 or "
             "÷4 the width"))
_register(DataflowContract(
    name="aggregate_multi/cgtrans/pallas/bf16",
    build=_build_multi("cgtrans", "pallas", False, wire="bf16"),
    forward=_merge(_MULTI_FWD["cgtrans"], {"kernel_scatter": 1}),
    fwd_bwd=_MULTI_BWD_PALLAS["cgtrans"],
    dtype_waivers=("narrow-wire",),
    note="the kernel path under the narrow wire: codec wraps the "
         "collective only, so the FAST-GAS dispatch budget (fwd scatter + "
         "bwd cotangent scatter) is untouched"))
_register(DataflowContract(
    name="serving_fetch/fused/xla/bf16",
    build=_build_serving_fused("xla", SERVE_CONTRACT_N, wire="bf16"),
    forward=_merge(SERVE_FETCH_COLLECTIVES["fused"],
                   {"find": SERVE_FETCH_FINDS["fused"],
                    "reduce": SERVE_CONTRACT_N}),
    dtype_waivers=("narrow-wire",),
    note=f"the serving drain on the bf16 wire (ServingEngine(wire=)): "
         f"N={SERVE_CONTRACT_N} fused callers, collective pair still "
         f"N-independent, bytes halved"))

# -- compressed-sparse feature variants (repro.core.sparse) ------------------
# like the narrow wire, the format changes BYTES, never budgets: the sparse
# gather is two takes instead of one (both inside the SAME ticked find) and
# the baseline raw-row shipment packs (nonzeros ‖ bitmap) through the SAME
# one all_to_all — so every sparse variant's collective and dispatch counts
# equal its dense twin's, forward AND backward (the sparse-gather VJP
# scatters the dense cotangent with the identical reduce/kernel_scatter
# pattern, and _sparse_all_to_all's VJP ships the dense cotangent over one
# all_to_all exactly like the dense transpose).
_SPARSE_NOTE = ("compressed-sparse features by design (repro.core.sparse): "
                "packed nonzeros + int32 occupancy bitmap on the {leg}, "
                "static capacity {cap} of F={f} — same budget as the dense "
                "twin")
_register(DataflowContract(
    name="aggregate_sampled/cgtrans/xla/sparse",
    build=_build_sampled("cgtrans", "xla", False, features="sparse"),
    forward=_SAMPLED_FWD["cgtrans"],
    fwd_bwd=_SAMPLED_BWD["cgtrans"],
    note=_SPARSE_NOTE.format(leg="table gather", cap=_SPARSE_CAP, f=_F)))
_register(DataflowContract(
    name="aggregate_sampled/cgtrans/pallas/sparse",
    build=_build_sampled("cgtrans", "pallas", False, features="sparse"),
    forward=_merge(_SAMPLED_FWD["cgtrans"], {"kernel_scatter": 1}),
    fwd_bwd=_SAMPLED_BWD_PALLAS["cgtrans"],
    note=_SPARSE_NOTE.format(leg="table gather", cap=_SPARSE_CAP, f=_F)
         + "; the sparse-gather VJP scatters through the FAST-GAS kernel "
           "like the dense pallas gather"))
_register(DataflowContract(
    name="aggregate_sampled/baseline/xla/sparse",
    build=_build_sampled("baseline", "xla", False, features="sparse"),
    forward=_SAMPLED_FWD["baseline"],
    fwd_bwd=_SAMPLED_BWD["baseline"],
    note=_SPARSE_NOTE.format(leg="table gather AND the raw-row all_to_all",
                             cap=_SPARSE_CAP, f=_F)))
_register(DataflowContract(
    name="aggregate_multi/cgtrans/xla/sparse",
    build=_build_multi("cgtrans", "xla", False, features="sparse"),
    forward=_MULTI_FWD["cgtrans"],
    fwd_bwd=_MULTI_BWD["cgtrans"],
    note=_SPARSE_NOTE.format(leg="combined table gather", cap=_SPARSE_CAP,
                             f=_F)))
_register(DataflowContract(
    name="aggregate_edges/cgtrans/add/xla/sparse",
    build=_build_edges("cgtrans", "xla", "add", features="sparse"),
    forward=_EDGES_FWD[("cgtrans", "add")],
    note=_SPARSE_NOTE.format(leg="edge-source gather", cap=_SPARSE_CAP, f=_F)
         + "; partials have UNION support so the psum_scatter shipment "
           "stays dense — unlike the narrow wire, add keeps its budget"))
_register(DataflowContract(
    name="aggregate_sampled/baseline/xla/sparse-bf16",
    build=_build_sampled("baseline", "xla", False, wire="bf16",
                         features="sparse"),
    forward=_SAMPLED_FWD["baseline"],
    fwd_bwd=_SAMPLED_BWD["baseline"],
    dtype_waivers=("narrow-wire",),
    note="the composition the formats were built for: baseline + narrow "
         "wire is ONLY legal with sparse features (packed nonzeros "
         "quantize like partials — bf16 codes + bitcast bitmap lanes on "
         "the raw-row all_to_all), still the dense twin's budget"))


#: every (entrypoint, dataflow-or-form, impl) the meta-test asserts coverage
#: for — adding a config to a dataflow without registering its contract
#: fails tests/test_analysis.py, not code review
def covered_configurations() -> List[str]:
    return sorted(CONTRACTS)
