"""Dtype-flow checks over traced jaxprs (the semantic half of the lint).

Three rules, each motivated by a repo invariant:

* ``f64`` — no float64 anywhere. The stack is f32-accumulation /
  low-precision-transport by design; an f64 aval means an accidental
  promotion (a Python float leaking through ``jnp.asarray`` under x64, a
  ``np.float64`` literal crossing into a trace) that silently doubles every
  byte the collective-bytes benches count.
* ``accum`` — reductions must accumulate in f32 (or wider ints). A
  ``dot_general`` producing bf16/f16, or a sum-reduction
  (``reduce_sum``/``psum``/``psum_scatter``/``add_any``) over bf16/f16
  operands, accumulates in the narrow type. This is the groundwork for the
  ROADMAP's compressed wire format: transport may be bf16/int8, but the
  *accumulation* stays f32 — a contract may waive this per-entrypoint
  (``dtype_waivers``) where narrow transport is the point (see
  ``embed_lookup``), which documents the exception instead of hiding it.
* ``unsigned-wire`` — the id/request streams are SIGNED end-to-end: the
  ``-1`` mask encoding of ``cgtrans._encode_requests`` and the dead-row
  convention of the FAST-GAS kernel both rely on ``id < 0`` surviving every
  hop. An unsigned aval entering a collective (the wire) or indexing a
  gather/scatter (the engine) means some cast re-encoded ``-1`` as 2³²−1 —
  numerically "in range" after a modular clip and therefore silently wrong.
  Unsigned values in *local arithmetic* (e.g. XLA's unsigned div idiom
  inside schedule math) are fine and not flagged.
* ``narrow-wire`` — a sub-32-bit payload entering a collective (bf16/f16
  partials, int8 quantized blocks, int16 delta-encoded id streams) is a
  LOSSY or re-encoded transport and must be a declared decision, never an
  accident: every contract whose dataflow compresses its wire
  (``repro.core.wire``; ``embed_lookup``'s bf16 psum) carries
  ``dtype_waivers=("narrow-wire", …)`` naming it. An unwaived narrow
  collective means a cast leaked into a wire that claims f32 — exactly the
  silent-precision-loss this tier exists to catch. Bools are exempt (the
  baseline dataflow legitimately ships 1-bit ownership masks; there is no
  narrower encoding to drift to).

``check_dtype_flow`` walks a jaxpr recursively through every sub-jaxpr
(pjit/shard_map/scan/custom-vjp branches) — same traversal contract as
``launch/jaxpr_stats`` — and returns a list of ``DtypeIssue``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp

from repro.compat import canonical_collective

#: every rule this module can emit (contracts reference these in waivers)
RULES = ("f64", "accum", "unsigned-wire", "narrow-wire")

#: sum-accumulating primitives: reducing a narrow float through these
#: accumulates in the narrow type (max/min are order statistics — no
#: accumulator — so bf16 pmax is precision-lossless and not flagged)
_SUM_REDUCTIONS = ("reduce_sum", "psum", "psum_scatter", "add_any")

#: primitives whose second operand is an index stream into a table
_INDEXED = ("gather", "scatter", "scatter-add", "scatter_add", "scatter-max",
            "scatter-min", "scatter-mul", "dynamic_gather")

_NARROW_FLOATS = (jnp.bfloat16, jnp.float16)


@dataclasses.dataclass(frozen=True)
class DtypeIssue:
    rule: str           # one of RULES
    primitive: str      # jaxpr primitive that exhibits it
    detail: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.primitive}: {self.detail}"


def _avals(vars_) -> List[Tuple[str, object]]:
    out = []
    for v in vars_:
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None:
            out.append((str(dt), dt))
    return out


def _sub_jaxprs(value):
    if hasattr(value, "eqns"):
        yield value
    elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _sub_jaxprs(v)


def _is_narrow_float(dt) -> bool:
    return any(dt == n for n in _NARROW_FLOATS)


def _is_unsigned(dt) -> bool:
    return jnp.issubdtype(dt, jnp.unsignedinteger)


def _is_narrow_wire(dt) -> bool:
    """Sub-32-bit non-bool payload: lossy/re-encoded on a collective unless
    a contract declares it (bools are the baseline's legitimate 1-bit
    ownership masks — nothing narrower exists to drift to)."""
    if dt == jnp.bool_:
        return False
    itemsize = getattr(jnp.dtype(dt), "itemsize", 4)
    return itemsize < 4


def check_dtype_flow(jaxpr, *, waive: Sequence[str] = ()) -> List[DtypeIssue]:
    """All dtype-flow issues in ``jaxpr`` (a ``Jaxpr`` or ``ClosedJaxpr``),
    recursing into sub-jaxprs. ``waive`` drops the named rules — contracts
    use it to document intentional exceptions (e.g. bf16 transport)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    waived = frozenset(waive)
    for w in waived:
        if w not in RULES:
            raise ValueError(f"unknown dtype rule {w!r} (have {RULES})")
    issues: List[DtypeIssue] = []
    stack = [jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            prim = eqn.prim.name if hasattr(eqn, "prim") else eqn.primitive.name
            in_avals = _avals(eqn.invars)
            out_avals = _avals(eqn.outvars)

            if "f64" not in waived:
                for name, dt in in_avals + out_avals:
                    if name == "float64":  # lint: allow(f64-literal): the rule that bans it must name it
                        issues.append(DtypeIssue(
                            "f64", prim, "float64 aval in the traced program "
                            "(f32-accumulation stack — find the promotion)"))
                        break

            if "accum" not in waived:
                if prim == "dot_general" and out_avals and _is_narrow_float(
                        out_avals[0][1]):
                    issues.append(DtypeIssue(
                        "accum", prim,
                        f"contraction accumulates in {out_avals[0][0]} — "
                        f"request preferred_element_type=float32"))
                canon = canonical_collective(prim) or prim
                if canon in _SUM_REDUCTIONS:
                    for name, dt in in_avals:
                        if _is_narrow_float(dt):
                            issues.append(DtypeIssue(
                                "accum", prim,
                                f"sum-reduction over {name} accumulates in "
                                f"{name}, not f32"))
                            break

            if "unsigned-wire" not in waived:
                if canonical_collective(prim) is not None:
                    for name, dt in in_avals + out_avals:
                        if _is_unsigned(dt):
                            issues.append(DtypeIssue(
                                "unsigned-wire", prim,
                                f"{name} id/payload stream on the wire — the "
                                f"-1 mask encoding needs signed ints"))
                            break
                elif prim in _INDEXED and len(eqn.invars) >= 2:
                    idx = _avals(eqn.invars[1:2])
                    if idx and _is_unsigned(idx[0][1]):
                        issues.append(DtypeIssue(
                            "unsigned-wire", prim,
                            f"{idx[0][0]} index stream into {prim} — the "
                            f"dead-row convention needs id < 0 representable"))

            if "narrow-wire" not in waived:
                if canonical_collective(prim) is not None:
                    for name, dt in in_avals:
                        if _is_narrow_wire(dt):
                            issues.append(DtypeIssue(
                                "narrow-wire", prim,
                                f"{name} payload on the wire — narrow "
                                f"transport must be declared via a "
                                f"dtype_waivers=('narrow-wire',) contract"))
                            break
            for v in eqn.params.values():
                stack.extend(_sub_jaxprs(v))
    return issues
