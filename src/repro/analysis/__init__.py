"""Static analysis over the repo's two program representations.

* **jaxpr layer** (``contracts``, ``dtype_flow``): every dataflow
  entrypoint carries a committed ``DataflowContract`` — its exact collective
  counts (canonical names via ``repro.compat``), its GAS dispatch budget
  (find / reduce / kernel-scatter, forward vs. forward+backward), and its
  dtype-flow waivers. ``verify_contract`` traces the entrypoint
  *abstractly* (``jax.make_jaxpr`` over ``ShapeDtypeStruct`` arguments — no
  execution, no device transfers, runs on headless CI) and checks the
  traced program against the budget.
* **AST layer** (``source_lint``): mechanical repo invariants the jaxpr
  can't see — the compat single-door rule, kernel-dispatch tick coverage,
  pytest marker registration, bare f64 literals.

``scripts/lint.py`` runs both layers; ``scripts/ci.sh --tier lint`` is the
CI lane. The contract tables here are the single source of truth for the
coalescing budgets — ``tests/test_cgtrans_coalesce.py``,
``tests/distributed_cases.py`` and ``benchmarks/collective_bytes.py``
import them instead of hand-duplicating the numbers.
"""

from repro.analysis.contracts import (  # noqa: F401
    CONTRACTS,
    DataflowContract,
    SAGE_FETCH_COLLECTIVES,
    SAGE_FETCH_DISPATCH,
    SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD,
    verify_contract,
)
from repro.analysis.dtype_flow import check_dtype_flow  # noqa: F401
from repro.analysis.source_lint import lint_file, lint_repo  # noqa: F401
