"""gemma2-2b [dense] — local+global alternating attention with logit softcaps.

[arXiv:2408.00118; hf]. Window 4096 on even (local) layers; attn softcap 50,
final softcap 30; pre+post norms; query_pre_attn_scalar = 256.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    pattern=("local", "attn"),
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_pre_attn_scalar=256.0,
    post_norms=True,
    rms_zero_centered=True,
    embed_scale=True,
    act="gelu",
    cgtrans_embedding=True,   # 256k vocab
)
