"""llama-3.2-vision-90b [vlm] — 100L incl. 20 cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. Backbone only; the vision
frontend is a STUB: input_specs provide precomputed patch embeddings
(B, vision_seq, d_model). Cross-attn layers sit at i % 5 == 3 (20 of 100).
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    pattern=("attn", "attn", "attn", "cross", "attn"),
    rope_theta=500000.0,
    vision_seq=1024,
    tie_embeddings=False,
    cgtrans_embedding=True,   # 128k vocab — CGTrans owner-aggregated embedding
)
