"""qwen1.5-0.5b [dense] — plain GQA (kv=heads) transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf].
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab=151936,
    pattern=("attn",),
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    cgtrans_embedding=True,   # 152k vocab
)
