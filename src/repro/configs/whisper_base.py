"""whisper-base [audio] — encoder-decoder; conv frontend is a STUB.

[arXiv:2212.04356; unverified]. input_specs provide precomputed frame
embeddings (B, 1500, 512). LayerNorm, plain GELU MLP, biases everywhere.
Decode shapes run a 32k decoder cache (structural stretch of the 448-pos
trained decoder — documented in DESIGN §4). The paper's technique is NOT
wired here (DESIGN §5: no sparse gather hotspot).
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    pattern=("dec",),
    is_encoder_decoder=True,
    n_enc_layers=6,
    enc_seq=1500,
    norm_type="ln",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    act="gelu",
    tie_embeddings=True,
    cgtrans_embedding=False,  # inapplicable (DESIGN §5)
)
