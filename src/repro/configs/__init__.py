"""Architecture registry: the 10 assigned pool configs + the paper's GCN.

``get_config(arch_id)`` resolves the exact assigned configuration;
``SKIP_CELLS`` documents the (arch × shape) cells excluded per the
assignment's sub-quadratic rule (reasons in DESIGN §4).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.common.config import SHAPES, ModelConfig, ShapeConfig, reduced

from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.llama_3_2_vision_90b import CONFIG as _llama_vis
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.whisper_base import CONFIG as _whisper

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _llama_vis, _rgemma, _qwen, _gemma2, _phi3, _gemma3,
        _moonshot, _deepseek, _whisper, _mamba2,
    )
}

ARCHS: List[str] = list(REGISTRY)

# long_500k requires sub-quadratic context handling; pure full-attention
# archs are skipped per the assignment (noted in DESIGN §4).
_FULL_ATTN = ("llama-3.2-vision-90b", "qwen1.5-0.5b", "phi3-medium-14b",
              "moonshot-v1-16b-a3b", "deepseek-moe-16b", "whisper-base")
SKIP_CELLS: Dict[Tuple[str, str], str] = {
    (a, "long_500k"): "pure full-attention arch — 500k decode cache is "
                      "quadratic-history; skipped per assignment"
    for a in _FULL_ATTN
}
SKIP_CELLS[("whisper-base", "long_500k")] = (
    "enc-dec with 1.5k-frame encoder and full-attention decoder; 500k decode "
    "context is architecturally meaningless — skipped per assignment")


def get_config(arch: str) -> ModelConfig:
    cfg = REGISTRY[arch]
    cfg.validate()
    return cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_skipped: bool = False) -> List[Tuple[str, str]]:
    """All 40 assigned (arch × shape) cells, minus documented skips."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if include_skipped or (a, s) not in SKIP_CELLS:
                out.append((a, s))
    return out


def smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


__all__ = ["REGISTRY", "ARCHS", "SKIP_CELLS", "get_config", "get_shape",
           "cells", "smoke_config"]
