"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]. d_inner = 2·1536 = 3072, 48 heads × 64,
state 128, chunked-SSD scan (chunk 256). The paper's GAS technique is
inapplicable to the mixer (attention-free; DESIGN §Arch-applicability);
vocab 50280 is below the CGTrans-embedding win threshold and not 16-divisible
→ plain sharded embedding.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,           # = d_inner / ssm_head_dim (bookkeeping only)
    n_kv_heads=48,
    head_dim=64,
    d_ff=0,               # SSD layers have no separate FFN
    vocab=50280,
    pattern=("ssd",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,          # §Perf M1: halved — the (B,L,L,H) intra-chunk
                            # tensors dominate HBM traffic (∝ L per token)
    conv_kernel=4,
    block_repeat=2,           # §Perf M2: 24 blocks of 2 — halves the
                              # backward working set (stored block inputs
                              # stay small; bwd replays 2 layers not 4)
    cgtrans_embedding=False,
)
