"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]. Window 1024 local; global layers use
rope theta 1M (dual-rope); qk-norm; pre+post norms.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    rope_theta=10000.0,
    rope_theta_global=1000000.0,
    query_pre_attn_scalar=256.0,
    qk_norm=True,
    post_norms=True,
    rms_zero_centered=True,
    embed_scale=True,
    act="gelu",
    cgtrans_embedding=True,   # 262k vocab — the biggest CGTrans embedding case
)
