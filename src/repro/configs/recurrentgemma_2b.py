"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427; hf]. 26 layers = 8×(rec, rec, attn) + 2 trailing rec.
MQA (kv=1), window 2048, GeGLU MLP, gemma-style norms/embedding scale.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=2560,
    act="gelu",
    embed_scale=True,
    rms_zero_centered=True,
    rope_theta=10000.0,
    cgtrans_embedding=True,   # 256k vocab
)
