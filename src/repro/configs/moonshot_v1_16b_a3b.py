"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 64 experts top-6 + 2 shared.

[hf:moonshotai/Moonlight-16B-A3B; hf]. First layer dense (width 8·d_ff,
derived — the assignment pins the expert width 1408).
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    pattern=("moe",),
    first_k_dense=1,
    d_ff_dense=11264,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    cgtrans_embedding=True,
    cgtrans_moe=True,         # combine-at-expert compressed all-to-all
)
