"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.

[arXiv:2401.06066; hf]. First layer dense FFN (10944, per the release).
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    pattern=("moe",),
    first_k_dense=1,
    d_ff_dense=10944,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    cgtrans_embedding=True,
    cgtrans_moe=True,
)
