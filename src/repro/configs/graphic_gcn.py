"""The paper's own workload: GraphSAGE/GCN over Table-II-scale graphs.

Fan-out 50 per the paper §4.2 ("GraphSAGE samples 50 neighbors at a time
according to the general setup"); feature widths from Table II.

``impl`` / ``request_chunk`` / ``coalesce`` are the FAST-GAS deployment
knobs surfaced from ``repro.core.cgtrans``: ``impl="pallas"`` runs every
per-shard aggregation through the in-SSD kernel (interpret-mode off-TPU),
``request_chunk`` is the SSD command-queue depth — the sampled dataflow
streams its id block through the collectives that many seeds at a time,
bounding per-shard peak gather memory — and ``coalesce`` fuses
``sage_forward``'s self-row lookup and 2-hop aggregation into ONE command
block (``aggregate_multi``): one request broadcast, one kernel gather, one
result shipment, one backward cotangent scatter per step. Both backends train end-to-end: the
kernel carries custom VJPs whose backward is itself GAS work
(``repro.core.gas``), so ``PALLAS_CONFIG`` is a full training deployment,
not just the inference/benchmark one — gradient parity with ``CONFIG`` is
asserted by ``tests/test_cgtrans_grad.py``.
"""

import dataclasses

from repro.core.gcn import GCNConfig

# Reddit-like (the paper's end-to-end Fig 16(c) dataset)
CONFIG = GCNConfig(
    n_features=602,
    hidden=256,
    n_classes=41,      # Reddit's subreddit-classification arity
    fanout=50,
    aggregate="add",
    dataflow="cgtrans",
    n_layers=2,
    impl="xla",        # oracle backend (training default)
    request_chunk=None,  # unchunked: one request burst per batch
    coalesce=True,     # sage_forward's self-lookup + 2-hop requests ride
                       # ONE SSD command block (collectives-per-step 2 → 1;
                       # the default — spelled out because it IS the
                       # paper's command-queue batching)
    partition="interval",  # contiguous-id vertex layout (the oracle layout;
                           # ISLAND_PALLAS_CONFIG below switches it)
)

# The deployed FAST-GAS configuration: Pallas kernel aggregation + a 16-seed
# command queue (peak gather memory ∝ 16·K·F instead of B_loc·K·F) + the
# destination-binned edge schedule (``scheduled=True`` — the Fig 11(c)
# locality pass that collapses the idle-skip occupancy to a band so the
# kernel actually skips; it would default on for impl="pallas" anyway, and
# is spelled out here because it IS the deployment). Trains end-to-end — the
# kernel's custom VJPs keep the backward in-SSD too, reusing the schedule.
PALLAS_CONFIG = dataclasses.replace(CONFIG, impl="pallas", request_chunk=16,
                                    scheduled=True)

# The locality deployment: FAST-GAS kernel + islandized vertex layout
# (``repro.graph.partition.islandize`` — BFS islands, boundary-refined,
# packed into the shard intervals). Callers partition the graph with
# ``partition_graph(g, P, method="island")`` and hand the returned
# ``IslandPartition.relabel`` to ``sage_forward`` / ``gcn_forward_full`` /
# ``make_sage_train_step`` (``ServingEngine(partition="island")`` does all
# of this internally). Fewer remote all_to_all destination rows and a near
# block-diagonal idle-skip occupancy on community graphs, bit-exact with
# PALLAS_CONFIG (the `part` tier's parity matrix).
ISLAND_PALLAS_CONFIG = dataclasses.replace(PALLAS_CONFIG, partition="island")

# per-dataset feature widths (Table II) for benchmarks
TABLE_II_GCN = {
    "Reddit": CONFIG,
    "Movielens": GCNConfig(n_features=1000, hidden=256, n_classes=32, fanout=50),
    "Amazon": GCNConfig(n_features=32, hidden=256, n_classes=32, fanout=50),
    "OGBN-100M": GCNConfig(n_features=32, hidden=256, n_classes=172, fanout=50),
    "Protein-PI": GCNConfig(n_features=512, hidden=256, n_classes=16, fanout=50),
}
