"""Classic graph algorithms on the GAS engine (paper §3.4, Fig 13).

The paper runs BFS, SSSP, CC and sorting as find-and-compute loops on the
CAM + FAST SRAM pair. Here each algorithm is the same loop over the GAS
primitives (match → row-parallel reduce), with ``lax.while_loop`` as the
fixed-point driver — fully jittable, device-resident, and validated against
networkx oracles in tests.

All take COO edge arrays and return dense per-vertex results.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.gas import gas_gather, gas_scatter

INF = jnp.float32(jnp.inf)


def sssp(src: jax.Array, dst: jax.Array, weights: jax.Array, n_vertices: int,
         source: int, *, impl: str = "xla", max_iters: int = 0) -> jax.Array:
    """Bellman-Ford SSSP — the paper's add-then-min GAS atom, iterated.

    Each round: gather dist[src] (find), add edge weight (1-bit-ALU add),
    scatter-min into dst rows (row-parallel min update).
    """
    max_iters = max_iters or n_vertices
    dist0 = jnp.full((n_vertices,), INF).at[source].set(0.0)

    def body(carry):
        it, dist, _ = carry
        relax = gas_gather(dist, src) + weights
        best = gas_scatter(dst, relax, n_vertices, op="min", impl=impl)
        new = jnp.minimum(dist, best)
        return it + 1, new, jnp.any(new < dist)

    def cond(carry):
        it, _, changed = carry
        return changed & (it < max_iters)

    _, dist, _ = lax.while_loop(cond, body, (0, dist0, jnp.bool_(True)))
    return dist


def bfs(src: jax.Array, dst: jax.Array, n_vertices: int, source: int,
        *, impl: str = "xla", max_iters: int = 0) -> jax.Array:
    """BFS levels = SSSP with unit weights (paper deploys BFS this way)."""
    return sssp(src, dst, jnp.ones_like(src, jnp.float32), n_vertices, source,
                impl=impl, max_iters=max_iters)


def connected_components(src: jax.Array, dst: jax.Array, n_vertices: int,
                         *, impl: str = "xla", max_iters: int = 0) -> jax.Array:
    """Min-label propagation (paper's CC: find-and-update the minimum among
    matched rows). Edges are treated as undirected. Returns component labels
    (the minimum vertex id of each component)."""
    max_iters = max_iters or n_vertices
    s = jnp.concatenate([src, dst])
    d = jnp.concatenate([dst, src])
    labels0 = jnp.arange(n_vertices, dtype=jnp.float32)

    def body(carry):
        it, lab, _ = carry
        prop = gas_scatter(d, gas_gather(lab, s), n_vertices, op="min", impl=impl)
        new = jnp.minimum(lab, prop)
        return it + 1, new, jnp.any(new < lab)

    def cond(carry):
        it, _, changed = carry
        return changed & (it < max_iters)

    _, labels, _ = lax.while_loop(cond, body, (0, labels0, jnp.bool_(True)))
    return labels.astype(jnp.int32)


def gas_sort(x: jax.Array, *, impl: str = "xla") -> jax.Array:
    """The paper's fully-concurrent insert sort, re-expressed.

    FAST-GAS compares the pivot against *all* rows at once and popcounts the
    flags with the SFU adder tree to find the pivot's rank — O(n) rounds of
    O(1) parallel work. On TPU the all-rows compare of *all* pivots is one
    broadcast compare (the same silicon trick, width-first):
        rank_i = Σ_j [x_j < x_i] + Σ_j [x_j == x_i ∧ j < i]   (stable)
    then one GAS scatter places every value at its rank row in parallel.
    """
    n = x.shape[0]
    lt = (x[None, :] < x[:, None]).astype(jnp.int32)
    eq = (x[None, :] == x[:, None]) & (jnp.arange(n)[None, :] < jnp.arange(n)[:, None])
    rank = lt.sum(1) + eq.astype(jnp.int32).sum(1)
    return gas_scatter(rank, x, n, op="add", impl=impl)


def feature_embedding(src: jax.Array, dst: jax.Array, weights: jax.Array,
                      feats: jax.Array, *, op: str = "add",
                      impl: str = "xla") -> jax.Array:
    """Paper Fig 12: aggregation (feature embedding) over a COO graph —
    out[v] = reduce_{(u,v,w)} w·feats[u]. The GCN aggregation atom."""
    vals = gas_gather(feats, src)
    if op == "add":
        vals = vals * weights[:, None].astype(vals.dtype)
    return gas_scatter(dst, vals, feats.shape[0], op=op, impl=impl)
