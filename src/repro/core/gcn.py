"""GCN / GraphSAGE models on the CGTrans substrate (the paper's workload).

Two entry styles:

* ``gcn_forward_full`` — full-graph GCN layers (aggregation = CGTrans edge
  dataflow, combination = tensor-parallel matmul). Used by correctness tests
  and the full-graph benchmarks.
* ``sage_*`` — minibatch GraphSAGE (fan-out sampling, the paper's deployed
  algorithm §4.2). Vertex features live **owner-sharded on the storage tier**
  (never shipped raw under CGTrans); the training batch carries only ids.
  Layer-1's remote feature aggregation is the distributed step; deeper layers
  compute on the locally-materialized subgraph (standard practice).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.common.schema import ParamDef
from repro.core import cgtrans


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    n_features: int
    hidden: int = 128
    n_classes: int = 16
    fanout: int = 50             # paper: GraphSAGE samples 50 neighbors
    aggregate: str = "add"       # add | max  (paper: sum and max are common)
    dataflow: str = "cgtrans"    # cgtrans | baseline
    n_layers: int = 2
    impl: str = "xla"            # xla | pallas — GAS backend for aggregation
    request_chunk: Optional[int] = None  # SSD command-queue depth (seeds per
                                         # sampled-aggregation request burst)
    scheduled: Optional[bool] = None     # destination-binned edge schedule
                                         # (idle-skip locality pass); None →
                                         # on exactly when impl="pallas"
    coalesce: bool = True                # fuse sage_forward's self-row
                                         # lookup + 2-hop aggregation into
                                         # ONE SSD command block (one
                                         # all_gather/all_to_all/kernel
                                         # gather/backward scatter); False
                                         # = the legacy two-body form
    wire: str = "f32"                    # collective transport format
                                         # (repro.core.wire): f32 | bf16 |
                                         # int8 — quantized partials +
                                         # delta-encoded id streams, f32
                                         # accumulation always (cgtrans
                                         # dataflow only)
    features: str = "dense"              # feature transport format
                                         # (repro.core.sparse): dense |
                                         # sparse — compressed-sparse rows
                                         # (occupancy bitmap + packed
                                         # nonzeros) on the table gather
                                         # and the baseline raw-row
                                         # shipment; requires
                                         # sparse_capacity
    sparse_capacity: Optional[int] = None  # static packed width for
                                         # features="sparse" — measure it
                                         # once per table with
                                         # sparse.table_capacity(feats)
    partition: str = "interval"          # host-side vertex layout
                                         # (repro.graph.partition): interval
                                         # = contiguous-id split | island =
                                         # islandized locality relabeling —
                                         # callers partition via
                                         # partition_graph(method="island")
                                         # and pass the IslandPartition's
                                         # relabel map to sage_forward /
                                         # gcn_forward_full, which translate
                                         # ids in and un-permute full-graph
                                         # outputs back to original vertex
                                         # order (islandized ≡ interval
                                         # bit-exact)


def gcn_schema(cfg: GCNConfig) -> Dict[str, Any]:
    F, H, C = cfg.n_features, cfg.hidden, cfg.n_classes
    s: Dict[str, Any] = {}
    d_in = F
    for i in range(cfg.n_layers):
        d_out = H
        # SAGE concat [self ‖ aggregated] → weight is (2·d_in, d_out)
        s[f"w{i}"] = ParamDef((2 * d_in, d_out), ("embed", "ff"), init="lecun")
        s[f"b{i}"] = ParamDef((d_out,), ("ff",), init="zeros")
        d_in = H
    s["w_out"] = ParamDef((d_in, C), ("embed", None), init="lecun")
    s["b_out"] = ParamDef((C,), (None,), init="zeros")
    return s


# ---------------------------------------------------------------------------
# full-graph GCN
# ---------------------------------------------------------------------------

def _check_partition_knob(cfg: GCNConfig, relabel) -> None:
    """``cfg.partition`` and the relabel map travel together or not at all:
    an islandized partition without the map (or vice versa) would silently
    aggregate the wrong rows, so mismatches fail loudly at trace time."""
    if cfg.partition not in ("interval", "island"):
        raise ValueError(f"unknown cfg.partition {cfg.partition!r} "
                         "(expected 'interval' or 'island')")
    if (cfg.partition == "island") != (relabel is not None):
        raise ValueError(
            "cfg.partition='island' requires the IslandPartition relabel map "
            "(relabel=isl.relabel), and relabel= requires partition='island' "
            f"— got partition={cfg.partition!r}, "
            f"relabel={'set' if relabel is not None else 'None'}")


def gcn_forward_full(params, feats, src_local, dst_global, weights, mask,
                     cfg: GCNConfig, *, mesh: Optional[Mesh] = None,
                     impl: Optional[str] = None, relabel=None):
    """feats: (P, part, F) owner-sharded. Returns (P, part, C) logits.

    ``impl`` overrides ``cfg.impl`` when given (the benchmarks sweep it).
    The destination-binned edge schedule is computed ONCE here and reused by
    every layer's aggregation (and, as a VJP residual, by the backward
    pass) — the paper's idle-skip buffer content is per (partition, batch),
    not per layer.

    With ``cfg.partition="island"`` the inputs live in the islandized id
    space (``partition_graph(..., method="island")``) and ``relabel`` is the
    old→new map; the output is un-permuted back so row ``v`` of the
    flattened result is original vertex ``v``'s logits (pad rows zeroed),
    making islandized ≡ interval bit-exact row-for-row over ``[0, V)``.
    """
    _check_partition_knob(cfg, relabel)
    impl_r = impl or cfg.impl
    use_sched = (impl_r == "pallas") if cfg.scheduled is None else cfg.scheduled
    sched, applied = None, False
    # (the sharded baseline flow bins AFTER raw assembly in its own row
    # space — a precomputed V-space schedule would be dead work there)
    if use_sched and (cfg.dataflow == "cgtrans"
                      or not cgtrans.is_sharded(mesh)):
        sched = cgtrans.build_edge_schedule(
            dst_global, mask, feats.shape[0] * feats.shape[1], mesh=mesh)
        if cgtrans.is_sharded(mesh):
            # pay the edge-list permutation once at partition time too —
            # every layer then consumes the binned list directly
            src_local, dst_global, weights, mask = cgtrans.apply_edge_schedule(
                sched, src_local, dst_global, weights, mask)
            applied = True
    h = feats
    for i in range(cfg.n_layers):
        agg = cgtrans.aggregate_edges(
            h, src_local, dst_global, weights, mask,
            mesh=mesh, dataflow=cfg.dataflow, op=cfg.aggregate,
            impl=impl_r, scheduled=use_sched, schedule=sched,
            schedule_applied=applied, wire=cfg.wire,
            # sparse only where the gather reads the RAW table: layer-0
            # rows are post-ReLU-style sparse inputs, deeper layers' h are
            # dense activations whose measured capacity would be F anyway
            features=cfg.features if i == 0 else "dense",
            sparse_capacity=cfg.sparse_capacity if i == 0 else None)
        if cfg.aggregate in ("max", "min"):
            # vertices with no in-edges hold the ±inf identity; mask before
            # the combine so neither the forward nor the cotangent meets inf
            agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
        h = jnp.concatenate([h, agg], axis=-1)
        h = jax.nn.relu(jnp.einsum("pvf,fh->pvh", h, params[f"w{i}"]) + params[f"b{i}"])
    out = jnp.einsum("pvh,hc->pvc", h, params["w_out"]) + params["b_out"]
    if relabel is not None:
        # un-permute: islandized row relabel[v] holds original vertex v.
        # Interval mode places vertex v at flat row v exactly (owner = v //
        # part, local = v % part), so after this gather the two layouts
        # agree row-for-row on [0, V); the replicated gather stays off the
        # data axis (host-permutation bookkeeping, not a collective).
        P_, psz, C = out.shape
        flat = out.reshape(P_ * psz, C)
        orig = jnp.take(flat, jnp.asarray(relabel, jnp.int32), axis=0)
        flat = jnp.zeros_like(flat).at[: orig.shape[0]].set(orig)
        out = flat.reshape(P_, psz, C)
    return out


# ---------------------------------------------------------------------------
# minibatch GraphSAGE
# ---------------------------------------------------------------------------

def lookup_rows(feats, ids, *, mesh=None, dataflow="cgtrans", impl="xla",
                request_chunk=None, scheduled=None, wire="f32",
                features="dense", sparse_capacity=None):
    """Distributed row lookup: ids (P, B_loc) → (P, B_loc, F)."""
    nbrs = ids[..., None]
    mask = jnp.ones_like(nbrs, dtype=bool)
    return cgtrans.aggregate_sampled(feats, nbrs, mask, mesh=mesh,
                                     dataflow=dataflow, impl=impl,
                                     request_chunk=request_chunk,
                                     scheduled=scheduled, wire=wire,
                                     features=features,
                                     sparse_capacity=sparse_capacity)


def sage_forward(params, feats, batch, cfg: GCNConfig, *,
                 mesh: Optional[Mesh] = None, relabel=None):
    """2-layer minibatch GraphSAGE.

    batch (all seed-sharded on the data axis, leading dim P):
      seeds (P, B)            seed vertex ids
      nbrs1 (P, B, K1)        1-hop samples
      mask1 (P, B, K1)
      nbrs2 (P, B·(1+K1), K2) 2-hop samples for every layer-1 vertex
      mask2 (P, B·(1+K1), K2)

    Returns (P, B, C) logits.

    With ``cfg.coalesce`` (the default) the distributed step issues ONE
    coalesced SSD command block (``cgtrans.aggregate_multi``): the self-row
    lookups and the 2-hop requests share a single request broadcast, kernel
    gather, result all_to_all and backward cotangent scatter —
    collectives-per-step 2 → 1 vs the two-body form, bit-exact both ways
    (``tests/test_cgtrans_coalesce.py``).

    With ``cfg.partition="island"`` the feature table is islandized
    (``IslandPartition.relabel_rows`` order) and ``relabel`` translates the
    batch's caller-visible vertex ids into that space at entry. Outputs are
    positional per seed — no un-permute needed — so islandized ≡ interval
    bit-exact (identical rows fetched in identical order).
    """
    _check_partition_knob(cfg, relabel)
    if relabel is not None:
        r = jnp.asarray(relabel, jnp.int32)
        batch = dict(batch,
                     seeds=jnp.take(r, batch["seeds"]),
                     nbrs1=jnp.take(r, batch["nbrs1"]),
                     nbrs2=jnp.take(r, batch["nbrs2"]))
    Pn, B = batch["seeds"].shape
    K1 = batch["nbrs1"].shape[-1]

    ids1 = jnp.concatenate([batch["seeds"][..., None], batch["nbrs1"]], axis=-1)
    flat1 = ids1.reshape(Pn, B * (1 + K1))

    # distributed step: fetch self features + aggregate 2-hop neighborhoods.
    if cfg.coalesce:
        # ONE SSD command block: the self-row lookups (a K=1 pure-find
        # segment) and the 2-hop sample requests concatenate into a single
        # (ids ‖ segment-descriptor) block — one request broadcast, one
        # kernel gather, one compressed result shipment, and (under
        # impl="pallas") one backward cotangent scatter, where the
        # two-body form below issues two of each.
        x_self, x_agg = cgtrans.aggregate_multi(
            feats,
            ((flat1[..., None], jnp.ones(flat1.shape + (1,), bool)),
             (batch["nbrs2"], batch["mask2"])),
            mesh=mesh, dataflow=cfg.dataflow, impl=cfg.impl,
            request_chunk=cfg.request_chunk, scheduled=cfg.scheduled,
            wire=cfg.wire, features=cfg.features,
            sparse_capacity=cfg.sparse_capacity)
    else:
        x_self = lookup_rows(feats, flat1, mesh=mesh, dataflow=cfg.dataflow,
                             impl=cfg.impl, request_chunk=cfg.request_chunk,
                             scheduled=cfg.scheduled, wire=cfg.wire,
                             features=cfg.features,
                             sparse_capacity=cfg.sparse_capacity)
        x_agg = cgtrans.aggregate_sampled(
            feats, batch["nbrs2"], batch["mask2"], mesh=mesh,
            dataflow=cfg.dataflow, impl=cfg.impl,
            request_chunk=cfg.request_chunk, scheduled=cfg.scheduled,
            wire=cfg.wire, features=cfg.features,
            sparse_capacity=cfg.sparse_capacity)

    h1 = jnp.concatenate([x_self, x_agg], axis=-1)
    h1 = jax.nn.relu(jnp.einsum("pbf,fh->pbh", h1, params["w0"]) + params["b0"])
    h1 = h1.reshape(Pn, B, 1 + K1, -1)

    # local step: aggregate 1-hop h1 per seed.
    m1 = batch["mask1"][..., None].astype(h1.dtype)
    agg1 = (h1[:, :, 1:] * m1).sum(2) / jnp.maximum(m1.sum(2), 1.0)
    h2 = jnp.concatenate([h1[:, :, 0], agg1], axis=-1)
    h2 = jax.nn.relu(jnp.einsum("pbf,fh->pbh", h2, params["w1"]) + params["b1"])
    return jnp.einsum("pbh,hc->pbc", h2, params["w_out"]) + params["b_out"]


def sage_loss(params, feats, batch, cfg: GCNConfig, *,
              mesh: Optional[Mesh] = None, relabel=None):
    logits = sage_forward(params, feats, batch, cfg, mesh=mesh, relabel=relabel)
    labels = batch["labels"]                  # (P, B)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    acc = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return nll.mean(), {"loss": nll.mean(), "acc": acc.mean()}
