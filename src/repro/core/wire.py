"""Wire formats for the CGTrans collectives — the "C" made literal.

CGTrans so far wins bytes by moving *fewer* rows (aggregate-at-owner); this
module is the paper's other lever: moving *smaller* rows. It is a PURE codec
layer — encode/decode transforms with no collectives of their own — so the
``collective-site`` lint allowlist stays exactly as small as it was: the one
``all_to_all`` these codecs wrap lives in ``repro.core.cgtrans``
(``_wire_all_to_all``), where every collective is already contract-budgeted.

Three wire formats, selected per dataflow call (``wire=`` on the
``aggregate_*`` entrypoints, ``GCNConfig.wire``, ``ServingEngine(wire=)``):

* ``"f32"``  — the raw wire. Byte-identical traces to the pre-wire code
  (no codec primitives appear at all), so every existing contract budget
  and parity tier is untouched.
* ``"bf16"`` — cast the ``all_to_all`` partials to bfloat16, ship the bits
  BITCAST AS INT16 (lossless; an integer wire cannot be silently widened
  back to f32 by a backend float-normalization pass, which CPU XLA does to
  bf16 collectives), cast back and ACCUMULATE IN F32 on arrival.
  Integer-valued payloads with ``|x| ≤ 256`` round-trip bit-exactly (8
  mantissa bits), which is what keeps a bit-exact mode for the grad-parity
  tiers; ±inf max/min identity rows are representable and survive as
  themselves.
* ``"int8"`` — symmetric per-row quantization: each (segment-row, shard)
  row of the partial block gets ``scale = max|finite x| / 127`` and ships
  ``round(x/scale)`` as int8. The f32 scale rides the block as 4 bitcast
  int8 columns (exact — no second collective, same trick as the ``op="add"``
  count column), non-finite entries (the ±inf identity rows of max/min
  partials) ship as the reserved code −128 and decode back to the op
  identity, and designated "exact" trailing columns (the contribution
  counts) ride as 4 bitcast int8 columns each so means never divide by a
  quantized count. Accumulation is f32 on arrival, always.

The request broadcast compresses too: ``delta_encode_ids`` transforms the
``-1``-encoded id stream to first-order deltas and ships them as int16 —
half the ``all_gather`` bytes. The safety condition is a STATIC range gate
(``delta_ids_fit``): ids live in ``[-1, V)``, so every delta lies in
``[-V, V]`` and int16 is lossless iff ``V ≤ 32767`` — sorted or not (the
sampled id streams are seed-major, not globally sorted; sortedness makes
the deltas small, the range gate is what makes them SAFE). ``-1`` dead ids
are preserved exactly: the decode is an int32 cumsum, so whatever the
encode summed to comes back bit-for-bit. Streams over the gate ship raw
int32, unchanged.

Gradients: the codecs themselves are never differentiated —
``cgtrans._wire_all_to_all`` is a ``custom_vjp`` whose backward ships the
cotangent block through the SAME wire (quantize → all_to_all → dequantize),
so the reverse pass pays the same compressed bytes as the forward and no
``round``/``where`` ever meets autodiff.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

#: the wire formats every ``wire=`` knob accepts
WIRE_FORMATS = ("f32", "bf16", "int8")

#: ids in [-1, V) have deltas in [-V, V]; int16 holds them iff V ≤ this
ID_DELTA_MAX_V = 2**15 - 1

#: the reserved int8 code for non-finite payload entries (±inf identity
#: rows); quantized values clip to [-127, 127] so it can never collide
INT8_SENTINEL = -128

#: bitcast width of one f32 column carried exactly inside an int8 block
_F32_BYTES = 4


def validate(wire: str) -> str:
    """The one place a wire-format string is checked (every entrypoint
    funnels through it, so a typo fails loudly at trace time)."""
    if wire not in WIRE_FORMATS:
        raise ValueError(f"unknown wire format {wire!r} (have {WIRE_FORMATS})")
    return wire


# ---------------------------------------------------------------------------
# the request broadcast: delta-encoded id streams (the all_gather half)
# ---------------------------------------------------------------------------

def delta_ids_fit(n_vertices: int) -> bool:
    """Static gate: can a [-1, n_vertices) id stream ship as int16 deltas?"""
    return int(n_vertices) <= ID_DELTA_MAX_V


def delta_encode_ids(ids: jnp.ndarray) -> jnp.ndarray:
    """(…, N) int32 id stream (``-1`` dead ids included) → int16 first-order
    deltas along the last axis. Lossless whenever ``delta_ids_fit`` holds
    for the stream's vertex range — the caller checks; this just encodes."""
    d = ids.astype(jnp.int32)
    d = jnp.concatenate([d[..., :1], d[..., 1:] - d[..., :-1]], axis=-1)
    return d.astype(jnp.int16)


def delta_decode_ids(deltas: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``delta_encode_ids``: int32 cumsum along the last axis
    (each row of a gathered (n, N) block decodes independently)."""
    return jnp.cumsum(deltas.astype(jnp.int32), axis=-1)


# ---------------------------------------------------------------------------
# the result shipment: quantized partial blocks (the all_to_all half)
# ---------------------------------------------------------------------------

def _split_exact(x, n_exact: int):
    if n_exact == 0:
        return x, None
    return x[..., : x.shape[-1] - n_exact], x[..., x.shape[-1] - n_exact:]


def encode_payload(x: jnp.ndarray, wire: str, *, identity: float = 0.0,
                   n_exact: int = 0) -> jnp.ndarray:
    """Encode a float partial block ``(…, C)`` for transport.

    ``n_exact`` trailing columns (the ``op="add"`` contribution counts — or
    a backward pass's count cotangents) are carried EXACTLY: cast along on
    the bf16 wire untouched by quantization scales, bitcast to raw bytes on
    the int8 wire. ``identity`` is the op identity that non-finite entries
    must decode back to (int8 wire only; bf16 represents ±inf natively).
    """
    validate(wire)
    if wire == "f32":
        return x
    if wire == "bf16":
        # ship the bf16 bits as int16: bitcast is lossless, and an integer
        # wire is immune to backend float-normalization passes that would
        # silently widen a bf16 collective back to f32 (CPU XLA does
        # exactly that — the "compressed" transport would compress nothing)
        return lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.int16)
    feat, exact = _split_exact(x, n_exact)
    feat = feat.astype(jnp.float32)
    finite = jnp.isfinite(feat)
    mag = jnp.where(finite, jnp.abs(feat), 0.0)
    scale = (mag.max(axis=-1) / 127.0).astype(jnp.float32)      # (…,)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(feat / safe[..., None]), -127, 127).astype(jnp.int8)
    q = jnp.where(finite, q, jnp.int8(INT8_SENTINEL))
    cols = [q, lax.bitcast_convert_type(scale, jnp.int8)]       # (…, C), (…, 4)
    if n_exact:
        raw = lax.bitcast_convert_type(exact.astype(jnp.float32), jnp.int8)
        cols.append(raw.reshape(*exact.shape[:-1], _F32_BYTES * n_exact))
    return jnp.concatenate(cols, axis=-1)


def decode_payload(enc: jnp.ndarray, wire: str, *, identity: float = 0.0,
                   n_exact: int = 0, out_dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of ``encode_payload`` — always dequantizes INTO f32 math
    (``out_dtype`` only recasts at the end, so accumulation downstream is
    f32 even when the features themselves are bf16)."""
    validate(wire)
    if wire == "f32":
        return enc
    if wire == "bf16":
        return lax.bitcast_convert_type(enc, jnp.bfloat16).astype(out_dtype)
    C = enc.shape[-1] - _F32_BYTES - _F32_BYTES * n_exact
    q = enc[..., :C]
    scale = lax.bitcast_convert_type(
        enc[..., C:C + _F32_BYTES], jnp.float32)                # (…,)
    vals = jnp.where(q == INT8_SENTINEL,
                     jnp.asarray(identity, jnp.float32),
                     q.astype(jnp.float32) * scale[..., None])
    if n_exact:
        exact = lax.bitcast_convert_type(
            enc[..., C + _F32_BYTES:].reshape(
                *enc.shape[:-1], n_exact, _F32_BYTES), jnp.float32)
        vals = jnp.concatenate([vals, exact], axis=-1)
    return vals.astype(out_dtype)


def int8_row_scale(x) -> jnp.ndarray:
    """The per-row quantization scale ``encode_payload`` uses — exposed so
    the property tests (and ``check_env``) can assert the round-trip error
    bound ``|decode(encode(x)) − x| ≤ scale/2`` against the same number."""
    finite = jnp.isfinite(x)
    mag = jnp.where(finite, jnp.abs(x), 0.0)
    scale = (mag.max(axis=-1) / 127.0).astype(jnp.float32)
    return jnp.where(scale > 0, scale, 1.0)
