"""The gather-and-scatter (GAS) engine — FAST-GAS semantics on TPU.

The paper's engine couples a CAM (parallel *match* of edge endpoints) with a
FAST SRAM (*row-parallel in-place update* of matched rows). The TPU-native
re-expression (DESIGN §2):

  * match     → equality-compare broadcast / one-hot mask (MXU-contractable)
  * update    → masked vectorized reduce into the accumulator rows
  * idle-skip → tile-occupancy check that skips empty (row-block × edge-tile)
                pairs (realized with ``pl.when`` in the Pallas kernel)

Public primitives (all fixed-shape, jit-friendly):

  gas_scatter(dst, values, n_rows, op)   — scatter-reduce values into rows
  gas_match(keys, queries)               — CAM match mask
  gas_gather(table, ids)                 — row gather (the "find" of
                                           find-and-compute)

``impl`` selects the backend: "xla" (jnp reference semantics, the oracle) or
"pallas" (the kernel, interpret-mode on CPU). Kernels live in
``repro.kernels.gas_scatter``.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Op = Literal["add", "max", "min", "or"]

_INIT = {
    "add": 0.0,
    "max": -jnp.inf,
    "min": jnp.inf,
    "or": 0,
}


def _segment_reduce_xla(dst: jax.Array, values: jax.Array, n_rows: int, op: Op):
    if op == "add":
        return jax.ops.segment_sum(values, dst, num_segments=n_rows)
    if op == "max":
        return jax.ops.segment_max(values, dst, num_segments=n_rows)
    if op == "min":
        return jax.ops.segment_min(values, dst, num_segments=n_rows)
    if op == "or":
        out = jax.ops.segment_max(values.astype(jnp.int32), dst, num_segments=n_rows)
        # empty segments come back as INT32_MIN; the or-identity is 0
        return jnp.maximum(out, 0).astype(values.dtype)
    raise ValueError(op)


def gas_scatter(dst: jax.Array, values: jax.Array, n_rows: int, *,
                op: Op = "add", impl: str = "xla") -> jax.Array:
    """Scatter-reduce ``values`` (E,) or (E, F) into ``n_rows`` rows by ``dst``.

    Rows with no incoming edge hold the op identity for max/min (±inf) — mask
    with a degree count if needed. ``impl="pallas"`` routes through the
    FAST-GAS kernel (CAM match + MXU one-hot contraction + idle-skip).
    """
    if impl == "pallas":
        from repro.kernels.gas_scatter import ops as gas_ops
        return gas_ops.gas_scatter(dst, values, n_rows, op=op)
    return _segment_reduce_xla(dst, values, n_rows, op)


def gas_gather(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Row gather — local by construction under the src-owner partition."""
    return jnp.take(table, ids, axis=0)


def gas_match(keys: jax.Array, queries: jax.Array) -> jax.Array:
    """CAM match: (R,) keys vs (Q,) queries → (Q, R) bool match-line matrix.

    This is the decoder-free use the paper argues for: the match lines are
    consumed directly as row-enable masks (here: a mask/one-hot fed straight
    into the compute), never priority-decoded into addresses.
    """
    return queries[:, None] == keys[None, :]


def gas_scatter_weighted(dst: jax.Array, src_vals: jax.Array, weights: jax.Array,
                         mask: jax.Array, n_rows: int, *, op: Op = "add",
                         impl: str = "xla") -> jax.Array:
    """Masked, edge-weighted scatter — the paper's aggregation atom.

    src_vals: (E, F); weights/mask: (E,). Invalid edges are routed to a
    dead row (n_rows) and sliced off, keeping shapes static.
    """
    E = dst.shape[0]
    if op in ("max", "min"):
        fill = jnp.asarray(_INIT[op], src_vals.dtype)
        vals = jnp.where(mask[:, None], src_vals, fill)
    elif op == "or":
        # boolean-or ignores edge weights: scaling by a zero or negative
        # weight before the segment-max would silently flip set bits
        vals = jnp.where(mask[:, None], src_vals, 0)
    else:
        vals = src_vals * weights[:, None].astype(src_vals.dtype)
        vals = jnp.where(mask[:, None], vals, 0)
    safe_dst = jnp.where(mask, dst, n_rows)
    out = gas_scatter(safe_dst, vals, n_rows + 1, op=op, impl=impl)
    return out[:n_rows]
