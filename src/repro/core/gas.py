"""The gather-and-scatter (GAS) engine — FAST-GAS semantics on TPU.

The paper's engine couples a CAM (parallel *match* of edge endpoints) with a
FAST SRAM (*row-parallel in-place update* of matched rows). The TPU-native
re-expression (DESIGN §2):

  * match     → equality-compare broadcast / one-hot mask (MXU-contractable)
  * update    → masked vectorized reduce into the accumulator rows
  * idle-skip → tile-occupancy check that skips empty (row-block × edge-tile)
                pairs (realized with ``pl.when`` in the Pallas kernel)

Public primitives (all fixed-shape, jit-friendly):

  gas_scatter(dst, values, n_rows, op)   — scatter-reduce values into rows
  gas_match(keys, queries)               — CAM match mask
  gas_gather(table, ids)                 — row gather (the "find" of
                                           find-and-compute)

``impl`` selects the backend: "xla" (jnp reference semantics, the oracle) or
"pallas" (the kernel, interpret-mode on CPU). Kernels live in
``repro.kernels.gas_scatter``.

**Differentiation (the backward pass is also GAS work).** ``pallas_call``
has no transpose rule, so the pallas backend carries ``jax.custom_vjp``
rules here — the same forward-only pattern the embedding lookup proved
(``repro.models.embedding``): fwd and bwd are each plain forward kernel
dispatches, and no transpose machinery ever touches the kernel. The rules
exploit the paper's own symmetry:

  * the backward of a scatter-add is a *gather* — the cotangent-to-values of
    ``gas_scatter_weighted(op="add")`` is a masked weighted gather of the
    output cotangent, and the cotangent-to-weights is a per-edge row-dot;
  * the backward of a gather is a *scatter* — ``gas_gather(impl="pallas")``
    scatter-adds its cotangent rows through the FAST-GAS kernel;
  * for ``op="max"/"min"`` the cotangent is routed through a recomputed
    ``gas_match``-style equality mask against the saved output — the CAM
    consumed as a grad router (match lines gate the cotangent directly,
    never priority-decoded into argmax addresses) — with the tie count
    itself produced by a kernel scatter, matching XLA's even-split-among-
    ties convention; ``op="or"`` is flat almost everywhere (the XLA oracle
    differentiates to exact zeros through its int cast), so its cotangents
    are zeros.

**Locality scheduling (the idle-skip actually firing).** ``schedule_edges``
bins the edge stream by destination row block (paper Fig 11(c)): with binned
edges each kernel edge tile touches one or two row blocks, so the idle-skip
occupancy collapses to a thin band and ``pl.when`` skips almost every
(row-block × edge-tile) round. The schedule is computed ONCE per
(partition, batch) — the dataflow permutes the edge LIST, so gathered value
streams arrive binned for free — and the same schedule serves every layer,
every feature block, and the backward pass (the max/min tie-count scatter
reuses it; cotangents to permuted inputs un-permute through the transpose of
the ``take`` that applied the permutation). On the pallas backend the
scheduled scatter additionally runs FUSED: mask and edge weights enter the
kernel (dead-row convention + match-line scaling), so no ``values*weights``
or mask-fill E×F stream is ever staged in HBM.
"""

from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

Op = Literal["add", "max", "min", "or"]

_INIT = {
    "add": 0.0,
    "max": -jnp.inf,
    "min": jnp.inf,
    "or": 0,
}


def _tick(kind: str) -> None:
    """Tick the shared trace-time dispatch counter (see ``count_dispatches``)."""
    from repro.kernels.gas_scatter import ops as gas_ops
    gas_ops._tick(kind)


def count_dispatches():
    """Context manager counting GAS dispatches at trace time — the
    deterministic "how many engine calls does this program issue" view.

    Engine-level keys ticked from this module: ``find`` (one per
    ``gas_gather`` — the find of find-and-compute, both backends) and
    ``reduce`` (one per weighted scatter reduction, both backends; the K=1
    pure-find specialization never reduces, so it never ticks). The kernel
    layer (``repro.kernels.gas_scatter.ops``) ticks ``kernel_scatter`` into
    the same counter for every actual pallas dispatch. This is what the
    request-coalescing tier asserts on: the coalesced ``sage_forward`` fetch
    runs ONE ``find`` (and its VJP one backward ``kernel_scatter``) where
    the separate two-stream form ran two.
    """
    from repro.kernels.gas_scatter import ops as gas_ops
    return gas_ops.count_dispatches()


def _segment_reduce_xla(dst: jax.Array, values: jax.Array, n_rows: int, op: Op):
    if op == "add":
        return jax.ops.segment_sum(values, dst, num_segments=n_rows)
    if op == "max":
        return jax.ops.segment_max(values, dst, num_segments=n_rows)
    if op == "min":
        return jax.ops.segment_min(values, dst, num_segments=n_rows)
    if op == "or":
        out = jax.ops.segment_max(values.astype(jnp.int32), dst, num_segments=n_rows)
        # empty segments come back as INT32_MIN; the or-identity is 0
        return jnp.maximum(out, 0).astype(values.dtype)
    raise ValueError(op)


def schedule_edges(dst: jax.Array, mask: Optional[jax.Array], n_rows: int, *,
                   assume_sorted: bool = False):
    """Destination-binned edge schedule (see ``kernels.gas_scatter.ops``).

    Returns an ``EdgeSchedule`` — a stable counting-sort permutation of the
    edges by ``dst // ROW_BLOCK`` plus the per-edge-tile live-block band the
    idle-skip occupancy collapses to. Compute it once per (partition, batch)
    and thread it through ``gas_scatter_weighted(schedule=...)`` with
    edge arrays permuted by ``.perm``; ``assume_sorted=True`` skips the sort
    for streams binned by construction (e.g. sampled-path seed rows).
    """
    from repro.kernels.gas_scatter import ops as gas_ops
    return gas_ops.schedule_edges(dst, mask, n_rows,
                                  assume_sorted=assume_sorted)


def gas_scatter(dst: jax.Array, values: jax.Array, n_rows: int, *,
                op: Op = "add", impl: str = "xla") -> jax.Array:
    """Scatter-reduce ``values`` (E,) or (E, F) into ``n_rows`` rows by ``dst``.

    Rows with no incoming edge hold the op identity for max/min (±inf) — mask
    with a degree count if needed. ``impl="pallas"`` routes through the
    FAST-GAS kernel (CAM match + MXU one-hot contraction + idle-skip); that
    raw kernel entry is forward-only — differentiate through
    ``gas_scatter_weighted``/``gas_gather``, which carry the custom VJPs.
    """
    if impl == "pallas":
        from repro.kernels.gas_scatter import ops as gas_ops
        return gas_ops.gas_scatter(dst, values, n_rows, op=op)
    return _segment_reduce_xla(dst, values, n_rows, op)


def _zero_cotangent(x: jax.Array):
    """Symbolic-zero cotangent with the right tangent type: float zeros for
    inexact primals, ``float0`` for int/bool primals (ids, masks)."""
    if jnp.issubdtype(x.dtype, jnp.inexact):
        return jnp.zeros_like(x)
    return np.zeros(np.shape(x), jax.dtypes.float0)


# ---------------------------------------------------------------------------
# gather (+ its kernel-routed VJP: the backward of a gather is a scatter)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _gather_pallas(n_rows: int):
    """Row gather whose VJP scatter-adds the cotangent through the FAST-GAS
    kernel — the in-SSD grad aggregation (no raw table rows move in either
    direction, and no XLA scatter silently replaces the kernel)."""

    @jax.custom_vjp
    def gather(table, ids):
        return jnp.take(table, ids, axis=0)

    def fwd(table, ids):
        # the zero-size residual carries the table dtype into the bwd cast
        return gather(table, ids), (ids, jnp.zeros((0,), table.dtype))

    def bwd(res, g):
        ids, like = res
        gf = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
        # fused dispatch (mask/weights-free): out-of-range ids ride the
        # dead-row convention inside the kernel wrapper, no E×F staging
        dtab = _scatter_weighted_impl(ids.reshape(-1), gf, None, None,
                                      n_rows, "add", "pallas")
        return dtab.astype(like.dtype), np.zeros(np.shape(ids), jax.dtypes.float0)

    gather.defvjp(fwd, bwd)
    return gather


def gas_gather(table: jax.Array, ids: jax.Array, *, impl: str = "xla") -> jax.Array:
    """Row gather — local by construction under the src-owner partition.

    ``impl="pallas"`` keeps the forward a plain take but routes the VJP's
    scatter-add (the backward of a gather IS a scatter) through the FAST-GAS
    kernel, so the reverse pass of a dataflow stays in the in-SSD regime.
    """
    _tick("find")
    if impl == "pallas":
        if table.ndim != 2:
            # a silent jnp.take fallback here would hand the backward to an
            # XLA scatter — the exact regression the grad tier forbids
            raise NotImplementedError(
                f"gas_gather(impl='pallas') routes its VJP through the "
                f"FAST-GAS kernel and requires a 2-D (rows, F) table; got "
                f"ndim={table.ndim}. Use impl='xla' for other ranks.")
        return _gather_pallas(table.shape[0])(table, ids)
    return jnp.take(table, ids, axis=0)


def gas_match(keys: jax.Array, queries: jax.Array) -> jax.Array:
    """CAM match: (R,) keys vs (Q,) queries → (Q, R) bool match-line matrix.

    This is the decoder-free use the paper argues for: the match lines are
    consumed directly as row-enable masks (here: a mask/one-hot fed straight
    into the compute), never priority-decoded into addresses. The max/min
    VJP below consumes the same match-line idea as a *grad router*.
    """
    return queries[:, None] == keys[None, :]


# ---------------------------------------------------------------------------
# weighted scatter (+ its custom VJP for the pallas backend)
# ---------------------------------------------------------------------------

def _scatter_weighted_impl(dst, src_vals, weights, mask, n_rows, op: Op,
                           impl: str, schedule=None):
    """The primal computation shared by both backends (see the public
    ``gas_scatter_weighted`` for semantics). ``schedule`` is the banded
    idle-skip bounds for pre-permuted inputs (pallas backend only)."""
    _tick("reduce")
    if impl == "pallas":
        # fused dispatch: mask → dead-row convention, weights → match-line
        # scaling, both INSIDE the kernel — no E×F staging array exists.
        from repro.kernels.gas_scatter import ops as gas_ops
        if op == "or":
            # boolean-or ignores edge weights: scaling by a zero or negative
            # weight before the max would silently flip set bits. The int
            # round-trip matches the XLA oracle's truncation exactly, so
            # both backends agree even on non-{0,1} values.
            vals = src_vals.astype(jnp.int32).astype(jnp.float32)
            out = gas_ops.gas_scatter_fused(dst, vals, None, mask, n_rows,
                                            op="max", schedule=schedule)
            return jnp.maximum(out, 0).astype(src_vals.dtype)
        w = weights if op == "add" else None
        return gas_ops.gas_scatter_fused(dst, src_vals, w, mask, n_rows,
                                         op=op, schedule=schedule)
    if op in ("max", "min"):
        fill = jnp.asarray(_INIT[op], src_vals.dtype)
        vals = jnp.where(mask[:, None], src_vals, fill)
    elif op == "or":
        # boolean-or ignores edge weights (see the fused branch above)
        vals = jnp.where(mask[:, None], src_vals, 0)
    else:
        vals = src_vals * weights[:, None].astype(src_vals.dtype)
        vals = jnp.where(mask[:, None], vals, 0)
    safe_dst = jnp.where(mask, dst, n_rows)
    out = gas_scatter(safe_dst, vals, n_rows + 1, op=op, impl=impl)
    return out[:n_rows]


@functools.lru_cache(maxsize=None)
def _scatter_weighted_pallas(n_rows: int, op: Op):
    """``gas_scatter_weighted`` on the kernel backend with a custom VJP.

    The rules mirror what autodiff derives for the XLA oracle — the grad
    parity tier (``tests/test_cgtrans_grad.py``) asserts the match:

      add      d_vals[e]  = mask[e] · w[e] · g[dst[e]]      (weighted gather)
               d_w[e]     = mask[e] · ⟨src_vals[e], g[dst[e]]⟩      (row-dot)
      max/min  d_vals[e,f] = eq[e,f] · g[dst[e],f] / ties[dst[e],f]
               with eq = mask ∧ (src_vals == out[dst]) — the CAM match lines
               recomputed against the saved output and consumed as the grad
               router (no argmax decode); ties counted by a kernel scatter,
               matching XLA's even-split convention. d_w = 0 (weights are
               not consumed by the compare ops).
    Both the tie-count scatter and (via ``gas_gather(impl="pallas")`` at the
    dataflow layer) the feature-table scatter run through the FAST-GAS
    kernel: the backward pass is itself GAS work — and the tie-count scatter
    reuses the SAME edge schedule as the forward (its dst stream IS the
    forward's), so the idle-skip band serves the reverse pass too.
    (``op="or"`` never reaches here — it is flat, so the public entry stops
    gradients instead of carrying residuals for an all-zero bwd.)
    """

    @jax.custom_vjp
    def scatter(dst, src_vals, weights, mask, schedule):
        return _scatter_weighted_impl(dst, src_vals, weights, mask,
                                      n_rows, op, "pallas", schedule)

    def fwd(dst, src_vals, weights, mask, schedule):
        out = _scatter_weighted_impl(dst, src_vals, weights, mask,
                                     n_rows, op, "pallas", schedule)
        res = (dst, src_vals, weights, mask, schedule) + (
            (out,) if op in ("max", "min") else ())
        return out, res

    def bwd(res, g):
        dst, src_vals, weights, mask, schedule = res[:5]
        d_dst = _zero_cotangent(dst)
        d_mask = _zero_cotangent(mask)
        d_sched = jax.tree.map(_zero_cotangent, schedule)
        # live = contributed to the forward: the fused kernel treats masked
        # AND out-of-range edges as dead, so the cotangent must gate on both
        # (mask alone would hand an out-of-range edge the clipped row's grad)
        live = mask & (dst >= 0) & (dst < n_rows)
        safe = jnp.clip(dst, 0, n_rows - 1)       # dead edges read junk rows
        g_rows = jnp.take(g, safe, axis=0)        # …zeroed by `live` below
        if op == "add":
            d_vals = jnp.where(live[:, None],
                               g_rows * weights[:, None].astype(g.dtype),
                               0).astype(src_vals.dtype)
            d_w = jnp.where(
                live,
                (src_vals.astype(jnp.float32) * g_rows.astype(jnp.float32)
                 ).sum(-1),
                0).astype(weights.dtype)
            return d_dst, d_vals, d_w, d_mask, d_sched

        out = res[5]
        # CAM match lines as the grad router: an edge's value participates in
        # the row extremum iff it equals the saved output there (and is live)
        eq = live[:, None] & (src_vals == jnp.take(out, safe, axis=0))
        # tie count via the kernel — the backward scatter is itself FAST-GAS
        # work sharing the forward's dst stream, hence its schedule; masked/
        # out-of-range edges ride the dead-row convention
        ties = _scatter_weighted_impl(dst, eq.astype(jnp.float32), None, mask,
                                      n_rows, "add", "pallas", schedule)
        share = g_rows / jnp.maximum(jnp.take(ties, safe, axis=0), 1.0)
        d_vals = jnp.where(eq, share, 0).astype(src_vals.dtype)
        return d_dst, d_vals, _zero_cotangent(weights), d_mask, d_sched

    scatter.defvjp(fwd, bwd)
    return scatter


def gas_scatter_weighted(dst: jax.Array, src_vals: jax.Array, weights: jax.Array,
                         mask: jax.Array, n_rows: int, *, op: Op = "add",
                         impl: str = "xla", schedule=None) -> jax.Array:
    """Masked, edge-weighted scatter — the paper's aggregation atom.

    src_vals: (E, F); weights/mask: (E,). Invalid edges are routed to a
    dead row and sliced off, keeping shapes static. On the pallas backend
    the dispatch is FUSED — mask and weights enter the kernel, no E×F
    staging. ``schedule`` (an ``EdgeSchedule`` whose ``.perm`` order the
    inputs are already in) swaps the dense grid for the banded walk, so
    off-band rounds are never even visited. Differentiable
    on BOTH backends: the XLA oracle through native autodiff, the pallas
    kernel through the custom VJP above (pallas ≡ xla gradients is asserted
    by ``tests/test_cgtrans_grad.py``); the schedule is reused by the
    backward (tie counts) and cotangents un-permute through the transpose
    of the caller's ``take``.
    """
    if impl == "pallas":
        if op == "or":
            # flat almost everywhere (the oracle differentiates to exact
            # zeros through its int cast): stop the gradients instead of
            # paying custom-VJP residuals for an all-zero backward
            return _scatter_weighted_impl(
                dst, jax.lax.stop_gradient(src_vals),
                jax.lax.stop_gradient(weights), mask, n_rows, op, impl,
                schedule)
        return _scatter_weighted_pallas(n_rows, op)(dst, src_vals, weights,
                                                    mask, schedule)
    return _scatter_weighted_impl(dst, src_vals, weights, mask, n_rows, op,
                                  impl, schedule)
