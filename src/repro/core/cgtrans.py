"""CGTrans — Compressive Graph Transmission (the paper's §3.2) on a mesh.

The storage tier is the ``data`` mesh axis: each shard owns a vertex interval
(features) and all edges whose *source* lies in it (gathers are local — the
in-SSD invariant). Two dataflows over identical math:

* ``baseline``  — GCNAX-style: ship **raw** gathered neighbor features to the
  destination owner, aggregate there. Interconnect bytes ∝ E·F (or B·K·F for
  sampled SAGE) — the paper's "slow SSD bus" regime.
* ``cgtrans``   — aggregate **at the owner** into per-destination partials and
  ship only those. Interconnect bytes ∝ V·F (or B·F): a fan-in/fan-out×
  compression — the paper's 50×.

Both are exposed full-graph (edge COO) and sampled (GraphSAGE fan-out), and
both run the per-shard reduction on either GAS backend: ``impl="xla"`` (the
jnp oracle) or ``impl="pallas"`` (the FAST-GAS kernel — CAM match + MXU
one-hot contraction + idle-skip; interpret-mode on CPU). ``pallas_call`` has
no shard_map replication rule, so the pallas dataflows trace with the
replication check disabled (``check_vma=False``) — the differential tier in
``tests/test_cgtrans_pallas.py`` is what guards their agreement instead.

``aggregate_sampled`` additionally supports a **chunked request stream**
(``request_chunk=``): instead of all-gathering the whole ``(B_loc, K)`` id
block, the seed block is streamed through a ``lax.scan`` in chunks — the
paper's SSD command-queue analogue — bounding per-shard peak gather memory at
``O(n·chunk·K·F)`` instead of ``O(n·B_loc·K·F)``. The chunked path is
bit-exact with the unchunked one (chunking partitions *seeds*, never a seed's
K contributions), which ``tests/test_cgtrans_pallas.py`` asserts.

``benchmarks/collective_bytes.py`` lowers both on the production mesh and
diffs the collective bytes in the compiled HLO — the mechanism, measured.

**Both dataflows are differentiable on both backends.** The collectives
(``psum_scatter``/``all_gather``/``all_to_all``) carry JAX's own transpose
rules; the only op without one is ``pallas_call``, which is hidden behind the
forward-only custom VJPs in ``repro.core.gas`` (the embedding-lookup
pattern): the backward of the owner-side gather is a FAST-GAS scatter and
the backward of the seed scatter is a masked weighted gather — the reverse
pass is itself in-SSD GAS work, never a transpose through the kernel. Two
consequences visible in this file: the non-add cross-shard combine of
``aggregate_edges`` is an ``all_gather`` + local extremum (``lax.pmax`` has
no differentiation rule at all), and ``_finalize``/``_combine_shards`` mask
the ±inf max/min identity rows to 0 so no downstream ``0·inf`` ever turns a
train-step gradient into NaN. The grad parity tier
(``tests/test_cgtrans_grad.py``) asserts pallas ≡ xla ≡ finite differences
across the whole matrix.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import psum_scatter, shard_map
from repro.core import gas

AXIS = "data"  # the storage-tier axis


def _check_vma(impl: str) -> Optional[bool]:
    """shard_map replication-check setting for a dataflow using ``impl``.

    ``pallas_call`` has no replication rule (NotImplementedError on trace), so
    pallas dataflows must disable the check; the xla dataflows keep the
    installed default.
    """
    return False if impl == "pallas" else None


# ---------------------------------------------------------------------------
# full-graph edge aggregation (GCN):  out[v] = Σ_{(u,v,w)∈E} w · feats[u]
# ---------------------------------------------------------------------------

def _agg_local(feats, src_local, dst_global, w, mask, n_vertices, op, impl):
    """In-SSD step: local gather + segment-reduce into global dst bins.

    ``impl`` threads into BOTH halves: under pallas the scatter's VJP is the
    kernel's and the gather's VJP (a scatter of the feature cotangent) runs
    through the kernel too — the backward stays in the in-SSD regime.
    """
    gathered = gas.gas_gather(feats, src_local, impl=impl)  # LOCAL by construction
    return gas.gas_scatter_weighted(
        dst_global, gathered, w, mask, n_vertices, op=op, impl=impl)


def aggregate_edges(
    feats: jax.Array,        # (P, part, F) owner-sharded vertex features
    src_local: jax.Array,    # (P, E) local src ids
    dst_global: jax.Array,   # (P, E) global dst ids
    weights: jax.Array,      # (P, E)
    mask: jax.Array,         # (P, E)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",      # cgtrans | baseline
    op: gas.Op = "add",
    impl: str = "xla",
) -> jax.Array:
    """Returns (P, part, F) aggregated destination features, owner-sharded."""
    Pn, part, F = feats.shape
    V = Pn * part

    if mesh is None or AXIS not in mesh.axis_names or mesh.shape[AXIS] == 1:
        # single-shard reference: both dataflows degenerate to one reduction
        out = _agg_local(
            feats.reshape(V, F),
            (src_local + (jnp.arange(Pn) * part)[:, None]).reshape(-1),
            dst_global.reshape(-1), weights.reshape(-1), mask.reshape(-1),
            V, op, impl)
        return out.reshape(Pn, part, F)

    n = mesh.shape[AXIS]
    assert Pn == n, f"partitions ({Pn}) must equal data-axis size ({n})"

    if dataflow == "cgtrans":
        def shard_fn(f, s, d, w, m):
            # f: (1, part, F); edge arrays (1, E). Per-shard E need not be
            # tile-aligned — the kernel wrapper pads and rebuilds the
            # occupancy map per shard from this shard's (padded) dst ids.
            partial = _agg_local(f[0], s[0], d[0], w[0], m[0], V, op, impl)
            # compressed transmission: reduce-scatter the (V, F) partials so
            # each shard receives exactly its owned interval, aggregated.
            if op == "add":
                out = psum_scatter(partial.reshape(n, part, F), AXIS,
                                   scatter_dimension=0)
            else:
                # max/min/or have no fused reduce-scatter; ship each owner
                # its interval's partials (all_to_all: V·F bytes per shard,
                # like the add path's reduce-scatter) and reduce locally.
                # (Not lax.pmax/pmin: those have NO differentiation rule,
                # while all_to_all is its own transpose — the grad tier
                # differentiates this flow.) or-partials are ≥ 0, so max
                # realizes boolean-or.
                parts = lax.all_to_all(partial.reshape(n, part, F), AXIS,
                                       split_axis=0, concat_axis=0,
                                       tiled=False)          # (n, part, F)
                out = parts.min(0) if op == "min" else parts.max(0)
            return out[None]

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=P(AXIS), check_vma=_check_vma(impl),
        )(feats, src_local, dst_global, weights, mask)

    if dataflow == "baseline":
        def shard_fn(f, s, d, w, m):
            # raw transmission: gather locally, ship the full edge payload.
            # Weights scale contributions only under op="add" — max/min take
            # the raw feature and or ignores weights entirely (matching
            # gas_scatter_weighted, so baseline ≡ cgtrans ≡ reference).
            raw = gas.gas_gather(f[0], s[0], impl=impl)
            if op == "add":
                raw = raw * w[0][:, None].astype(raw.dtype)
            raw = jnp.where(m[0][:, None], raw, 0)
            all_raw = lax.all_gather(raw, AXIS)          # (n, E, F) — E·F·n bytes
            all_dst = lax.all_gather(d[0], AXIS)
            all_m = lax.all_gather(m[0], AXIS)
            # destination side ("the accelerator"): keep only owned interval
            lo = lax.axis_index(AXIS) * part
            rel = all_dst.reshape(-1) - lo
            ok = all_m.reshape(-1) & (rel >= 0) & (rel < part)
            out = gas.gas_scatter_weighted(
                jnp.clip(rel, 0, part - 1), all_raw.reshape(-1, F),
                jnp.ones_like(rel, jnp.float32), ok, part, op=op, impl=impl)
            return out[None]

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=P(AXIS), check_vma=_check_vma(impl),
        )(feats, src_local, dst_global, weights, mask)

    raise ValueError(dataflow)


# ---------------------------------------------------------------------------
# sampled GraphSAGE aggregation: out[b] = reduce_k feats[nbrs[b, k]]
# ---------------------------------------------------------------------------

def _seed_reduce(f_shard, rel, own, op: gas.Op, impl: str):
    """Per-request-block GAS reduction: (R, K) local ids → (R, F) partials.

    This is the in-SSD step of the sampled path — the seed index is the
    destination row, so the fan-out reduction is exactly a FAST-GAS scatter
    (``impl`` selects the backend). Rows with no owned neighbor hold the op
    identity (0 for add/or, ±inf for max/min). Also returns (R,) own counts.
    """
    R, K = rel.shape
    rows = gas.gas_gather(f_shard, rel.reshape(-1), impl=impl)   # (R·K, F)
    seed = jnp.repeat(jnp.arange(R, dtype=jnp.int32), K)
    red = gas.gas_scatter_weighted(
        seed, rows, jnp.ones((R * K,), jnp.float32), own.reshape(-1), R,
        op=op, impl=impl)
    return red, own.sum(-1)


def _mask_identity_rows(out, op: gas.Op):
    """Zero the ±inf max/min identity rows (seeds with no valid sample).

    Applied at every *terminal* finalize (never on pre-combine partials —
    a shard with no sample for a seed must still contribute the identity to
    the cross-shard extremum). Keeping ±inf here would make any downstream
    use produce ``0·inf = NaN`` under autodiff — the classic silent
    train-step NaN — so identity rows now read 0 on every op, matching
    add/or, and their cotangent is cut at the ``where``.
    """
    if op in ("max", "min"):
        return jnp.where(jnp.isfinite(out), out, 0)
    return out


def _finalize(red, cnt, op: gas.Op):
    """Partial → output rows: mean for add, identity-masked passthrough
    otherwise (terminal positions only — see ``aggregate_sampled``)."""
    if op == "add":
        return red / jnp.maximum(cnt, 1).astype(red.dtype)[..., None]
    return _mask_identity_rows(red, op)


def _combine_shards(parts, cnts, op: gas.Op):
    """(n, B, F) per-source-shard partials (+ (n, B) counts) → (B, F)."""
    if op == "add":
        return parts.sum(0) / jnp.maximum(cnts.sum(0), 1).astype(parts.dtype)[..., None]
    if op in ("max", "or"):
        return _mask_identity_rows(parts.max(0), op)
    return _mask_identity_rows(parts.min(0), op)


def _pad_rows(x, mult, fill):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1), constant_values=fill)


def scan_request_chunks(body, nbrs2d, mask2d, chunk: int):
    """Stream the (R, K) request block through ``body`` in row chunks.

    The SSD command-queue analogue: requests are issued ``chunk`` rows at a
    time; padded rows are all-masked so they reduce to the op identity and
    are sliced off. Chunking partitions rows (never a row's K entries), so
    the result is bit-exact with one full-block ``body`` call. ``body`` maps
    an (chunk, K) id/mask pair to (chunk, F) output rows. Shared with the
    chunked embedding lookup (``repro.models.embedding``).
    """
    R = nbrs2d.shape[0]
    chunk = max(1, min(chunk, R))
    nb = _pad_rows(nbrs2d, chunk, 0)
    mk = _pad_rows(mask2d, chunk, False)
    steps = nb.shape[0] // chunk

    def step(_, inp):
        return None, body(*inp)

    _, outs = lax.scan(step, None,
                       (nb.reshape(steps, chunk, -1), mk.reshape(steps, chunk, -1)))
    return outs.reshape(steps * chunk, -1)[:R]


def aggregate_sampled(
    feats: jax.Array,     # (P, part, F) owner-sharded features
    nbrs: jax.Array,      # (P, B_loc, K) global neighbor ids, seed-sharded
    mask: jax.Array,      # (P, B_loc, K)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",
    op: gas.Op = "add",
    impl: str = "xla",
    request_chunk: Optional[int] = None,
) -> jax.Array:
    """Returns (P, B_loc, F) aggregated neighbor features per seed.

    ``op="add"`` is the masked *mean* (GraphSAGE); max/min/or reduce
    elementwise over the valid samples. Seeds with no valid sample read 0 on
    every op — the ±inf max/min identities are masked at the terminal
    finalize (``_mask_identity_rows``) so autodiff never meets ``0·inf``.
    ``impl`` selects the GAS backend for every per-shard reduction (both
    backends differentiate; under pallas the backward runs through the
    FAST-GAS kernel); ``request_chunk`` streams the seed block through the
    collectives ``request_chunk`` seeds at a time.
    """
    if dataflow not in ("cgtrans", "baseline"):
        raise ValueError(dataflow)
    Pn, part, F = feats.shape
    _, B_loc, K = nbrs.shape

    if mesh is None or AXIS not in mesh.axis_names or mesh.shape[AXIS] == 1:
        table = feats.reshape(Pn * part, F)

        def body(nb_c, m_c):
            red, cnt = _seed_reduce(table, nb_c, m_c, op, impl)
            return _finalize(red, cnt, op)

        flat_nb = nbrs.reshape(Pn * B_loc, K)
        flat_m = mask.reshape(Pn * B_loc, K)
        if request_chunk is None:
            out = body(flat_nb, flat_m)
        else:
            out = scan_request_chunks(body, flat_nb, flat_m, request_chunk)
        return out.reshape(Pn, B_loc, F)

    n = mesh.shape[AXIS]

    def shard_fn(f, nb, m):
        f, nb, m = f[0], nb[0], m[0]
        lo = lax.axis_index(AXIS) * part

        def body(nb_c, m_c):
            # request broadcast (ids only — tiny; "addresses into the SSD")
            C = nb_c.shape[0]
            ids = lax.all_gather(nb_c, AXIS)                 # (n, C, K)
            msk = lax.all_gather(m_c, AXIS)
            rel = ids - lo
            own = msk & (rel >= 0) & (rel < part)
            relc = jnp.clip(rel, 0, part - 1)

            if dataflow == "cgtrans":
                # in-SSD aggregation: GAS-reduce per seed, ship (n·C, F)
                red, cnt = _seed_reduce(
                    f, relc.reshape(n * C, K), own.reshape(n * C, K), op, impl)
                parts = lax.all_to_all(red.reshape(n, C, F), AXIS,
                                       split_axis=0, concat_axis=0, tiled=False)
                if op == "add":
                    cnts = lax.all_to_all(
                        cnt.reshape(n, C)[..., None].astype(f.dtype), AXIS,
                        split_axis=0, concat_axis=0, tiled=False)[..., 0]
                else:
                    cnts = None
                return _combine_shards(parts, cnts, op)

            # baseline: ship raw (n·C·K, F) neighbor rows to the seed owners,
            # reduce there ("the accelerator") — also through the GAS engine.
            rows = gas.gas_gather(f, relc.reshape(-1), impl=impl
                                  ).reshape(n, C, K, F)
            rows = jnp.where(own[..., None], rows, 0)
            raw = lax.all_to_all(rows, AXIS, split_axis=0, concat_axis=0,
                                 tiled=False)                 # (n, C, K, F)
            okk = lax.all_to_all(own[..., None], AXIS, split_axis=0,
                                 concat_axis=0, tiled=False)[..., 0]
            flat = raw.transpose(1, 0, 2, 3).reshape(C * n * K, F)
            okf = okk.transpose(1, 0, 2).reshape(C * n * K)
            seed = jnp.repeat(jnp.arange(C, dtype=jnp.int32), n * K)
            red = gas.gas_scatter_weighted(
                seed, flat, jnp.ones((C * n * K,), jnp.float32), okf, C,
                op=op, impl=impl)
            return _finalize(red, okf.reshape(C, n * K).sum(-1), op)

        if request_chunk is None:
            out = body(nb, m)
        else:
            out = scan_request_chunks(body, nb, m, request_chunk)
        return out[None]

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=P(AXIS), check_vma=_check_vma(impl),
    )(feats, nbrs, mask)
