"""CGTrans — Compressive Graph Transmission (the paper's §3.2) on a mesh.

The storage tier is the ``data`` mesh axis: each shard owns a vertex interval
(features) and all edges whose *source* lies in it (gathers are local — the
in-SSD invariant). Two dataflows over identical math:

* ``baseline``  — GCNAX-style: ship **raw** gathered neighbor features to the
  destination owner, aggregate there. Interconnect bytes ∝ E·F (or B·K·F for
  sampled SAGE) — the paper's "slow SSD bus" regime.
* ``cgtrans``   — aggregate **at the owner** into per-destination partials and
  ship only those. Interconnect bytes ∝ V·F (or B·F): a fan-in/fan-out×
  compression — the paper's 50×.

Both are exposed full-graph (edge COO) and sampled (GraphSAGE fan-out).
``benchmarks/collective_bytes.py`` lowers both on the production mesh and
diffs the collective bytes in the compiled HLO — the mechanism, measured.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import psum_scatter, shard_map
from repro.core import gas

AXIS = "data"  # the storage-tier axis


# ---------------------------------------------------------------------------
# full-graph edge aggregation (GCN):  out[v] = Σ_{(u,v,w)∈E} w · feats[u]
# ---------------------------------------------------------------------------

def _agg_local(feats, src_local, dst_global, w, mask, n_vertices, op, impl):
    """In-SSD step: local gather + segment-reduce into global dst bins."""
    gathered = gas.gas_gather(feats, src_local)          # LOCAL by construction
    return gas.gas_scatter_weighted(
        dst_global, gathered, w, mask, n_vertices, op=op, impl=impl)


def aggregate_edges(
    feats: jax.Array,        # (P, part, F) owner-sharded vertex features
    src_local: jax.Array,    # (P, E) local src ids
    dst_global: jax.Array,   # (P, E) global dst ids
    weights: jax.Array,      # (P, E)
    mask: jax.Array,         # (P, E)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",      # cgtrans | baseline
    op: gas.Op = "add",
    impl: str = "xla",
) -> jax.Array:
    """Returns (P, part, F) aggregated destination features, owner-sharded."""
    Pn, part, F = feats.shape
    V = Pn * part

    if mesh is None or AXIS not in mesh.axis_names or mesh.shape[AXIS] == 1:
        # single-shard reference: both dataflows degenerate to one reduction
        out = _agg_local(
            feats.reshape(V, F),
            (src_local + (jnp.arange(Pn) * part)[:, None]).reshape(-1),
            dst_global.reshape(-1), weights.reshape(-1), mask.reshape(-1),
            V, op, impl)
        return out.reshape(Pn, part, F)

    n = mesh.shape[AXIS]
    assert Pn == n, f"partitions ({Pn}) must equal data-axis size ({n})"

    if dataflow == "cgtrans":
        def shard_fn(f, s, d, w, m):
            # f: (1, part, F); edge arrays (1, E)
            partial = _agg_local(f[0], s[0], d[0], w[0], m[0], V, op, impl)
            # compressed transmission: reduce-scatter the (V, F) partials so
            # each shard receives exactly its owned interval, aggregated.
            if op == "add":
                out = psum_scatter(partial.reshape(n, part, F), AXIS,
                                   scatter_dimension=0)
            else:
                # max/min have no fused reduce-scatter; all-reduce then slice
                out = lax.pmax(partial, AXIS) if op == "max" else lax.pmin(partial, AXIS)
                i = lax.axis_index(AXIS)
                out = lax.dynamic_slice_in_dim(out.reshape(n, part, F), i, 1, 0)[0]
            return out[None]

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=P(AXIS))(feats, src_local, dst_global, weights, mask)

    if dataflow == "baseline":
        def shard_fn(f, s, d, w, m):
            # raw transmission: gather locally, ship the full edge payload
            raw = gas.gas_gather(f[0], s[0]) * w[0][:, None].astype(f.dtype)
            raw = jnp.where(m[0][:, None], raw, 0)
            all_raw = lax.all_gather(raw, AXIS)          # (n, E, F) — E·F·n bytes
            all_dst = lax.all_gather(d[0], AXIS)
            all_m = lax.all_gather(m[0], AXIS)
            # destination side ("the accelerator"): keep only owned interval
            lo = lax.axis_index(AXIS) * part
            rel = all_dst.reshape(-1) - lo
            ok = all_m.reshape(-1) & (rel >= 0) & (rel < part)
            out = gas.gas_scatter_weighted(
                jnp.clip(rel, 0, part - 1), all_raw.reshape(-1, F),
                jnp.ones_like(rel, f.dtype), ok, part, op=op, impl=impl)
            return out[None]

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=P(AXIS))(feats, src_local, dst_global, weights, mask)

    raise ValueError(dataflow)


# ---------------------------------------------------------------------------
# sampled GraphSAGE aggregation: out[b] = mean_k feats[nbrs[b, k]]
# ---------------------------------------------------------------------------

def aggregate_sampled(
    feats: jax.Array,     # (P, part, F) owner-sharded features
    nbrs: jax.Array,      # (P, B_loc, K) global neighbor ids, seed-sharded
    mask: jax.Array,      # (P, B_loc, K)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",
) -> jax.Array:
    """Returns (P, B_loc, F) mean-aggregated neighbor features per seed."""
    Pn, part, F = feats.shape
    _, B_loc, K = nbrs.shape

    if mesh is None or AXIS not in mesh.axis_names or mesh.shape[AXIS] == 1:
        table = feats.reshape(Pn * part, F)
        g = gas.gas_gather(table, nbrs.reshape(-1)).reshape(Pn, B_loc, K, F)
        g = jnp.where(mask[..., None], g, 0)
        cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1)
        return g.sum(2) / cnt.astype(g.dtype)

    n = mesh.shape[AXIS]

    def shard_fn(f, nb, m):
        f, nb, m = f[0], nb[0], m[0]
        # request broadcast (ids only — tiny; "addresses into the SSD")
        ids = lax.all_gather(nb, AXIS)                   # (n, B_loc, K)
        msk = lax.all_gather(m, AXIS)
        lo = lax.axis_index(AXIS) * part
        rel = ids - lo
        own = msk & (rel >= 0) & (rel < part)
        rows = gas.gas_gather(f, jnp.clip(rel, 0, part - 1).reshape(-1, K))
        rows = jnp.where(own.reshape(-1, K)[..., None], rows.astype(f.dtype), 0)

        if dataflow == "cgtrans":
            # in-SSD aggregation: partial sum per seed, ship (n·B_loc, F)
            part_sum = rows.sum(1).reshape(n, B_loc, F)
            part_cnt = own.sum(-1).astype(f.dtype)       # (n, B_loc)
            tot = lax.all_to_all(part_sum, AXIS, split_axis=0, concat_axis=0,
                                 tiled=False)
            cnt = lax.all_to_all(part_cnt[..., None], AXIS, split_axis=0,
                                 concat_axis=0, tiled=False)
            out = tot.sum(0) / jnp.maximum(cnt.sum(0), 1)
            return out[None]

        # baseline: ship raw (n·B_loc·K, F) neighbor rows to seed owners
        raw = rows.reshape(n, B_loc, K, F)
        raw = lax.all_to_all(raw, AXIS, split_axis=0, concat_axis=0, tiled=False)
        ok = lax.all_to_all(own.reshape(n, B_loc, K)[..., None].astype(f.dtype),
                            AXIS, split_axis=0, concat_axis=0, tiled=False)
        out = raw.sum(0).sum(1) / jnp.maximum(ok.sum(0).sum(1), 1)
        return out[None]

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=P(AXIS))(feats, nbrs, mask)
