"""CGTrans — Compressive Graph Transmission (the paper's §3.2) on a mesh.

The storage tier is the ``data`` mesh axis: each shard owns a vertex interval
(features) and all edges whose *source* lies in it (gathers are local — the
in-SSD invariant). Two dataflows over identical math:

* ``baseline``  — GCNAX-style: ship **raw** gathered neighbor features to the
  destination owner, aggregate there. Interconnect bytes ∝ E·F (or B·K·F for
  sampled SAGE) — the paper's "slow SSD bus" regime.
* ``cgtrans``   — aggregate **at the owner** into per-destination partials and
  ship only those. Interconnect bytes ∝ V·F (or B·F): a fan-in/fan-out×
  compression — the paper's 50×.

Both are exposed full-graph (edge COO) and sampled (GraphSAGE fan-out), and
both run the per-shard reduction on either GAS backend: ``impl="xla"`` (the
jnp oracle) or ``impl="pallas"`` (the FAST-GAS kernel — CAM match + MXU
one-hot contraction + idle-skip; interpret-mode on CPU). ``pallas_call`` has
no shard_map replication rule, so the pallas dataflows trace with the
replication check disabled (``check_vma=False``) — the differential tier in
``tests/test_cgtrans_pallas.py`` is what guards their agreement instead.

``aggregate_sampled`` additionally supports a **chunked request stream**
(``request_chunk=``): instead of all-gathering the whole ``(B_loc, K)`` id
block, the seed block is streamed through a ``lax.scan`` in chunks — the
paper's SSD command-queue analogue — bounding per-shard peak gather memory at
``O(n·chunk·K·F)`` instead of ``O(n·B_loc·K·F)``. The chunked path is
bit-exact with the unchunked one (chunking partitions *seeds*, never a seed's
K contributions), which ``tests/test_cgtrans_pallas.py`` asserts.

**Coalesced request blocks.** ``aggregate_multi`` is the command-queue
batching applied across request *streams*: several sampled segments of
different fan-out (e.g. ``sage_forward``'s K=1 self-row lookup + its K2
2-hop block) concatenate into one (ids ‖ ``SegmentDescriptor``) command
block and run through ONE ``shard_map`` body — one ``all_gather`` of the
concatenated id stream (masks ride a ``-1`` encoding, so the request
broadcast is a single array), one kernel gather (``_multi_find``), one
``all_to_all`` of the concatenated partials (for ``op="add"`` the
contribution counts travel as one extra feature column instead of a second
collective), and — under ``impl="pallas"`` — one backward cotangent
scatter, split per segment by the static descriptor the VJP closes over.
``aggregate_sampled`` is its single-segment form, so the plain sampled path
inherits the single-collective request/response pair too; the K=1 segment
keeps the pure-find specialization (no kernel round-trip), and chunk
boundaries always respect segment boundaries. The coalesce tier
(``tests/test_cgtrans_coalesce.py``, ``ci.sh --tier coalesce``) asserts
coalesced ≡ separate bit-exactly (values and gradients) and pins the
counters: collectives-per-step 2 → 1, finds 2 → 1, backward scatters
2 → 1.

**Locality scheduling.** ``scheduled`` (default: on whenever
``impl="pallas"``) runs the paper's Fig 11(c) locality pass before the
per-shard reduction: ``gas.schedule_edges`` counting-sorts each shard's edge
stream by destination row block, the dataflow permutes the edge LIST once
(ids/weights/mask — O(E) ints; the gathered value stream then arrives binned
for free), and the kernel's idle-skip occupancy collapses to a thin band so
``pl.when`` actually skips. ``build_edge_schedule`` computes the schedule
once per (partition, batch) for reuse across layers (``gcn_forward_full``
hoists it out of its layer loop) and the backward pass; cotangents to the
permuted inputs un-permute through the transpose of the ``take`` that
applied the permutation, so gradients are schedule-invariant
(``tests/test_gas_schedule.py`` asserts bit-exactness on integer data). The
sampled path's seed rows are binned by construction, so its schedule is
sort-free (``assume_sorted``). The baseline dataflow schedules its
destination-side reduction after raw assembly (its shipped bytes are
unchanged — scheduling is always collective-neutral).

``benchmarks/collective_bytes.py`` lowers both on the production mesh and
diffs the collective bytes in the compiled HLO — the mechanism, measured.

**Both dataflows are differentiable on both backends.** The collectives
(``psum_scatter``/``all_gather``/``all_to_all``) carry JAX's own transpose
rules; the only op without one is ``pallas_call``, which is hidden behind the
forward-only custom VJPs in ``repro.core.gas`` (the embedding-lookup
pattern): the backward of the owner-side gather is a FAST-GAS scatter and
the backward of the seed scatter is a masked weighted gather — the reverse
pass is itself in-SSD GAS work, never a transpose through the kernel. Two
consequences visible in this file: the non-add cross-shard combine of
``aggregate_edges`` is an ``all_gather`` + local extremum (``lax.pmax`` has
no differentiation rule at all), and ``_finalize``/``_combine_shards`` mask
the ±inf max/min identity rows to 0 so no downstream ``0·inf`` ever turns a
train-step gradient into NaN. The grad parity tier
(``tests/test_cgtrans_grad.py``) asserts pallas ≡ xla ≡ finite differences
across the whole matrix.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import psum_scatter, shard_map
from repro.core import gas
from repro.core import sparse as sparsefmt
from repro.core import wire as wirefmt

AXIS = "data"  # the storage-tier axis


# ---------------------------------------------------------------------------
# the compressed wire (ROADMAP "make the C in CGTrans real"): the codecs live
# in repro.core.wire (pure transforms); the ONE collective they wrap lives
# here, inside the contract-covered module, so the collective-site allowlist
# never grows. wire="f32" keeps every pre-wire code path byte-identical.
# ---------------------------------------------------------------------------

def _wire_identity(op: gas.Op) -> float:
    """The op identity non-finite int8 codes decode back to (±inf for the
    max/min identity rows; add/or partials are finite so it never fires)."""
    return float(gas._INIT[op]) if op in ("max", "min") else 0.0


def _wired_a2a(x, wire: str, identity: float, n_exact: int):
    enc = wirefmt.encode_payload(x, wire, identity=identity, n_exact=n_exact)
    parts = lax.all_to_all(enc, AXIS, split_axis=0, concat_axis=0,
                           tiled=False)
    return wirefmt.decode_payload(parts, wire, identity=identity,
                                  n_exact=n_exact, out_dtype=x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _wire_all_to_all(x, wire: str, identity: float = 0.0, n_exact: int = 0):
    """``all_to_all`` with the payload encoded for transport and decoded
    (f32 math) on arrival. A ``custom_vjp`` so the codec's ``round``/
    ``where`` never meet autodiff: the backward ships the cotangent block
    through the SAME wire — split==concat axis makes the collective its own
    transpose — so the reverse pass pays the same compressed bytes."""
    return _wired_a2a(x, wire, identity, n_exact)


def _wire_a2a_fwd(x, wire, identity, n_exact):
    return _wired_a2a(x, wire, identity, n_exact), None


def _wire_a2a_bwd(wire, identity, n_exact, _res, g):
    # cotangents carry no ±inf identities (identity 0); the exact trailing
    # columns keep count cotangents exact — they are discarded into the
    # integer mask path anyway, but exactness keeps the wire's error model
    # one sentence: "quantization touches feature values only".
    return (_wired_a2a(g, wire, 0.0, n_exact),)


_wire_all_to_all.defvjp(_wire_a2a_fwd, _wire_a2a_bwd)


def _check_wire(wire: str, dataflow: str, features: str = "dense") -> str:
    """Validate a ``wire=`` knob at trace time. The baseline dataflow is the
    ship-raw strawman — compressing its wire would un-define the comparison
    the byte benches make — so only cgtrans accepts a narrow wire. With
    ``features="sparse"`` the baseline's shipment is the PACKED row block,
    which quantizes exactly like a cgtrans partial block does, so the narrow
    wire becomes legal there too (sparse nonzeros ship bf16/int8 + bitmap)."""
    wirefmt.validate(wire)
    if wire != "f32" and dataflow == "baseline" and features != "sparse":
        raise ValueError(
            "wire compression is a cgtrans-dataflow mechanism; the baseline "
            "strawman ships raw f32 by definition (features='sparse' is the "
            "exception: packed nonzeros quantize like partials)")
    return wire


# ---------------------------------------------------------------------------
# compressed-sparse features (repro.core.sparse): the codec is pure; the
# find that consumes the packed table and the ONE all_to_all that ships a
# packed row block both live HERE, inside the contract-covered module, so
# the collective-site allowlist and the dispatch-tick coverage never grow.
# ---------------------------------------------------------------------------

def _resolve_sparse(features: str, sparse_capacity: Optional[int],
                    n_features: int) -> Optional[int]:
    """``features=`` knob → the packed capacity to run with, or None for
    the dense path. ``features="sparse"`` requires an explicit capacity
    (``sparse.table_capacity(feats)`` — a static host-side measurement, the
    one thing trace-time code cannot derive); a capacity that fails the
    static ``sparse_fits`` gate falls back to dense UNCHANGED — the
    fallback ships exactly the pre-sparse bytes, never a truncated row."""
    if sparsefmt.validate_features(features) == "dense":
        if sparse_capacity is not None:
            raise ValueError(
                "sparse_capacity= only applies with features='sparse'")
        return None
    if sparse_capacity is None:
        raise ValueError(
            "features='sparse' needs sparse_capacity= — measure it once "
            "with sparse.table_capacity(feats) (a static host-side int)")
    cap = int(sparse_capacity)
    if cap < 1:
        raise ValueError(f"sparse_capacity must be ≥ 1, got {cap}")
    return cap if sparsefmt.sparse_fits(cap, n_features) else None


@functools.lru_cache(maxsize=None)
def _sparse_gather(n_rows: int, capacity: int, impl: str):
    """Row gather from the PACKED table — the SSD→host read that scales
    with density: two ``take``s (packed nonzeros in the table dtype + the
    int32 bitmap) move ``capacity + ceil(F/32)`` lanes per row instead of
    F. The decode is positional and the capacity gate is static, so the
    result is bit-exact with the dense gather — which is why ONE custom_vjp
    covers both backends: the backward is the same scatter-add of the
    cotangent rows the dense gather uses (``_gather_pallas`` under pallas —
    the FAST-GAS kernel; a segment-sum under xla, matching the take
    transpose), never a differentiation of the codec's cumsum."""

    @jax.custom_vjp
    def gather(table, ids):
        packed, bitmap = sparsefmt.encode_rows(table, capacity)
        rows = sparsefmt.decode_rows(
            jnp.take(packed, ids, axis=0), jnp.take(bitmap, ids, axis=0),
            table.shape[-1])
        return rows.astype(table.dtype)

    def fwd(table, ids):
        # the zero-size residual carries the table dtype into the bwd cast
        return gather(table, ids), (ids, jnp.zeros((0,), table.dtype))

    def bwd(res, g):
        ids, like = res
        gf = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
        if impl == "pallas":
            dtab = gas._scatter_weighted_impl(ids.reshape(-1), gf, None,
                                              None, n_rows, "add", "pallas")
        else:
            dtab = jax.ops.segment_sum(gf, ids.reshape(-1),
                                       num_segments=n_rows)
        return dtab.astype(like.dtype), np.zeros(np.shape(ids),
                                                 jax.dtypes.float0)

    gather.defvjp(fwd, bwd)
    return gather


def _find(table, ids, *, impl: str, sparse_cap: Optional[int] = None):
    """The find of find-and-compute, density-aware: dense tables route
    through ``gas.gas_gather`` unchanged; a packed capacity swaps in the
    compressed-table gather. Ticks ``find`` exactly once either way, so
    every dispatch budget is features-invariant."""
    if sparse_cap is None:
        return gas.gas_gather(table, ids, impl=impl)
    gas._tick("find")
    return _sparse_gather(table.shape[0], sparse_cap, impl)(table, ids)


def _sparse_ship(x, wire: str, capacity: int):
    """Pack a raw (n, N, F) row block, ship (packed ‖ bitmap) through ONE
    ``all_to_all``, decode on arrival (f32 math under a narrow wire). The
    bitmap always travels as exact bitcast lanes — int16×2 / int8×4 per
    word — so only the nonzero VALUES ever quantize."""
    F = x.shape[-1]
    W = sparsefmt.bitmap_words(F)
    packed, bitmap = sparsefmt.encode_rows(x, capacity)
    if wire == "f32":
        payload = jnp.concatenate(
            [packed, lax.bitcast_convert_type(bitmap, x.dtype)], axis=-1)
        parts = lax.all_to_all(payload, AXIS, split_axis=0, concat_axis=0,
                               tiled=False)
        pk = parts[..., :capacity]
        bm = lax.bitcast_convert_type(parts[..., capacity:], jnp.int32)
        return sparsefmt.decode_rows(pk, bm, F)
    enc = wirefmt.encode_payload(packed.astype(jnp.float32), wire)
    bits16 = lax.bitcast_convert_type(
        bitmap, enc.dtype).reshape(*bitmap.shape[:-1], -1)
    nb = bits16.shape[-1]
    parts = lax.all_to_all(jnp.concatenate([enc, bits16], axis=-1), AXIS,
                           split_axis=0, concat_axis=0, tiled=False)
    pk = wirefmt.decode_payload(parts[..., :parts.shape[-1] - nb], wire)
    bm = lax.bitcast_convert_type(
        parts[..., parts.shape[-1] - nb:].reshape(
            *parts.shape[:-1], W, nb // W), jnp.int32)
    return sparsefmt.decode_rows(pk, bm, F).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _sparse_all_to_all(x, wire: str, capacity: int):
    """The baseline dataflow's raw-row shipment on sparse features: bytes
    on the wire are ``capacity + ceil(F/32)`` lanes per row instead of F —
    the all_to_all bytes scale with density. A ``custom_vjp`` so the
    codec's cumsum/scatter never meets autodiff; the backward ships the
    DENSE cotangent through the plain wired collective (cotangent support
    is not statically knowable — rows that were zero forward can carry
    nonzero cotangents — so compressing it would need a runtime capacity;
    exactness over economy on the reverse path)."""
    return _sparse_ship(x, wire, capacity)


def _sparse_a2a_fwd(x, wire, capacity):
    return _sparse_ship(x, wire, capacity), None


def _sparse_a2a_bwd(wire, capacity, _res, g):
    return (_wired_a2a(g, wire, 0.0, 0),)


_sparse_all_to_all.defvjp(_sparse_a2a_fwd, _sparse_a2a_bwd)


def _check_vma(impl: str) -> Optional[bool]:
    """shard_map replication-check setting for a dataflow using ``impl``.

    ``pallas_call`` has no replication rule (NotImplementedError on trace), so
    pallas dataflows must disable the check; the xla dataflows keep the
    installed default.
    """
    return False if impl == "pallas" else None


def _resolve_scheduled(scheduled: Optional[bool], impl: str) -> bool:
    """The locality pass defaults on exactly where it pays: the kernel."""
    return (impl == "pallas") if scheduled is None else bool(scheduled)


def _permuted(sched, *arrays):
    """Apply an edge schedule's permutation to per-edge arrays. Autodiff
    transposes the ``take`` into the exact un-permuting scatter, so
    cotangents to weights (and values) return in original edge order."""
    return tuple(jnp.take(a, sched.perm, axis=0) for a in arrays)


def is_sharded(mesh: Optional[Mesh]) -> bool:
    return (mesh is not None and AXIS in mesh.axis_names
            and mesh.shape[AXIS] > 1)


def build_edge_schedule(dst_global: jax.Array, mask: jax.Array,
                        n_vertices: int, *, mesh: Optional[Mesh] = None):
    """Destination-binned edge schedule for (P, E) edge arrays — computed
    ONCE per (partition, batch) and reused across layers, feature blocks,
    and the backward pass (pass it to ``aggregate_edges(schedule=...)``).

    On a sharded mesh the schedule is per-shard (every leaf keeps the
    leading P axis and shards with the edges); on the single-shard
    reference path it is one schedule over the flattened edge list.
    """
    if not is_sharded(mesh):
        return gas.schedule_edges(dst_global.reshape(-1), mask.reshape(-1),
                                  n_vertices)
    return jax.vmap(
        lambda d, m: gas.schedule_edges(d, m, n_vertices))(dst_global, mask)


def apply_edge_schedule(schedule, *edge_arrays):
    """Reorder per-shard (P, E) edge arrays into schedule order, ONCE.

    This is the SGCN-style data-format restructuring: pay the permutation
    at partition time, then every layer's aggregation (and its backward)
    consumes the binned edge list directly — pass the results to
    ``aggregate_edges(..., schedule=..., schedule_applied=True)``. Only
    meaningful for per-shard schedules (sharded-mesh layout); local src
    ids, weights and masks all permute shard-locally.
    """
    return tuple(
        jax.vmap(lambda a, p: jnp.take(a, p, axis=0), in_axes=(0, 0))(
            a, schedule.perm)
        for a in edge_arrays)


# ---------------------------------------------------------------------------
# full-graph edge aggregation (GCN):  out[v] = Σ_{(u,v,w)∈E} w · feats[u]
# ---------------------------------------------------------------------------

def _agg_local(feats, src_local, dst_global, w, mask, n_vertices, op, impl,
               schedule=None, sparse_cap=None):
    """In-SSD step: local gather + segment-reduce into global dst bins.

    ``impl`` threads into BOTH halves: under pallas the scatter's VJP is the
    kernel's and the gather's VJP (a scatter of the feature cotangent) runs
    through the kernel too — the backward stays in the in-SSD regime.
    ``schedule``: banded idle-skip bounds for edge arrays that are already
    in schedule order (the caller permutes the edge list, so the gather
    emits the value stream binned). ``sparse_cap`` swaps the gather for the
    compressed-table read (``repro.core.sparse``) — the SSD→host bytes
    scale with density; the reduction itself stays dense (aggregated
    partials have union support).
    """
    gathered = _find(feats, src_local, impl=impl,
                     sparse_cap=sparse_cap)       # LOCAL by construction
    return gas.gas_scatter_weighted(
        dst_global, gathered, w, mask, n_vertices, op=op, impl=impl,
        schedule=schedule)


def aggregate_edges(
    feats: jax.Array,        # (P, part, F) owner-sharded vertex features
    src_local: jax.Array,    # (P, E) local src ids
    dst_global: jax.Array,   # (P, E) global dst ids
    weights: jax.Array,      # (P, E)
    mask: jax.Array,         # (P, E)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",      # cgtrans | baseline
    op: gas.Op = "add",
    impl: str = "xla",
    scheduled: Optional[bool] = None,   # None → on for impl="pallas"
    schedule=None,                      # precomputed build_edge_schedule(...)
    schedule_applied: bool = False,     # edge arrays already in perm order
    wire: str = "f32",                  # f32 | bf16 | int8 (cgtrans only)
    features: str = "dense",            # dense | sparse (repro.core.sparse)
    sparse_capacity: Optional[int] = None,
) -> jax.Array:
    """Returns (P, part, F) aggregated destination features, owner-sharded.

    ``scheduled`` runs the destination-binning locality pass before the
    per-shard reduction (see the module docstring); ``schedule`` supplies a
    precomputed ``build_edge_schedule`` result so multi-layer callers pay
    the counting sort once, and ``schedule_applied=True`` declares the edge
    arrays are ALREADY in schedule order (``apply_edge_schedule`` paid the
    permutation at partition time; sharded-mesh cgtrans flow only). The
    baseline dataflow bins its destination-side reduction after raw
    assembly (a precomputed V-space schedule does not apply there and is
    ignored). ``wire`` selects the transport format of the compressed
    transmission (``repro.core.wire``); the single-shard reference path has
    no interconnect, so there it is validated and otherwise a no-op.
    ``features="sparse"`` (with ``sparse_capacity=`` from
    ``sparse.table_capacity``) reads the feature table through the packed
    compressed-sparse layout — the in-SSD gather bytes scale with density;
    the partial shipments stay dense (union support) and every result is
    bit-exact with the dense path.
    """
    _check_wire(wire, dataflow, features)
    Pn, part, F = feats.shape
    sparse_cap = _resolve_sparse(features, sparse_capacity, F)
    V = Pn * part
    use_sched = _resolve_scheduled(scheduled, impl) or schedule is not None
    if schedule_applied:
        assert schedule is not None, "schedule_applied requires schedule="

    if not is_sharded(mesh):
        # single-shard reference: both dataflows degenerate to one reduction
        assert not schedule_applied, (
            "schedule_applied is a sharded-mesh layout (per-shard perms); "
            "the single-shard path flattens partitions and permutes itself")
        s = (src_local + (jnp.arange(Pn) * part)[:, None]).reshape(-1)
        d, w, m = (dst_global.reshape(-1), weights.reshape(-1),
                   mask.reshape(-1))
        sched = None
        if use_sched:
            sched = (schedule if schedule is not None
                     else gas.schedule_edges(d, m, V))
            s, d, w, m = _permuted(sched, s, d, w, m)
        out = _agg_local(feats.reshape(V, F), s, d, w, m, V, op, impl,
                         schedule=sched, sparse_cap=sparse_cap)
        return out.reshape(Pn, part, F)

    n = mesh.shape[AXIS]
    assert Pn == n, f"partitions ({Pn}) must equal data-axis size ({n})"

    if dataflow == "cgtrans":
        def shard_fn(f, s, d, w, m, *pre_sched):
            # f: (1, part, F); edge arrays (1, E). Per-shard E need not be
            # tile-aligned — the kernel wrapper pads and rebuilds the
            # occupancy map per shard from this shard's (padded) dst ids.
            s, d, w, m = s[0], d[0], w[0], m[0]
            sched = None
            if use_sched:
                sched = (jax.tree.map(lambda a: a[0], pre_sched[0])
                         if pre_sched else gas.schedule_edges(d, m, V))
                if not schedule_applied:
                    s, d, w, m = _permuted(sched, s, d, w, m)
            partial = _agg_local(f[0], s, d, w, m, V, op, impl,
                                 schedule=sched, sparse_cap=sparse_cap)
            # compressed transmission: reduce-scatter the (V, F) partials so
            # each shard receives exactly its owned interval, aggregated.
            if op == "add" and wire == "f32":
                out = psum_scatter(partial.reshape(n, part, F), AXIS,
                                   scatter_dimension=0)
            elif op == "add":
                # a narrow wire cannot ride psum_scatter (it would SUM the
                # quantized codes on the wire — int8 codes from different
                # scales don't add); ship each owner its interval's encoded
                # partials and accumulate in f32 locally. Same bytes-on-wire
                # shape as the max/min path, ÷2 or ÷4 per the format.
                parts = _wire_all_to_all(partial.reshape(n, part, F), wire)
                out = parts.sum(0)
            else:
                # max/min/or have no fused reduce-scatter; ship each owner
                # its interval's partials (all_to_all: V·F bytes per shard,
                # like the add path's reduce-scatter) and reduce locally.
                # (Not lax.pmax/pmin: those have NO differentiation rule,
                # while all_to_all is its own transpose — the grad tier
                # differentiates this flow.) or-partials are ≥ 0, so max
                # realizes boolean-or.
                block = partial.reshape(n, part, F)
                parts = (lax.all_to_all(block, AXIS, split_axis=0,
                                        concat_axis=0, tiled=False)
                         if wire == "f32" else
                         _wire_all_to_all(block, wire, _wire_identity(op)))
                out = parts.min(0) if op == "min" else parts.max(0)
            return out[None]

        args = (feats, src_local, dst_global, weights, mask)
        specs = (P(AXIS),) * 5
        if schedule is not None:
            args += (schedule,)
            specs += (P(AXIS),)
        return shard_map(
            shard_fn, mesh=mesh, in_specs=specs,
            out_specs=P(AXIS), check_vma=_check_vma(impl),
        )(*args)

    if dataflow == "baseline":
        def shard_fn(f, s, d, w, m):
            # raw transmission: gather locally, ship the full edge payload.
            # Weights scale contributions only under op="add" — max/min take
            # the raw feature and or ignores weights entirely (matching
            # gas_scatter_weighted, so baseline ≡ cgtrans ≡ reference).
            raw = _find(f[0], s[0], impl=impl, sparse_cap=sparse_cap)
            if op == "add":
                raw = raw * w[0][:, None].astype(raw.dtype)
            raw = jnp.where(m[0][:, None], raw, 0)
            all_raw = lax.all_gather(raw, AXIS)          # (n, E, F) — E·F·n bytes
            all_dst = lax.all_gather(d[0], AXIS)
            all_m = lax.all_gather(m[0], AXIS)
            # destination side ("the accelerator"): keep only owned interval
            lo = lax.axis_index(AXIS) * part
            rel = all_dst.reshape(-1) - lo
            ok = all_m.reshape(-1) & (rel >= 0) & (rel < part)
            vals = all_raw.reshape(-1, F)
            sched = None
            if use_sched:
                # baseline bins AFTER assembly: the scatter's row space is
                # this owner's interval, which only exists post-all_gather
                # (a precomputed V-space schedule cannot serve it)
                sched = gas.schedule_edges(rel, ok, part)
                rel, ok, vals = _permuted(sched, rel, ok, vals)
            out = gas.gas_scatter_weighted(
                jnp.clip(rel, 0, part - 1), vals,
                jnp.ones_like(rel, jnp.float32), ok, part, op=op, impl=impl,
                schedule=sched)
            return out[None]

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=P(AXIS), check_vma=_check_vma(impl),
        )(feats, src_local, dst_global, weights, mask)

    raise ValueError(dataflow)


# ---------------------------------------------------------------------------
# sampled GraphSAGE aggregation: out[b] = reduce_k feats[nbrs[b, k]]
# ---------------------------------------------------------------------------

def _op_identity(dtype, op: gas.Op):
    """The reduction identity a no-sample row must hold, per dtype — matches
    the segment-reduce empty-segment convention (±inf on floats, the integer
    extremes on ints, 0 for add/or)."""
    if op in ("add", "or"):
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.inexact):
        return jnp.asarray(gas._INIT[op], dtype)
    info = jnp.iinfo(dtype)
    return jnp.asarray(info.min if op == "max" else info.max, dtype)


def _seed_reduce_rows(rows, own, op: gas.Op, impl: str,
                      scheduled: bool = False):
    """Per-request-block GAS reduction on PRE-GATHERED candidate rows:
    (R, K, F) rows + (R, K) validity → (R, F) partials + (R,) own counts.

    This is the in-SSD step of the sampled path — the seed index is the
    destination row, so the fan-out reduction is exactly a FAST-GAS scatter
    (``impl`` selects the backend). Rows with no owned neighbor hold the op
    identity (0 for add/or, ±inf for max/min). The gather itself is the
    caller's (``aggregate_multi`` issues ONE combined gather for a whole
    coalesced command block and slices it per segment). The seed stream
    ``repeat(arange(R), K)`` is destination-binned by construction, so
    ``scheduled`` derives the idle-skip band sort-free (``assume_sorted``)
    — no permutation is ever applied here; the schedule is only built
    where it is consumed (the pallas kernel — XLA ignores it).
    """
    R, K, F = rows.shape
    if K == 1:
        # a single-sample request block is a pure *find*: the seed scatter
        # would be the identity permutation, so the reduction degenerates to
        # masking the gathered row with the op identity — no kernel
        # round-trip (the gather's VJP still scatters through the kernel
        # under pallas). This is the row-lookup path of ``sage_forward``.
        flat = rows.reshape(R, F)
        if op == "or":
            # mirror the scatter path's boolean-or normalization exactly:
            # int-cast the value, clamp the or-identity at 0 (a raw
            # passthrough would leak negative/fractional values)
            red = jnp.where(own.reshape(R, 1),
                            jnp.maximum(flat.astype(jnp.int32), 0),
                            0).astype(flat.dtype)
        else:
            red = jnp.where(own.reshape(R, 1), flat,
                            _op_identity(flat.dtype, op))
        return red, own.sum(-1)
    seed = jnp.repeat(jnp.arange(R, dtype=jnp.int32), K)
    sched = (gas.schedule_edges(seed, own.reshape(-1), R, assume_sorted=True)
             if scheduled and impl == "pallas" else None)
    red = gas.gas_scatter_weighted(
        seed, rows.reshape(R * K, F), jnp.ones((R * K,), jnp.float32),
        own.reshape(-1), R, op=op, impl=impl, schedule=sched)
    return red, own.sum(-1)


def _mask_identity_rows(out, op: gas.Op):
    """Zero the ±inf max/min identity rows (seeds with no valid sample).

    Applied at every *terminal* finalize (never on pre-combine partials —
    a shard with no sample for a seed must still contribute the identity to
    the cross-shard extremum). Keeping ±inf here would make any downstream
    use produce ``0·inf = NaN`` under autodiff — the classic silent
    train-step NaN — so identity rows now read 0 on every op, matching
    add/or, and their cotangent is cut at the ``where``.
    """
    if op in ("max", "min"):
        return jnp.where(jnp.isfinite(out), out, 0)
    return out


def _finalize(red, cnt, op: gas.Op):
    """Partial → output rows: mean for add, identity-masked passthrough
    otherwise (terminal positions only — see ``aggregate_sampled``)."""
    if op == "add":
        return red / jnp.maximum(cnt, 1).astype(red.dtype)[..., None]
    return _mask_identity_rows(red, op)


def _combine_shards(parts, cnts, op: gas.Op):
    """(n, B, F) per-source-shard partials (+ (n, B) counts) → (B, F)."""
    if op == "add":
        return parts.sum(0) / jnp.maximum(cnts.sum(0), 1).astype(parts.dtype)[..., None]
    if op in ("max", "or"):
        return _mask_identity_rows(parts.max(0), op)
    return _mask_identity_rows(parts.min(0), op)


def _pad_rows(x, mult, fill):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1), constant_values=fill)


def scan_request_chunks(body, nbrs2d, mask2d, chunk: int):
    """Stream the (R, K) request block through ``body`` in row chunks.

    The SSD command-queue analogue: requests are issued ``chunk`` rows at a
    time; padded rows are all-masked so they reduce to the op identity and
    are sliced off. Chunking partitions rows (never a row's K entries), so
    the result is bit-exact with one full-block ``body`` call. ``body`` maps
    an (chunk, K) id/mask pair to (chunk, F) output rows. Shared with the
    chunked embedding lookup (``repro.models.embedding``).
    """
    R = nbrs2d.shape[0]
    chunk = max(1, min(chunk, R))
    nb = _pad_rows(nbrs2d, chunk, 0)
    mk = _pad_rows(mask2d, chunk, False)
    steps = nb.shape[0] // chunk

    def step(_, inp):
        return None, body(*inp)

    _, outs = lax.scan(step, None,
                       (nb.reshape(steps, chunk, -1), mk.reshape(steps, chunk, -1)))
    return outs.reshape(steps * chunk, -1)[:R]


class SegmentDescriptor(NamedTuple):
    """Static layout of a coalesced request block (one "SSD command block").

    A coalesced block concatenates S request segments — each a
    ``(rows_i, K_i)`` id/mask pair — into one flat id stream. The
    descriptor records where every segment lives in that stream so the ONE
    combined gather / all_to_all can be split back into per-segment
    results, forward and backward. All fields are static Python ints:
    under ``jit`` the descriptor is baked into the jaxpr (and closed over
    by the custom-VJP residuals of the pallas gather), so the backward
    splits the cotangent block along exactly the same boundaries — no
    runtime bookkeeping crosses the bus.

    ``shapes``       — per-segment (rows_i, K_i);
    ``id_offsets``   — flat-id offset of each segment (length S+1;
                       segment i's ids live at ``[id_offsets[i],
                       id_offsets[i+1])``, so ``id_offsets[-1]`` is the
                       total id count);
    ``row_offsets``  — output-row offset of each segment (length S+1) in
                       the concatenated (rows_tot, F) result block;
    ``tenants``      — per-segment OWNER tags (length S, or None for the
                       single-caller case). A serving command block fuses
                       request segments from several concurrent callers;
                       the tenant tag is what scatters each segment's rows
                       back to the caller that issued them and nobody else
                       (``repro.serving`` is the consumer; the serving tier
                       asserts results never cross callers).
    """
    shapes: Tuple[Tuple[int, int], ...]
    id_offsets: Tuple[int, ...]
    row_offsets: Tuple[int, ...]
    tenants: Optional[Tuple[int, ...]] = None

    @property
    def n_ids(self) -> int:
        return self.id_offsets[-1]

    @property
    def n_rows(self) -> int:
        return self.row_offsets[-1]

    def segments_of(self, tenant: int) -> Tuple[int, ...]:
        """Indices of the segments owned by ``tenant`` (in block order)."""
        if self.tenants is None:
            raise ValueError("descriptor carries no tenant tags")
        return tuple(i for i, t in enumerate(self.tenants) if t == tenant)


def segment_descriptor(shapes: Sequence[Tuple[int, int]],
                       tenants: Optional[Sequence[int]] = None
                       ) -> SegmentDescriptor:
    """Build the descriptor for segments of static (rows_i, K_i) shapes.

    ``tenants`` (optional) tags each segment with the caller that owns it —
    the cross-request serving engine fuses many callers' segments into one
    command block and uses the tags to scatter results back per caller.
    """
    shapes = tuple((int(r), int(k)) for r, k in shapes)
    if not shapes:
        raise ValueError("a request block needs at least one segment")
    if any(r < 1 or k < 1 for r, k in shapes):
        raise ValueError(f"degenerate segment in {shapes}")
    if tenants is not None:
        tenants = tuple(int(t) for t in tenants)
        if len(tenants) != len(shapes):
            raise ValueError(
                f"tenant tags ({len(tenants)}) must match segments "
                f"({len(shapes)})")
    ids, rows = [0], [0]
    for r, k in shapes:
        ids.append(ids[-1] + r * k)
        rows.append(rows[-1] + r)
    return SegmentDescriptor(shapes, tuple(ids), tuple(rows), tenants)


def _encode_requests(blocks):
    """Encode each (nbrs, mask) segment as one id stream with masked
    entries set to -1 — the request broadcast then carries ONE array
    instead of an (ids, mask) pair: a dead id resolves as owned-by-nobody
    on every shard (``rel < 0`` everywhere), which is exactly what the
    mask meant. Returns the (P, N_tot) concatenated stream."""
    flat = [jnp.where(m, nb, -1).reshape(nb.shape[0], -1)
            for nb, m in blocks]
    return flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=1)


def _multi_find(table, seg_ids, op: gas.Op, impl: str, use_sched: bool,
                sparse_cap: Optional[int] = None):
    """The in-SSD step of a coalesced command block: ONE combined gather
    over every segment's encoded ids, then the per-segment seed reductions.

    ``table``: (rows, F) local feature rows; ``seg_ids``: list of
    (R_i, K_i) encoded id blocks (-1 or out-of-range = dead). Exactly one
    ``gas_gather`` is issued regardless of segment count — under pallas its
    custom VJP therefore scatter-adds the whole block's cotangent through
    the kernel in ONE backward dispatch, split per segment by the same
    static offsets. ``sparse_cap`` swaps the gather for the packed
    compressed-table read (one find either way). Returns a list of
    (red_i (R_i, F), cnt_i (R_i,))."""
    V, F = table.shape
    flat = (seg_ids[0].reshape(-1) if len(seg_ids) == 1 else
            jnp.concatenate([s.reshape(-1) for s in seg_ids]))
    own = (flat >= 0) & (flat < V)
    rows = _find(table, jnp.clip(flat, 0, V - 1), impl=impl,
                 sparse_cap=sparse_cap)
    outs, off = [], 0
    for s in seg_ids:
        R, K = s.shape
        outs.append(_seed_reduce_rows(
            rows[off:off + R * K].reshape(R, K, F),
            own[off:off + R * K].reshape(R, K), op, impl, use_sched))
        off += R * K
    return outs


def aggregate_multi(
    feats: jax.Array,     # (P, part, F) owner-sharded features
    blocks,               # sequence of (nbrs (P, R_i, K_i), mask) segments
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",
    op: gas.Op = "add",
    impl: str = "xla",
    request_chunk: Optional[int] = None,
    scheduled: Optional[bool] = None,   # None → on for impl="pallas"
    wire: str = "f32",                  # f32 | bf16 | int8 (cgtrans only)
    features: str = "dense",            # dense | sparse (repro.core.sparse)
    sparse_capacity: Optional[int] = None,
):
    """Coalesced request blocks: aggregate SEVERAL sampled request segments
    in ONE SSD command block. Returns a tuple of (P, R_i, F), one per
    segment, each exactly what ``aggregate_sampled`` would return for that
    segment alone (bit-exact on integer-valued data — the coalesce tier
    asserts it, values and gradients).

    This is the paper's command-queue batching applied across *request
    streams*, not just within one: ``sage_forward``'s self-row lookup (a
    K=1 pure find) and its 2-hop aggregation used to run as two
    ``shard_map`` bodies — two request broadcasts, two kernel gathers, two
    result shipments, two backward scatters per step. Here the segments
    concatenate into one (ids ‖ segment-descriptor) block and the sharded
    body runs ONCE:

    * **one request broadcast** — a single ``all_gather`` of the
      concatenated id stream (masks ride the ``-1`` encoding, so no second
      mask collective);
    * **one kernel gather** — ``_multi_find`` resolves every segment's ids
      against the local rows in one ``gas_gather``; per-segment reductions
      stay separate (a K=1 segment stays the pure find with no kernel
      round-trip, K>1 segments keep their sort-free banded schedules);
    * **one result shipment** — per-segment partials (plus, for
      ``op="add"``, the contribution counts as one extra feature column)
      concatenate into a single ``all_to_all`` payload, split back on
      arrival by the static ``SegmentDescriptor``;
    * **one cotangent scatter** — under ``impl="pallas"`` the combined
      gather's custom VJP scatters the whole block's cotangent through the
      FAST-GAS kernel in one dispatch; the descriptor (closed over as a
      static residual) splits the cotangent block the same way the forward
      split the results.

    ``request_chunk`` streams each segment through the collectives
    ``request_chunk`` rows at a time; chunk boundaries always respect the
    segment descriptor (a chunk never spans two segments — their K differ),
    so chunked mode degenerates to per-segment command queues and stays
    bit-exact with the unchunked block.

    ``wire`` compresses BOTH collectives (``repro.core.wire``): the request
    broadcast ships int16 delta-encoded ids (when the vertex range permits
    — a static gate; the ``-1`` encoding is preserved exactly) and the
    result shipment ships bf16 or per-row-scaled int8 partials, decoded to
    f32 before any accumulation. The backward cotangent block takes the
    same wire. ``wire="f32"`` traces byte-identically to the pre-wire code;
    the unsharded reference path has no interconnect, so wire is a no-op
    there (validated, then ignored).

    ``features="sparse"`` (capacity from ``sparse.table_capacity``) reads
    the local table through the packed compressed-sparse layout on BOTH
    dataflows (the SSD→host gather bytes scale with density), and on the
    baseline dataflow additionally ships the raw row block as
    (packed nonzeros ‖ occupancy bitmap) through the same single
    ``all_to_all`` — composing with a narrow wire, the nonzeros quantize
    while the bitmap rides exact. cgtrans partial shipments stay dense
    (aggregated rows have union support). Bit-exact with dense, values and
    gradients, under the static capacity gate; a capacity that fails
    ``sparse.sparse_fits`` falls back to the unchanged dense path.
    """
    if dataflow not in ("cgtrans", "baseline"):
        raise ValueError(dataflow)
    _check_wire(wire, dataflow, features)
    blocks = tuple(blocks)
    Pn, part, F = feats.shape
    sparse_cap = _resolve_sparse(features, sparse_capacity, F)
    desc = segment_descriptor([nb.shape[-2:] for nb, _ in blocks])
    use_sched = _resolve_scheduled(scheduled, impl)
    enc = _encode_requests(blocks)                       # (P, N_tot)

    def split_ids(flat):
        """Flat (… N_tot) stream → per-segment (…·R_i, K_i) blocks."""
        return [flat[..., desc.id_offsets[i]:desc.id_offsets[i + 1]]
                .reshape(-1, k)
                for i, (r, k) in enumerate(desc.shapes)]

    if not is_sharded(mesh):
        table = feats.reshape(Pn * part, F)
        seg_enc = split_ids(enc)                         # (Pn·R_i, K_i)
        if request_chunk is None:
            outs = [_finalize(red, cnt, op)
                    for red, cnt in _multi_find(table, seg_enc, op, impl,
                                                use_sched, sparse_cap)]
        else:
            def one(nb_c, m_c):
                red, cnt = _multi_find(table, [jnp.where(m_c, nb_c, -1)],
                                       op, impl, use_sched, sparse_cap)[0]
                return _finalize(red, cnt, op)

            outs = [scan_request_chunks(one, e, e >= 0, request_chunk)
                    for e in seg_enc]
        return tuple(o.reshape(Pn, r, F)
                     for o, (r, k) in zip(outs, desc.shapes))

    n = mesh.shape[AXIS]
    assert Pn == n, f"partitions ({Pn}) must equal data-axis size ({n})"

    def shard_fn(f, ids_enc):
        f, ids_enc = f[0], ids_enc[0]                    # (part, F), (N_tot,)
        lo = lax.axis_index(AXIS) * part

        def fetch(seg_enc):
            """ONE command block over local segments [(r_i, k_i) encoded
            ids] → list of (r_i, F) aggregated rows for OUR seeds."""
            shapes = [s.shape for s in seg_enc]
            flat = (seg_enc[0].reshape(-1) if len(seg_enc) == 1 else
                    jnp.concatenate([s.reshape(-1) for s in seg_enc]))
            # the request broadcast: ONE all_gather of the concatenated id
            # stream ("addresses into the SSD" — masks ride the encoding).
            # On a narrow wire the stream ships as int16 first-order deltas
            # (half the bytes) whenever the vertex range statically fits —
            # the cumsum decode restores every id, -1 dead codes included.
            if wire != "f32" and wirefmt.delta_ids_fit(n * part):
                ids = wirefmt.delta_decode_ids(
                    lax.all_gather(wirefmt.delta_encode_ids(flat), AXIS))
            else:
                ids = lax.all_gather(flat, AXIS)         # (n, N)
            rel = ids - lo                               # dead ids stay < 0

            if dataflow == "cgtrans":
                # one source of truth for the segment layout: the same
                # descriptor arithmetic callers and the VJP split by
                offs = segment_descriptor(shapes).id_offsets
                seg_rel = [rel[:, offs[i]:offs[i + 1]].reshape(n * r, k)
                           for i, (r, k) in enumerate(shapes)]
                # in-SSD aggregation: ONE gather, per-segment reductions
                found = _multi_find(f, seg_rel, op, impl, use_sched,
                                    sparse_cap)
                reds = [red.reshape(n, r, F)
                        for (red, _), (r, k) in zip(found, shapes)]
                payload = reds[0] if len(reds) == 1 else jnp.concatenate(
                    reds, axis=1)                        # (n, R_tot, F)
                if op == "add":
                    cnts = [cnt.reshape(n, r).astype(f.dtype)
                            for (_, cnt), (r, k) in zip(found, shapes)]
                    cnt = (cnts[0] if len(cnts) == 1 else
                           jnp.concatenate(cnts, axis=1))
                    # the counts ride the payload as one extra feature
                    # column — compressed transmission stays ONE collective
                    payload = jnp.concatenate([payload, cnt[..., None]],
                                              axis=-1)
                if wire == "f32":
                    parts = lax.all_to_all(payload, AXIS, split_axis=0,
                                           concat_axis=0, tiled=False)
                else:
                    # quantize the shipment; the add path's count column is
                    # an "exact" column (int8 bitcasts it; bf16 carries
                    # integer counts ≤ 256 exactly) so the mean never
                    # divides by a quantized count
                    parts = _wire_all_to_all(
                        payload, wire, _wire_identity(op),
                        1 if op == "add" else 0)
                outs, roff = [], 0
                for r, k in shapes:
                    seg = parts[:, roff:roff + r]
                    roff += r
                    outs.append(_combine_shards(seg[..., :F], seg[..., F],
                                                op) if op == "add"
                                else _combine_shards(seg, None, op))
                return outs

            # baseline: gather once, ship the raw (n, N, F) rows plus the
            # ownership bits to the seed owners, reduce there ("the
            # accelerator") — also through the GAS engine.
            own = (rel >= 0) & (rel < part)
            rows = _find(f, jnp.clip(rel, 0, part - 1).reshape(-1),
                         impl=impl, sparse_cap=sparse_cap).reshape(n, -1, F)
            rows = jnp.where(own[..., None], rows, 0)
            if sparse_cap is not None and rows.dtype.itemsize == 4:
                # the raw shipment, packed: non-owned rows were just zeroed
                # (popcount 0) and owned rows fit the table's capacity gate,
                # so the SAME static capacity covers every shipped row
                raw = _sparse_all_to_all(rows, wire, sparse_cap)
            else:
                # sub-32-bit tables (bf16 serving) keep the dense ship: an
                # int32 bitmap has no 16-bit bitcast lane to ride in
                raw = lax.all_to_all(rows, AXIS, split_axis=0,
                                     concat_axis=0, tiled=False)  # (n, N, F)
            okk = lax.all_to_all(own[..., None], AXIS, split_axis=0,
                                 concat_axis=0, tiled=False)[..., 0]
            outs, off = [], 0
            for r, k in shapes:
                sl = slice(off, off + r * k)
                off += r * k
                # every source shard's k candidates line up per seed row:
                # (r, n·k) — the destination-side reduce is a seed scatter
                seg_rows = raw[:, sl].reshape(n, r, k, F).transpose(
                    1, 0, 2, 3).reshape(r, n * k, F)
                seg_ok = okk[:, sl].reshape(n, r, k).transpose(
                    1, 0, 2).reshape(r, n * k)
                red, cnt = _seed_reduce_rows(seg_rows, seg_ok, op, impl,
                                             use_sched)
                outs.append(_finalize(red, cnt, op))
            return outs

        if request_chunk is None:
            outs = fetch(split_ids(ids_enc))
        else:
            # the chunked command queue respects segment boundaries: each
            # segment streams separately (their K differ, so a chunk can
            # never span two segments)
            def one(nb_c, m_c):
                return fetch([jnp.where(m_c, nb_c, -1)])[0]

            outs = [scan_request_chunks(one, e, e >= 0, request_chunk)
                    for e in split_ids(ids_enc)]
        return tuple(o[None] for o in outs)

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS)),
        out_specs=tuple(P(AXIS) for _ in blocks), check_vma=_check_vma(impl),
    )(feats, enc)


def aggregate_sampled(
    feats: jax.Array,     # (P, part, F) owner-sharded features
    nbrs: jax.Array,      # (P, B_loc, K) global neighbor ids, seed-sharded
    mask: jax.Array,      # (P, B_loc, K)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",
    op: gas.Op = "add",
    impl: str = "xla",
    request_chunk: Optional[int] = None,
    scheduled: Optional[bool] = None,   # None → on for impl="pallas"
    wire: str = "f32",                  # f32 | bf16 | int8 (cgtrans only)
    features: str = "dense",            # dense | sparse (repro.core.sparse)
    sparse_capacity: Optional[int] = None,
) -> jax.Array:
    """Returns (P, B_loc, F) aggregated neighbor features per seed.

    ``op="add"`` is the masked *mean* (GraphSAGE); max/min/or reduce
    elementwise over the valid samples. Seeds with no valid sample read 0 on
    every op — the ±inf max/min identities are masked at the terminal
    finalize (``_mask_identity_rows``) so autodiff never meets ``0·inf``.
    ``impl`` selects the GAS backend for every per-shard reduction (both
    backends differentiate; under pallas the backward runs through the
    FAST-GAS kernel); ``request_chunk`` streams the seed block through the
    collectives ``request_chunk`` seeds at a time; ``scheduled`` turns the
    per-shard reductions' idle-skip occupancy into the sort-free banded form
    (seed rows are destination-binned by construction).

    This is the single-segment form of ``aggregate_multi`` — one code path,
    so every coalesced mechanism (mask-encoded request broadcast, count
    column riding the payload) serves the plain sampled entry too: one
    ``all_gather`` + one ``all_to_all`` per request burst on the cgtrans
    dataflow.
    """
    out, = aggregate_multi(feats, ((nbrs, mask),), mesh=mesh,
                           dataflow=dataflow, op=op, impl=impl,
                           request_chunk=request_chunk, scheduled=scheduled,
                           wire=wire, features=features,
                           sparse_capacity=sparse_capacity)
    return out
