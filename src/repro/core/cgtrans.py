"""CGTrans — Compressive Graph Transmission (the paper's §3.2) on a mesh.

The storage tier is the ``data`` mesh axis: each shard owns a vertex interval
(features) and all edges whose *source* lies in it (gathers are local — the
in-SSD invariant). Two dataflows over identical math:

* ``baseline``  — GCNAX-style: ship **raw** gathered neighbor features to the
  destination owner, aggregate there. Interconnect bytes ∝ E·F (or B·K·F for
  sampled SAGE) — the paper's "slow SSD bus" regime.
* ``cgtrans``   — aggregate **at the owner** into per-destination partials and
  ship only those. Interconnect bytes ∝ V·F (or B·F): a fan-in/fan-out×
  compression — the paper's 50×.

Both are exposed full-graph (edge COO) and sampled (GraphSAGE fan-out), and
both run the per-shard reduction on either GAS backend: ``impl="xla"`` (the
jnp oracle) or ``impl="pallas"`` (the FAST-GAS kernel — CAM match + MXU
one-hot contraction + idle-skip; interpret-mode on CPU). ``pallas_call`` has
no shard_map replication rule, so the pallas dataflows trace with the
replication check disabled (``check_vma=False``) — the differential tier in
``tests/test_cgtrans_pallas.py`` is what guards their agreement instead.

``aggregate_sampled`` additionally supports a **chunked request stream**
(``request_chunk=``): instead of all-gathering the whole ``(B_loc, K)`` id
block, the seed block is streamed through a ``lax.scan`` in chunks — the
paper's SSD command-queue analogue — bounding per-shard peak gather memory at
``O(n·chunk·K·F)`` instead of ``O(n·B_loc·K·F)``. The chunked path is
bit-exact with the unchunked one (chunking partitions *seeds*, never a seed's
K contributions), which ``tests/test_cgtrans_pallas.py`` asserts.

**Locality scheduling.** ``scheduled`` (default: on whenever
``impl="pallas"``) runs the paper's Fig 11(c) locality pass before the
per-shard reduction: ``gas.schedule_edges`` counting-sorts each shard's edge
stream by destination row block, the dataflow permutes the edge LIST once
(ids/weights/mask — O(E) ints; the gathered value stream then arrives binned
for free), and the kernel's idle-skip occupancy collapses to a thin band so
``pl.when`` actually skips. ``build_edge_schedule`` computes the schedule
once per (partition, batch) for reuse across layers (``gcn_forward_full``
hoists it out of its layer loop) and the backward pass; cotangents to the
permuted inputs un-permute through the transpose of the ``take`` that
applied the permutation, so gradients are schedule-invariant
(``tests/test_gas_schedule.py`` asserts bit-exactness on integer data). The
sampled path's seed rows are binned by construction, so its schedule is
sort-free (``assume_sorted``). The baseline dataflow schedules its
destination-side reduction after raw assembly (its shipped bytes are
unchanged — scheduling is always collective-neutral).

``benchmarks/collective_bytes.py`` lowers both on the production mesh and
diffs the collective bytes in the compiled HLO — the mechanism, measured.

**Both dataflows are differentiable on both backends.** The collectives
(``psum_scatter``/``all_gather``/``all_to_all``) carry JAX's own transpose
rules; the only op without one is ``pallas_call``, which is hidden behind the
forward-only custom VJPs in ``repro.core.gas`` (the embedding-lookup
pattern): the backward of the owner-side gather is a FAST-GAS scatter and
the backward of the seed scatter is a masked weighted gather — the reverse
pass is itself in-SSD GAS work, never a transpose through the kernel. Two
consequences visible in this file: the non-add cross-shard combine of
``aggregate_edges`` is an ``all_gather`` + local extremum (``lax.pmax`` has
no differentiation rule at all), and ``_finalize``/``_combine_shards`` mask
the ±inf max/min identity rows to 0 so no downstream ``0·inf`` ever turns a
train-step gradient into NaN. The grad parity tier
(``tests/test_cgtrans_grad.py``) asserts pallas ≡ xla ≡ finite differences
across the whole matrix.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import psum_scatter, shard_map
from repro.core import gas

AXIS = "data"  # the storage-tier axis


def _check_vma(impl: str) -> Optional[bool]:
    """shard_map replication-check setting for a dataflow using ``impl``.

    ``pallas_call`` has no replication rule (NotImplementedError on trace), so
    pallas dataflows must disable the check; the xla dataflows keep the
    installed default.
    """
    return False if impl == "pallas" else None


def _resolve_scheduled(scheduled: Optional[bool], impl: str) -> bool:
    """The locality pass defaults on exactly where it pays: the kernel."""
    return (impl == "pallas") if scheduled is None else bool(scheduled)


def _permuted(sched, *arrays):
    """Apply an edge schedule's permutation to per-edge arrays. Autodiff
    transposes the ``take`` into the exact un-permuting scatter, so
    cotangents to weights (and values) return in original edge order."""
    return tuple(jnp.take(a, sched.perm, axis=0) for a in arrays)


def is_sharded(mesh: Optional[Mesh]) -> bool:
    return (mesh is not None and AXIS in mesh.axis_names
            and mesh.shape[AXIS] > 1)


def build_edge_schedule(dst_global: jax.Array, mask: jax.Array,
                        n_vertices: int, *, mesh: Optional[Mesh] = None):
    """Destination-binned edge schedule for (P, E) edge arrays — computed
    ONCE per (partition, batch) and reused across layers, feature blocks,
    and the backward pass (pass it to ``aggregate_edges(schedule=...)``).

    On a sharded mesh the schedule is per-shard (every leaf keeps the
    leading P axis and shards with the edges); on the single-shard
    reference path it is one schedule over the flattened edge list.
    """
    if not is_sharded(mesh):
        return gas.schedule_edges(dst_global.reshape(-1), mask.reshape(-1),
                                  n_vertices)
    return jax.vmap(
        lambda d, m: gas.schedule_edges(d, m, n_vertices))(dst_global, mask)


def apply_edge_schedule(schedule, *edge_arrays):
    """Reorder per-shard (P, E) edge arrays into schedule order, ONCE.

    This is the SGCN-style data-format restructuring: pay the permutation
    at partition time, then every layer's aggregation (and its backward)
    consumes the binned edge list directly — pass the results to
    ``aggregate_edges(..., schedule=..., schedule_applied=True)``. Only
    meaningful for per-shard schedules (sharded-mesh layout); local src
    ids, weights and masks all permute shard-locally.
    """
    return tuple(
        jax.vmap(lambda a, p: jnp.take(a, p, axis=0), in_axes=(0, 0))(
            a, schedule.perm)
        for a in edge_arrays)


# ---------------------------------------------------------------------------
# full-graph edge aggregation (GCN):  out[v] = Σ_{(u,v,w)∈E} w · feats[u]
# ---------------------------------------------------------------------------

def _agg_local(feats, src_local, dst_global, w, mask, n_vertices, op, impl,
               schedule=None):
    """In-SSD step: local gather + segment-reduce into global dst bins.

    ``impl`` threads into BOTH halves: under pallas the scatter's VJP is the
    kernel's and the gather's VJP (a scatter of the feature cotangent) runs
    through the kernel too — the backward stays in the in-SSD regime.
    ``schedule``: banded idle-skip bounds for edge arrays that are already
    in schedule order (the caller permutes the edge list, so the gather
    emits the value stream binned).
    """
    gathered = gas.gas_gather(feats, src_local, impl=impl)  # LOCAL by construction
    return gas.gas_scatter_weighted(
        dst_global, gathered, w, mask, n_vertices, op=op, impl=impl,
        schedule=schedule)


def aggregate_edges(
    feats: jax.Array,        # (P, part, F) owner-sharded vertex features
    src_local: jax.Array,    # (P, E) local src ids
    dst_global: jax.Array,   # (P, E) global dst ids
    weights: jax.Array,      # (P, E)
    mask: jax.Array,         # (P, E)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",      # cgtrans | baseline
    op: gas.Op = "add",
    impl: str = "xla",
    scheduled: Optional[bool] = None,   # None → on for impl="pallas"
    schedule=None,                      # precomputed build_edge_schedule(...)
    schedule_applied: bool = False,     # edge arrays already in perm order
) -> jax.Array:
    """Returns (P, part, F) aggregated destination features, owner-sharded.

    ``scheduled`` runs the destination-binning locality pass before the
    per-shard reduction (see the module docstring); ``schedule`` supplies a
    precomputed ``build_edge_schedule`` result so multi-layer callers pay
    the counting sort once, and ``schedule_applied=True`` declares the edge
    arrays are ALREADY in schedule order (``apply_edge_schedule`` paid the
    permutation at partition time; sharded-mesh cgtrans flow only). The
    baseline dataflow bins its destination-side reduction after raw
    assembly (a precomputed V-space schedule does not apply there and is
    ignored).
    """
    Pn, part, F = feats.shape
    V = Pn * part
    use_sched = _resolve_scheduled(scheduled, impl) or schedule is not None
    if schedule_applied:
        assert schedule is not None, "schedule_applied requires schedule="

    if not is_sharded(mesh):
        # single-shard reference: both dataflows degenerate to one reduction
        assert not schedule_applied, (
            "schedule_applied is a sharded-mesh layout (per-shard perms); "
            "the single-shard path flattens partitions and permutes itself")
        s = (src_local + (jnp.arange(Pn) * part)[:, None]).reshape(-1)
        d, w, m = (dst_global.reshape(-1), weights.reshape(-1),
                   mask.reshape(-1))
        sched = None
        if use_sched:
            sched = (schedule if schedule is not None
                     else gas.schedule_edges(d, m, V))
            s, d, w, m = _permuted(sched, s, d, w, m)
        out = _agg_local(feats.reshape(V, F), s, d, w, m, V, op, impl,
                         schedule=sched)
        return out.reshape(Pn, part, F)

    n = mesh.shape[AXIS]
    assert Pn == n, f"partitions ({Pn}) must equal data-axis size ({n})"

    if dataflow == "cgtrans":
        def shard_fn(f, s, d, w, m, *pre_sched):
            # f: (1, part, F); edge arrays (1, E). Per-shard E need not be
            # tile-aligned — the kernel wrapper pads and rebuilds the
            # occupancy map per shard from this shard's (padded) dst ids.
            s, d, w, m = s[0], d[0], w[0], m[0]
            sched = None
            if use_sched:
                sched = (jax.tree.map(lambda a: a[0], pre_sched[0])
                         if pre_sched else gas.schedule_edges(d, m, V))
                if not schedule_applied:
                    s, d, w, m = _permuted(sched, s, d, w, m)
            partial = _agg_local(f[0], s, d, w, m, V, op, impl,
                                 schedule=sched)
            # compressed transmission: reduce-scatter the (V, F) partials so
            # each shard receives exactly its owned interval, aggregated.
            if op == "add":
                out = psum_scatter(partial.reshape(n, part, F), AXIS,
                                   scatter_dimension=0)
            else:
                # max/min/or have no fused reduce-scatter; ship each owner
                # its interval's partials (all_to_all: V·F bytes per shard,
                # like the add path's reduce-scatter) and reduce locally.
                # (Not lax.pmax/pmin: those have NO differentiation rule,
                # while all_to_all is its own transpose — the grad tier
                # differentiates this flow.) or-partials are ≥ 0, so max
                # realizes boolean-or.
                parts = lax.all_to_all(partial.reshape(n, part, F), AXIS,
                                       split_axis=0, concat_axis=0,
                                       tiled=False)          # (n, part, F)
                out = parts.min(0) if op == "min" else parts.max(0)
            return out[None]

        args = (feats, src_local, dst_global, weights, mask)
        specs = (P(AXIS),) * 5
        if schedule is not None:
            args += (schedule,)
            specs += (P(AXIS),)
        return shard_map(
            shard_fn, mesh=mesh, in_specs=specs,
            out_specs=P(AXIS), check_vma=_check_vma(impl),
        )(*args)

    if dataflow == "baseline":
        def shard_fn(f, s, d, w, m):
            # raw transmission: gather locally, ship the full edge payload.
            # Weights scale contributions only under op="add" — max/min take
            # the raw feature and or ignores weights entirely (matching
            # gas_scatter_weighted, so baseline ≡ cgtrans ≡ reference).
            raw = gas.gas_gather(f[0], s[0], impl=impl)
            if op == "add":
                raw = raw * w[0][:, None].astype(raw.dtype)
            raw = jnp.where(m[0][:, None], raw, 0)
            all_raw = lax.all_gather(raw, AXIS)          # (n, E, F) — E·F·n bytes
            all_dst = lax.all_gather(d[0], AXIS)
            all_m = lax.all_gather(m[0], AXIS)
            # destination side ("the accelerator"): keep only owned interval
            lo = lax.axis_index(AXIS) * part
            rel = all_dst.reshape(-1) - lo
            ok = all_m.reshape(-1) & (rel >= 0) & (rel < part)
            vals = all_raw.reshape(-1, F)
            sched = None
            if use_sched:
                # baseline bins AFTER assembly: the scatter's row space is
                # this owner's interval, which only exists post-all_gather
                # (a precomputed V-space schedule cannot serve it)
                sched = gas.schedule_edges(rel, ok, part)
                rel, ok, vals = _permuted(sched, rel, ok, vals)
            out = gas.gas_scatter_weighted(
                jnp.clip(rel, 0, part - 1), vals,
                jnp.ones_like(rel, jnp.float32), ok, part, op=op, impl=impl,
                schedule=sched)
            return out[None]

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=P(AXIS), check_vma=_check_vma(impl),
        )(feats, src_local, dst_global, weights, mask)

    raise ValueError(dataflow)


# ---------------------------------------------------------------------------
# sampled GraphSAGE aggregation: out[b] = reduce_k feats[nbrs[b, k]]
# ---------------------------------------------------------------------------

def _op_identity(dtype, op: gas.Op):
    """The reduction identity a no-sample row must hold, per dtype — matches
    the segment-reduce empty-segment convention (±inf on floats, the integer
    extremes on ints, 0 for add/or)."""
    if op in ("add", "or"):
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.inexact):
        return jnp.asarray(gas._INIT[op], dtype)
    info = jnp.iinfo(dtype)
    return jnp.asarray(info.min if op == "max" else info.max, dtype)


def _seed_reduce(f_shard, rel, own, op: gas.Op, impl: str,
                 scheduled: bool = False):
    """Per-request-block GAS reduction: (R, K) local ids → (R, F) partials.

    This is the in-SSD step of the sampled path — the seed index is the
    destination row, so the fan-out reduction is exactly a FAST-GAS scatter
    (``impl`` selects the backend). Rows with no owned neighbor hold the op
    identity (0 for add/or, ±inf for max/min). Also returns (R,) own counts.
    The seed stream ``repeat(arange(R), K)`` is destination-binned by
    construction, so ``scheduled`` derives the idle-skip band sort-free
    (``assume_sorted``) — no permutation is ever applied here.
    """
    R, K = rel.shape
    rows = gas.gas_gather(f_shard, rel.reshape(-1), impl=impl)   # (R·K, F)
    if K == 1:
        # a single-sample request block is a pure *find*: the seed scatter
        # would be the identity permutation, so the reduction degenerates to
        # masking the gathered row with the op identity — no kernel
        # round-trip (the gather's VJP still scatters through the kernel
        # under pallas). This is the row-lookup path of ``sage_forward``.
        if op == "or":
            # mirror the scatter path's boolean-or normalization exactly:
            # int-cast the value, clamp the or-identity at 0 (a raw
            # passthrough would leak negative/fractional values)
            red = jnp.where(own.reshape(R, 1),
                            jnp.maximum(rows.astype(jnp.int32), 0),
                            0).astype(rows.dtype)
        else:
            red = jnp.where(own.reshape(R, 1), rows,
                            _op_identity(rows.dtype, op))
        return red, own.sum(-1)
    seed = jnp.repeat(jnp.arange(R, dtype=jnp.int32), K)
    sched = (gas.schedule_edges(seed, own.reshape(-1), R, assume_sorted=True)
             if scheduled else None)
    red = gas.gas_scatter_weighted(
        seed, rows, jnp.ones((R * K,), jnp.float32), own.reshape(-1), R,
        op=op, impl=impl, schedule=sched)
    return red, own.sum(-1)


def _mask_identity_rows(out, op: gas.Op):
    """Zero the ±inf max/min identity rows (seeds with no valid sample).

    Applied at every *terminal* finalize (never on pre-combine partials —
    a shard with no sample for a seed must still contribute the identity to
    the cross-shard extremum). Keeping ±inf here would make any downstream
    use produce ``0·inf = NaN`` under autodiff — the classic silent
    train-step NaN — so identity rows now read 0 on every op, matching
    add/or, and their cotangent is cut at the ``where``.
    """
    if op in ("max", "min"):
        return jnp.where(jnp.isfinite(out), out, 0)
    return out


def _finalize(red, cnt, op: gas.Op):
    """Partial → output rows: mean for add, identity-masked passthrough
    otherwise (terminal positions only — see ``aggregate_sampled``)."""
    if op == "add":
        return red / jnp.maximum(cnt, 1).astype(red.dtype)[..., None]
    return _mask_identity_rows(red, op)


def _combine_shards(parts, cnts, op: gas.Op):
    """(n, B, F) per-source-shard partials (+ (n, B) counts) → (B, F)."""
    if op == "add":
        return parts.sum(0) / jnp.maximum(cnts.sum(0), 1).astype(parts.dtype)[..., None]
    if op in ("max", "or"):
        return _mask_identity_rows(parts.max(0), op)
    return _mask_identity_rows(parts.min(0), op)


def _pad_rows(x, mult, fill):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1), constant_values=fill)


def scan_request_chunks(body, nbrs2d, mask2d, chunk: int):
    """Stream the (R, K) request block through ``body`` in row chunks.

    The SSD command-queue analogue: requests are issued ``chunk`` rows at a
    time; padded rows are all-masked so they reduce to the op identity and
    are sliced off. Chunking partitions rows (never a row's K entries), so
    the result is bit-exact with one full-block ``body`` call. ``body`` maps
    an (chunk, K) id/mask pair to (chunk, F) output rows. Shared with the
    chunked embedding lookup (``repro.models.embedding``).
    """
    R = nbrs2d.shape[0]
    chunk = max(1, min(chunk, R))
    nb = _pad_rows(nbrs2d, chunk, 0)
    mk = _pad_rows(mask2d, chunk, False)
    steps = nb.shape[0] // chunk

    def step(_, inp):
        return None, body(*inp)

    _, outs = lax.scan(step, None,
                       (nb.reshape(steps, chunk, -1), mk.reshape(steps, chunk, -1)))
    return outs.reshape(steps * chunk, -1)[:R]


def aggregate_sampled(
    feats: jax.Array,     # (P, part, F) owner-sharded features
    nbrs: jax.Array,      # (P, B_loc, K) global neighbor ids, seed-sharded
    mask: jax.Array,      # (P, B_loc, K)
    *,
    mesh: Optional[Mesh] = None,
    dataflow: str = "cgtrans",
    op: gas.Op = "add",
    impl: str = "xla",
    request_chunk: Optional[int] = None,
    scheduled: Optional[bool] = None,   # None → on for impl="pallas"
) -> jax.Array:
    """Returns (P, B_loc, F) aggregated neighbor features per seed.

    ``op="add"`` is the masked *mean* (GraphSAGE); max/min/or reduce
    elementwise over the valid samples. Seeds with no valid sample read 0 on
    every op — the ±inf max/min identities are masked at the terminal
    finalize (``_mask_identity_rows``) so autodiff never meets ``0·inf``.
    ``impl`` selects the GAS backend for every per-shard reduction (both
    backends differentiate; under pallas the backward runs through the
    FAST-GAS kernel); ``request_chunk`` streams the seed block through the
    collectives ``request_chunk`` seeds at a time; ``scheduled`` turns the
    per-shard reductions' idle-skip occupancy into the sort-free banded form
    (seed rows are destination-binned by construction).
    """
    if dataflow not in ("cgtrans", "baseline"):
        raise ValueError(dataflow)
    Pn, part, F = feats.shape
    _, B_loc, K = nbrs.shape
    use_sched = _resolve_scheduled(scheduled, impl)

    if not is_sharded(mesh):
        table = feats.reshape(Pn * part, F)

        def body(nb_c, m_c):
            red, cnt = _seed_reduce(table, nb_c, m_c, op, impl, use_sched)
            return _finalize(red, cnt, op)

        flat_nb = nbrs.reshape(Pn * B_loc, K)
        flat_m = mask.reshape(Pn * B_loc, K)
        if request_chunk is None:
            out = body(flat_nb, flat_m)
        else:
            out = scan_request_chunks(body, flat_nb, flat_m, request_chunk)
        return out.reshape(Pn, B_loc, F)

    n = mesh.shape[AXIS]

    def shard_fn(f, nb, m):
        f, nb, m = f[0], nb[0], m[0]
        lo = lax.axis_index(AXIS) * part

        def body(nb_c, m_c):
            # request broadcast (ids only — tiny; "addresses into the SSD")
            C = nb_c.shape[0]
            ids = lax.all_gather(nb_c, AXIS)                 # (n, C, K)
            msk = lax.all_gather(m_c, AXIS)
            rel = ids - lo
            own = msk & (rel >= 0) & (rel < part)
            relc = jnp.clip(rel, 0, part - 1)

            if dataflow == "cgtrans":
                # in-SSD aggregation: GAS-reduce per seed, ship (n·C, F)
                red, cnt = _seed_reduce(
                    f, relc.reshape(n * C, K), own.reshape(n * C, K), op,
                    impl, use_sched)
                parts = lax.all_to_all(red.reshape(n, C, F), AXIS,
                                       split_axis=0, concat_axis=0, tiled=False)
                if op == "add":
                    cnts = lax.all_to_all(
                        cnt.reshape(n, C)[..., None].astype(f.dtype), AXIS,
                        split_axis=0, concat_axis=0, tiled=False)[..., 0]
                else:
                    cnts = None
                return _combine_shards(parts, cnts, op)

            # baseline: ship raw (n·C·K, F) neighbor rows to the seed owners,
            # reduce there ("the accelerator") — also through the GAS engine.
            rows = gas.gas_gather(f, relc.reshape(-1), impl=impl
                                  ).reshape(n, C, K, F)
            rows = jnp.where(own[..., None], rows, 0)
            raw = lax.all_to_all(rows, AXIS, split_axis=0, concat_axis=0,
                                 tiled=False)                 # (n, C, K, F)
            okk = lax.all_to_all(own[..., None], AXIS, split_axis=0,
                                 concat_axis=0, tiled=False)[..., 0]
            flat = raw.transpose(1, 0, 2, 3).reshape(C * n * K, F)
            okf = okk.transpose(1, 0, 2).reshape(C * n * K)
            seed = jnp.repeat(jnp.arange(C, dtype=jnp.int32), n * K)
            sched = (gas.schedule_edges(seed, okf, C, assume_sorted=True)
                     if use_sched else None)
            red = gas.gas_scatter_weighted(
                seed, flat, jnp.ones((C * n * K,), jnp.float32), okf, C,
                op=op, impl=impl, schedule=sched)
            return _finalize(red, okf.reshape(C, n * K).sum(-1), op)

        if request_chunk is None:
            out = body(nb, m)
        else:
            out = scan_request_chunks(body, nb, m, request_chunk)
        return out[None]

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=P(AXIS), check_vma=_check_vma(impl),
    )(feats, nbrs, mask)
