"""The paper's primary contribution:

* ``cgtrans``    — Compressive Graph Transmission dataflows (aggregate-at-
                   owner + compressed collective vs ship-raw baseline)
* ``gas``        — the gather-and-scatter engine primitives (CAM match +
                   row-parallel update, idle-skip)
* ``gcn``        — GCN / GraphSAGE models on the CGTrans substrate
* ``algorithms`` — BFS / SSSP / CC / sort as GAS find-and-compute loops
* ``cost_model`` — the paper's Table I/II-calibrated latency+bytes+area model
                   (reproduces Figures 14–16)
"""

from repro.core import algorithms, cgtrans, cost_model, gas, gcn

__all__ = ["algorithms", "cgtrans", "cost_model", "gas", "gcn"]
