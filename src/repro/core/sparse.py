"""Compressed-sparse feature rows — per-row occupancy bitmap + packed
nonzero columns (the SGCN/LW-GCN layout for post-ReLU activations).

Real GCN activations go sparse after the first ReLU (SGCN measures 10–30%
density); moving dense R×F blocks then wastes exactly the bytes GRAPHIC's
50× claim is about. This module is the PURE codec layer (the wire.py
pattern): encode/decode transforms with no collectives and no kernel calls
of their own. The consumers live where those already are:

* ``repro.core.cgtrans`` gathers from a pre-packed table (two ``take``s —
  packed nonzeros + bitmap — instead of one dense row read: the SSD→host
  bytes scale with density) and, on the baseline dataflow, ships the raw
  row block as (packed ‖ bitmap) through ONE ``all_to_all``
  (``_sparse_all_to_all``, inside the collective-site allowlist);
* ``repro.kernels.gas_scatter`` consumes the same idea one level down:
  per-feature-block liveness rides the scalar-prefetch work list so the
  banded walk skips all-zero feature blocks like idle tiles.

The layout: a row ``x`` of width F becomes

* ``bitmap`` — ``ceil(F/32)`` int32 words, bit ``j`` of word ``w`` set iff
  ``x[32w + j] != 0`` (int32 on the wire, never uint — the dtype-flow rule);
* ``packed`` — the nonzero values in column order, left-justified into a
  static ``capacity`` columns (``FEAT_BLOCK``-aligned so the MXU
  contraction consumes it without repacking).

The decode is positional (a cumsum over the bitmap), so the round-trip is
EXACT — bit-for-bit, any dtype — whenever every row's popcount fits the
capacity. That fit is a STATIC gate (``sparse_fits``, the ``delta_ids_fit``
pattern): ``table_capacity`` measures the real table's worst row once on
the host, and a capacity that doesn't beat dense (capacity + bitmap words
≥ F) falls back to the unchanged dense path — never a silently-truncating
"compressed" one. cgtrans aggregation itself stays dense: aggregated
partials have UNION support (a sum of sparse rows is dense), so the format
compresses the gather and the raw-row shipment, not the partial shipment.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

#: feature modes every ``features=`` knob accepts
FEATURE_MODES = ("dense", "sparse")

#: packed-column alignment on wide tables — mirrors the kernel's MXU tile
#: (``kernels.gas_scatter.kernel.FEAT_BLOCK``; asserted equal by the sparse
#: tier so the two can never drift apart silently)
FEAT_ALIGN = 128

#: alignment for narrow tables (F not a FEAT_BLOCK multiple): the 8-lane
#: granule the interpret-mode kernel pads to
NARROW_ALIGN = 8

_WORD = 32  # bits per bitmap word


def validate_features(features: str) -> str:
    """The one place a ``features=`` string is checked (every entrypoint
    funnels through it, so a typo fails loudly at trace time)."""
    if features not in FEATURE_MODES:
        raise ValueError(
            f"unknown features mode {features!r} (have {FEATURE_MODES})")
    return features


def bitmap_words(n_features: int) -> int:
    """int32 words per row of the occupancy bitmap."""
    return -(-int(n_features) // _WORD)


def _align(n_features: int) -> int:
    return FEAT_ALIGN if n_features % FEAT_ALIGN == 0 else NARROW_ALIGN


def worst_case_capacity(n_features: int, density: float) -> int:
    """Static packed-column capacity for a target density, rounded up to
    the feature-block alignment and capped at F (density 1.0 ⇒ the gate
    falls back to dense — there is nothing to compress)."""
    a = _align(n_features)
    need = math.ceil(n_features * float(density))
    return min(int(n_features), -(-max(need, 1) // a) * a)


def table_capacity(feats) -> int:
    """The measured worst-row capacity of a concrete feature table — the
    max row popcount, alignment-rounded. Host-side, once per table (the
    ``schedule_edges`` economics): the result is a static Python int that
    bakes into the jaxpr as the packed width."""
    x = np.asarray(feats)
    F = x.shape[-1]
    nnz = int((x.reshape(-1, F) != 0).sum(axis=-1).max()) if x.size else 0
    a = _align(F)
    return min(int(F), -(-max(nnz, 1) // a) * a)


def sparse_fits(capacity: int, n_features: int) -> bool:
    """Static gate (the ``delta_ids_fit`` pattern): does the packed layout
    actually beat dense? Bytes per row are ``capacity + bitmap_words(F)``
    32-bit lanes vs ``F`` dense — equal-or-worse means the caller ships
    dense unchanged, never a silently-pointless "compression"."""
    return int(capacity) + bitmap_words(n_features) < int(n_features)


def density_stats(x) -> dict:
    """Measured density of a feature block — host floats for bench rows."""
    a = np.asarray(x)
    total = int(a.size)
    nnz = int((a != 0).sum())
    return {"nnz": nnz, "total": total,
            "density": (nnz / total) if total else 0.0}


def encode_rows(x: jnp.ndarray, capacity: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(…, F) rows → (packed (…, capacity) in x's dtype, bitmap (…, W)
    int32). Rows whose popcount exceeds ``capacity`` lose their trailing
    nonzeros (positionally) — the static ``sparse_fits``/``table_capacity``
    gate is what makes that impossible on the entrypoint paths."""
    F = x.shape[-1]
    W = bitmap_words(F)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, F)
    R = x2.shape[0]
    nz = x2 != 0
    bits = jnp.pad(nz, ((0, 0), (0, W * _WORD - F)))
    words = (bits.reshape(R, W, _WORD).astype(jnp.uint32)
             << jnp.arange(_WORD, dtype=jnp.uint32)).sum(
                 -1, dtype=jnp.uint32)
    bitmap = lax.bitcast_convert_type(words, jnp.int32)
    # left-justify the nonzeros: zeros and over-capacity spill land in a
    # scratch column that the final slice drops
    pos = jnp.cumsum(nz, axis=-1) - 1
    col = jnp.where(nz & (pos < capacity), pos, capacity)
    packed = jnp.zeros((R, capacity + 1), x.dtype).at[
        jnp.arange(R)[:, None], col].set(x2)[:, :capacity]
    return (packed.reshape(*lead, capacity), bitmap.reshape(*lead, W))


def _unpack_bits(bitmap: jnp.ndarray, n_features: int) -> jnp.ndarray:
    """(…, W) int32 bitmap → (…, F) bool occupancy."""
    words = lax.bitcast_convert_type(bitmap, jnp.uint32)
    bits = (words[..., None] >> jnp.arange(_WORD, dtype=jnp.uint32)) & 1
    return (bits.reshape(*bitmap.shape[:-1], bitmap.shape[-1] * _WORD)
            [..., :n_features]).astype(bool)


def decode_rows(packed: jnp.ndarray, bitmap: jnp.ndarray,
                n_features: int) -> jnp.ndarray:
    """Inverse of ``encode_rows``: positional unpack through a cumsum over
    the occupancy bits. Exact whenever the row's popcount fit the packed
    capacity (the static gate's guarantee)."""
    C = packed.shape[-1]
    bits = _unpack_bits(bitmap, n_features)
    pos = jnp.cumsum(bits, axis=-1) - 1
    vals = jnp.take_along_axis(packed, jnp.clip(pos, 0, C - 1), axis=-1)
    return jnp.where(bits & (pos < C), vals, jnp.zeros((), packed.dtype))


def popcount(bitmap: jnp.ndarray) -> jnp.ndarray:
    """(…, W) int32 bitmap → (…,) int32 set-bit count (≡ the packed length
    the decode consumes — the property tests pin the equivalence)."""
    words = lax.bitcast_convert_type(bitmap, jnp.uint32)
    bits = (words[..., None] >> jnp.arange(_WORD, dtype=jnp.uint32)) & 1
    return bits.sum(axis=(-1, -2)).astype(jnp.int32)
