"""The paper's latency / bytes / area model (Tables I–II, Figures 14–16).

The paper's evaluation is simulator-driven: SPICE-calibrated per-op constants
(Table I) + a trace-level dataflow simulator (networkX/PyTorch). This module
rebuilds that model. Byte counts follow the dataflows *exactly* (they are the
paper's contribution); engine/bus constants are Table I where given and
standard textbook values elsewhere (marked CALIB) — chosen once, within
realistic ranges, and then every reported ratio is *emergent*, not fitted
per-figure.

Reproduced claims (benchmarks assert tolerance bands):
  · Fig 15 — CGTrans ~50× SSD-loading reduction (weaker on Amazon: F=32 so
    index traffic is comparable — the model reproduces the caveat naturally),
    GRAPHIC 3.6× over GCNAX, 2.4× over CGTrans-on-Insider (averages).
  · Fig 16(a) — idle-skip ≈10× over typical cache on sparse frontiers.
  · Fig 16(c) — ~70% end-to-end latency cut on Reddit GCN.
  · Fig 14   — ~5× area efficiency over Insider on aggregation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import numpy as np

from repro.graph.synthetic import TABLE_II


@dataclasses.dataclass(frozen=True)
class GraphicConstants:
    # --- Table I (65 nm, 128×16 arrays) ---
    fast_area_mm2: float = 0.016
    cam_area_mm2: float = 0.013
    fast_op_ns: float = 0.025      # 16-bit add w/ writeback, per row-op (amortized)
    cam_op_ns: float = 0.182       # per parallel match
    fast_op_pj: float = 0.38
    cam_op_pj: float = 0.33
    rows_per_array: int = 128
    row_bytes: int = 32            # 16 cells × 16 bit
    # --- storage system (CALIB: textbook values) ---
    ssd_ext_bw: float = 3.2e9      # PCIe 3.0 ×4 NVMe effective B/s
    ssd_int_bw: float = 11.0e9     # aggregated internal channel bandwidth
    dram_bw: float = 25.6e9        # DDR4-3200 single rank
    dram_random_ns: float = 60.0   # random row fetch (cache-miss regime)
    # --- compute engines (CALIB) ---
    gcnax_macs: int = 1024         # GCNAX-like ASIC @ 1 GHz
    gcnax_ghz: float = 1.0
    systolic_macs: int = 16384     # 128×128 combination systolic array @ 1 GHz
    systolic_ghz: float = 1.0
    # Insider-class in-SSD FPGA: ~8 streaming 16-bit adder lanes @ ~300 MHz.
    insider_ops_per_s: float = 2.2e9
    digital_ops_per_s: float = 8.0e9   # synthesized FIFO+ALU block
    insider_area_eff: float = 0.2  # paper: GAS is 5× more area-efficient
    digital_area_eff: float = 0.4
    # --- formats ---
    feature_bytes: int = 2         # fp16 features on the bus
    id_bytes: int = 4

    def gas_arrays(self, cache_mb: float) -> int:
        return int(cache_mb * 2**20 / (self.rows_per_array * self.row_bytes))

    def agg_ops_per_s(self, engine: str, cache_mb: float) -> float:
        """Aggregation throughput (16-bit row-ops/s) of each engine.

        GAS: Table I's 0.025 ns/OP is the row-amortized figure for a 128-row
        array — i.e. one 16-bit bit-serial add completes on *all* rows every
        128·0.025 ns ≈ 16 cycles @ 5 GHz. Across all arrays of the cache:
        arrays · 128 / (128 · fast_op_ns).
        """
        if engine == "gas":
            per_array = self.rows_per_array / (self.rows_per_array * self.fast_op_ns * 1e-9)
            return self.gas_arrays(cache_mb) * per_array
        if engine == "insider":
            return self.insider_ops_per_s
        if engine == "digital":
            return self.digital_ops_per_s
        raise ValueError(engine)


C = GraphicConstants()


@dataclasses.dataclass(frozen=True)
class SageWorkload:
    """One GraphSAGE layer-1 inference batch (the paper's §4.2 setting)."""
    batch: int            # seed vertices per batch
    fanout: int           # sampled neighbors (paper: 50)
    n_features: int
    hidden: int = 256     # combination MLP width

    @property
    def sampled_rows(self) -> int:
        return self.batch * self.fanout


def load_bytes(w: SageWorkload, k: GraphicConstants, dataflow: str) -> float:
    """SSD→host bytes per batch (the paper's "SSD loading"). Requests (ids)
    travel host→SSD on the full-duplex link and are counted separately."""
    if dataflow == "baseline":
        return w.sampled_rows * w.n_features * k.feature_bytes
    if dataflow == "cgtrans":
        return w.batch * w.n_features * k.feature_bytes
    raise ValueError(dataflow)


def request_bytes(w: SageWorkload, k: GraphicConstants) -> float:
    return w.sampled_rows * k.id_bytes


def agg_ops(w: SageWorkload) -> float:
    """16-bit add row-ops for sum aggregation of the batch."""
    return w.sampled_rows * w.n_features


def comb_macs(w: SageWorkload) -> float:
    return w.batch * 2 * w.n_features * w.hidden          # concat[self‖agg] MLP


def latency(w: SageWorkload, system: str, k: GraphicConstants = C,
            cache_mb: float = 1.0) -> Dict[str, float]:
    """End-to-end per-batch latency breakdown (seconds) for one system.

    systems: gcnax | insider (CGTrans on near-SSD FPGA) | graphic (CGTrans on
    FAST-GAS). Stages pipeline where the architecture overlaps them (Fig 9):
    storage stage = max(flash streaming, in-SSD aggregation); host stage =
    max(DRAM staging, accelerator compute).
    """
    if system == "gcnax":
        t_bus = load_bytes(w, k, "baseline") / k.ssd_ext_bw
        t_dram = load_bytes(w, k, "baseline") / k.dram_bw
        t_agg = agg_ops(w) / (k.gcnax_macs * k.gcnax_ghz * 1e9)
        t_comb = comb_macs(w) / (k.gcnax_macs * k.gcnax_ghz * 1e9)
        return {"ssd_bus": t_bus, "dram": t_dram, "agg": t_agg, "comb": t_comb,
                "total": t_bus + max(t_dram, t_agg + t_comb)}

    engine = {"insider": "insider", "graphic": "gas"}[system]
    # raw features stream flash→cache inside the SSD (channel bandwidth);
    # the in-SSD engine aggregates as they stream (overlapped ⇒ max)
    t_int = (w.sampled_rows * w.n_features * k.feature_bytes) / k.ssd_int_bw
    t_agg = agg_ops(w) / k.agg_ops_per_s(engine, cache_mb)
    t_bus = load_bytes(w, k, "cgtrans") / k.ssd_ext_bw
    t_dram = load_bytes(w, k, "cgtrans") / k.dram_bw
    t_comb = comb_macs(w) / (k.systolic_macs * k.systolic_ghz * 1e9)
    return {"ssd_int": t_int, "agg": t_agg, "ssd_bus": t_bus, "dram": t_dram,
            "comb": t_comb,
            "total": max(t_int, t_agg) + t_bus + max(t_dram, t_comb)}


def fig15_table(batch: int = 4096, fanout: int = 50,
                k: GraphicConstants = C) -> List[Dict]:
    """Per Table-II dataset: loading reduction + speedups of the 3 systems."""
    rows = []
    for name, (_, _, F) in TABLE_II.items():
        w = SageWorkload(batch=batch, fanout=fanout, n_features=int(F))
        t = {s: latency(w, s, k)["total"] for s in ("gcnax", "insider", "graphic")}
        rows.append({
            "dataset": name,
            "n_features": int(F),
            "load_reduction": load_bytes(w, k, "baseline") / load_bytes(w, k, "cgtrans"),
            "load_reduction_with_requests": (
                (load_bytes(w, k, "baseline") + request_bytes(w, k))
                / (load_bytes(w, k, "cgtrans") + request_bytes(w, k))),
            "speedup_vs_gcnax": t["gcnax"] / t["graphic"],
            "speedup_vs_insider": t["insider"] / t["graphic"],
            "t_gcnax_ms": t["gcnax"] * 1e3,
            "t_insider_ms": t["insider"] * 1e3,
            "t_graphic_ms": t["graphic"] * 1e3,
        })
    return rows


def fig14_area(k: GraphicConstants = C, cache_mb: float = 1.0) -> Dict[str, float]:
    """Area (mm²) to sustain the same aggregation throughput (Fig 14)."""
    gas_area = k.gas_arrays(cache_mb) * (k.fast_area_mm2 + k.cam_area_mm2)
    return {
        "gas_mm2": gas_area,
        "insider_mm2": gas_area / k.insider_area_eff,
        "digital_mm2": gas_area / k.digital_area_eff,
        "area_eff_vs_insider": 1.0 / k.insider_area_eff,
        "area_eff_vs_digital": 1.0 / k.digital_area_eff,
    }


# ---------------------------------------------------------------------------
# Fig 16(a)/(b): trace-level GAS simulator for classic graph algorithms
# ---------------------------------------------------------------------------

# CALIB constants for the traversal trace model. The paper's simulator is not
# fully specified (no per-round equation is given); these two constants encode
# its *narrative* — without idle-skip the lockstep round time makes pure GAS
# comparable to a typical cache (paper: 0.4–1×); idle-skip then wins by the
# measured (trace-derived) occupancy factor (paper: 10.1× average).
T_EDGE_CACHE_NS = 8.0    # typical SSD-controller cache: serial update per edge
T_ROUND_NS = 180.0       # lockstep GAS round (CAM broadcast + slowest-array
                         # bit-serial chain; all arrays clocked regardless)


def simulate_gas_traversal(indptr: np.ndarray, levels: np.ndarray,
                           k: GraphicConstants = C, cache_mb: float = 1.0,
                           feature_bits: int = 16) -> Dict[str, float]:
    """Trace-driven model of a frontier traversal (BFS/SSSP/CC-like).

    ``levels[v]`` = iteration at which v is settled (-1 if unreached). Per
    iteration, every frontier vertex is one CAM query round; arrays with no
    match for the query burn the round unless idle-skip is on (paper Fig
    11(c)), in which case the input-buffer check (one CAM op) skips it. The
    match probability per round is computed from the *actual* per-iteration
    frontier edge counts of the trace.
    """
    n = len(indptr) - 1
    deg = np.diff(indptr)
    n_arrays = max(k.gas_arrays(cache_mb), 1)
    edges = int(deg.sum())
    reached = levels >= 0
    queries = int(reached.sum())
    matched_edges = int(deg[reached].sum())

    # per-iteration occupancy: a query matches a given array w.p. 1-exp(-d/A)
    max_lev = int(levels.max()) if queries else 0
    t_skip_rounds = 0.0
    for lev in range(max_lev + 1):
        front = reached & (levels == lev)
        q_i = int(front.sum())
        if not q_i:
            continue
        lam = deg[front].mean() / n_arrays
        p_i = 1.0 - math.exp(-lam)
        t_skip_rounds += q_i * max(p_i, 1.0 / n_arrays)
    p_match = t_skip_rounds / max(queries, 1)

    # graphs larger than the cache are processed in cache-sized partitions
    graph_bytes = edges * (2 * k.id_bytes + feature_bits // 8)
    passes = max(1.0, graph_bytes / (cache_mb * 2**20))

    t_cache = matched_edges * T_EDGE_CACHE_NS * 1e-9
    t_no_skip = queries * T_ROUND_NS * passes * 1e-9
    t_skip = (queries * k.cam_op_ns + t_skip_rounds * T_ROUND_NS) * passes * 1e-9
    return {
        "t_cache_s": t_cache,
        "t_gas_s": t_no_skip,
        "t_gas_idle_skip_s": t_skip,
        "speedup_no_skip": t_cache / t_no_skip,
        "speedup_idle_skip": t_cache / t_skip,
        "passes": passes,
        "p_match": p_match,
        "queries": queries,
        "matched_edges": matched_edges,
    }


def fig16c_breakdown(k: GraphicConstants = C) -> Dict[str, Dict[str, float]]:
    """End-to-end GCN (aggregation+combination) on Reddit (Fig 16(c))."""
    _, _, F = TABLE_II["Reddit"]
    w = SageWorkload(batch=4096, fanout=50, n_features=int(F))
    return {s: latency(w, s, k) for s in ("gcnax", "insider", "graphic")}
