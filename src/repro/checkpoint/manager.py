"""Checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/  arrays.npz (flattened leaves) + manifest.json
(treedef paths, shapes, dtypes, step). Commit protocol: write into
``.tmp_step_<N>``, fsync, atomic rename — a crash mid-save never corrupts the
latest checkpoint. Retention keeps the newest K.

Elastic restore: leaves are stored UNSHARDED (gathered); on restore they are
``jax.device_put`` with NamedShardings resolved against the *current* mesh —
a checkpoint written on (16,16) restores onto (2,16,16), (4,), or 1 device
unchanged (logical specs are mesh-agnostic; see repro.common.logical).

Async: ``save_async`` snapshots to host memory synchronously (cheap) and
writes in a daemon thread; ``wait()`` joins before the next save or exit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # --- write ---------------------------------------------------------

    def save(self, state, step: int) -> str:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        return self._write(host_state, step)

    def save_async(self, state, step: int) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = threading.Thread(
            target=self._write, args=(host_state, step), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int) -> str:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten_with_paths(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in leaves})
        manifest = {
            "step": step,
            "leaves": [{"key": k, "shape": list(np.shape(v)),
                        "dtype": str(np.asarray(v).dtype)} for k, v in leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._retain()
        return final

    def _retain(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --- read ----------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.isdir(os.path.join(self.dir, name)):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None, *,
                mesh=None, spec_tree=None):
        """Restore into the structure of ``template`` (pytree of arrays or
        ShapeDtypeStructs). With mesh+spec_tree (logical specs), leaves are
        placed sharded — onto whatever mesh is current (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        keys = [k for k, _ in _flatten_with_paths(template)]
        leaves = [arrays[k] for k in keys]

        if mesh is not None and spec_tree is not None:
            from repro.common.logical import to_physical
            from jax.sharding import NamedSharding
            spec_leaves = jax.tree.leaves(
                spec_tree, is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
            placed = [
                jax.device_put(l, NamedSharding(mesh, to_physical(s, mesh)))
                for l, s in zip(leaves, spec_leaves)
            ]
        else:
            placed = [jax.numpy.asarray(l) for l in leaves]
        treedef = jax.tree.structure(template)
        return jax.tree.unflatten(treedef, placed), step
