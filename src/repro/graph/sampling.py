"""GraphSAGE fixed-fan-out neighbor sampling (Hamilton et al., the paper's
deployed algorithm, fan-out 50 per §4.2).

Sampling with replacement from each vertex's neighbor list yields perfectly
regular (batch, fanout) shapes — the paper leans on exactly this property for
load balance, and it is also what makes the device-side aggregation a
fixed-shape segment reduction.

Both a host (numpy, data-pipeline) and a device (jax, on-accelerator) sampler
are provided; they draw from the same CSR view and share one semantic
contract:

* every returned sample is VALID (mask all-True): an isolated vertex
  aggregates ITSELF — its row repeats across the fan-out, so a masked mean
  returns its own features rather than the reduction identity (0), which is
  what a lookup-style serving query expects;
* a sampled offset never escapes its vertex's CSR range: the device sampler
  clamps ``int(u · deg)`` at ``deg - 1`` (``_fanout_offsets``), so even a
  uniform draw that rounds to 1.0 can't select the first neighbor of the
  NEXT vertex's range.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import COOGraph


def host_sample(g: COOGraph, seeds: np.ndarray, fanout: int,
                *, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (neighbors (B, fanout) int32, mask (B, fanout) bool)."""
    indptr, indices, _ = g.to_csr()
    return host_sample_csr(indptr, indices, seeds, fanout, seed=seed)


def host_sample_csr(indptr: np.ndarray, indices: np.ndarray,
                    seeds: np.ndarray, fanout: int,
                    *, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """``host_sample`` on a raw CSR view (the serving engine samples at
    request-submit time from the CSR it already holds, without a COOGraph
    round-trip)."""
    rng = np.random.default_rng(seed)
    B = seeds.shape[0]
    out = np.zeros((B, fanout), np.int32)
    mask = np.ones((B, fanout), bool)
    for i, s in enumerate(seeds):
        lo, hi = int(indptr[s]), int(indptr[s + 1])
        deg = hi - lo
        if deg == 0:
            out[i] = s  # isolated vertex aggregates itself — and its
            continue    # self-samples are VALID (mask True), not identity
        out[i] = indices[lo + rng.integers(0, deg, fanout)]
    return out, mask


def _fanout_offsets(u: jax.Array, deg: jax.Array) -> jax.Array:
    """(B, fanout) uniform draws × (B,) degrees → in-range neighbor offsets.

    ``int(u · deg)`` lands in ``[0, deg]``: a float32 ``u`` close enough to
    1.0 (or any upstream rounding that nudges ``u · deg`` up to ``deg``)
    yields ``offs == deg`` — the first slot of the NEXT vertex's CSR range.
    The clamp pins the edge case to the last real neighbor; degree-0 rows
    produce offset 0 (the caller substitutes the seed itself).
    """
    deg1 = jnp.maximum(deg, 1).astype(jnp.int32)[:, None]
    offs = (u * deg1).astype(jnp.int32)
    return jnp.minimum(offs, deg1 - 1)


def device_sample(indptr: jax.Array, indices: jax.Array, seeds: jax.Array,
                  fanout: int, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """On-device fixed-fan-out sampling from a CSR graph.

    Matches ``host_sample``'s semantics exactly: with-replacement draws are
    always valid (mask all-True) and an isolated vertex self-aggregates —
    its own id fills the fan-out. Offsets are range-clamped
    (``_fanout_offsets``), so no draw can read past a vertex's CSR slice.
    """
    lo = jnp.take(indptr, seeds)
    hi = jnp.take(indptr, seeds + 1)
    deg = (hi - lo).astype(jnp.int32)
    u = jax.random.uniform(key, (seeds.shape[0], fanout))
    offs = _fanout_offsets(u, deg)
    idx = jnp.clip(lo[:, None] + offs, 0, indices.shape[0] - 1)
    nbrs = jnp.take(indices, idx)
    has_nbrs = jnp.broadcast_to(deg[:, None] > 0, nbrs.shape)
    nbrs = jnp.where(has_nbrs, nbrs, seeds[:, None])
    mask = jnp.ones_like(has_nbrs)
    return nbrs.astype(jnp.int32), mask
