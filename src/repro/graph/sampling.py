"""GraphSAGE fixed-fan-out neighbor sampling (Hamilton et al., the paper's
deployed algorithm, fan-out 50 per §4.2).

Sampling with replacement from each vertex's neighbor list yields perfectly
regular (batch, fanout) shapes — the paper leans on exactly this property for
load balance, and it is also what makes the device-side aggregation a
fixed-shape segment reduction.

Both a host (numpy, data-pipeline) and a device (jax, on-accelerator) sampler
are provided; they draw from the same CSR view.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import COOGraph


def host_sample(g: COOGraph, seeds: np.ndarray, fanout: int,
                *, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (neighbors (B, fanout) int32, mask (B, fanout) bool)."""
    rng = np.random.default_rng(seed)
    indptr, indices, _ = g.to_csr()
    B = seeds.shape[0]
    out = np.zeros((B, fanout), np.int32)
    mask = np.zeros((B, fanout), bool)
    for i, s in enumerate(seeds):
        lo, hi = int(indptr[s]), int(indptr[s + 1])
        deg = hi - lo
        if deg == 0:
            out[i] = s  # isolated vertex aggregates itself
            continue
        out[i] = indices[lo + rng.integers(0, deg, fanout)]
        mask[i] = True
    return out, mask


def device_sample(indptr: jax.Array, indices: jax.Array, seeds: jax.Array,
                  fanout: int, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """On-device fixed-fan-out sampling from a CSR graph."""
    lo = jnp.take(indptr, seeds)
    hi = jnp.take(indptr, seeds + 1)
    deg = (hi - lo).astype(jnp.int32)
    u = jax.random.uniform(key, (seeds.shape[0], fanout))
    offs = (u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    idx = jnp.clip(lo[:, None] + offs, 0, indices.shape[0] - 1)
    nbrs = jnp.take(indices, idx)
    mask = jnp.broadcast_to(deg[:, None] > 0, nbrs.shape)
    nbrs = jnp.where(mask, nbrs, seeds[:, None])
    return nbrs.astype(jnp.int32), mask
