"""Vertex-interval graph partitioning (paper §4.3, "vertex-orientated").

Vertices are split into P contiguous intervals; each partition *owns* the
features of its interval and every edge whose **source** lies in it. That is
the in-SSD invariant of DESIGN §2: the gather side of gather-and-scatter is
always local to the shard — only aggregated destination features ever cross
the interconnect (CGTrans).

Edges per partition are padded to the max count so the device-side arrays are
regular (stackable into one (P, E_max) batch for ``repro.compat.shard_map``,
the version-portable entry point every sharded dataflow goes through).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graph.structure import COOGraph


@dataclasses.dataclass
class PartitionedGraph:
    n_vertices: int
    n_parts: int
    part_size: int               # vertices per interval (padded)
    src: np.ndarray              # (P, E_max) int32, LOCAL src ids (src - lo)
    dst: np.ndarray              # (P, E_max) int32, GLOBAL dst ids
    weights: np.ndarray          # (P, E_max) float32
    mask: np.ndarray             # (P, E_max) bool — padding mask
    features: Optional[np.ndarray] = None  # (P, part_size, F) owner shards

    @property
    def e_max(self) -> int:
        return int(self.src.shape[1])


def partition_by_src(g: COOGraph, n_parts: int, *, pad_multiple: int = 8) -> PartitionedGraph:
    V = g.n_vertices
    part = -(-V // n_parts)                      # ceil
    part = -(-part // pad_multiple) * pad_multiple
    owner = g.src // part
    order = np.argsort(owner, kind="stable")
    src, dst = g.src[order], g.dst[order]
    w = g.weights[order] if g.weights is not None else np.ones_like(src, np.float32)
    counts = np.bincount(owner, minlength=n_parts)
    e_max = max(int(counts.max()), 1)
    e_max = -(-e_max // pad_multiple) * pad_multiple

    ps = np.zeros((n_parts, e_max), np.int32)
    pd = np.zeros((n_parts, e_max), np.int32)
    pw = np.zeros((n_parts, e_max), np.float32)
    pm = np.zeros((n_parts, e_max), bool)
    off = 0
    for p in range(n_parts):
        c = int(counts[p])
        ps[p, :c] = src[off:off + c] - p * part  # local ids
        pd[p, :c] = dst[off:off + c]
        pw[p, :c] = w[off:off + c]
        pm[p, :c] = True
        off += c

    feats = None
    if g.features is not None:
        F = g.features.shape[1]
        feats = np.zeros((n_parts, part, F), g.features.dtype)
        for p in range(n_parts):
            lo, hi = p * part, min((p + 1) * part, V)
            if lo < V:
                feats[p, : hi - lo] = g.features[lo:hi]

    return PartitionedGraph(V, n_parts, part, ps, pd, pw, pm, feats)
