"""Vertex-interval graph partitioning (paper §4.3, "vertex-orientated").

Vertices are split into P contiguous intervals; each partition *owns* the
features of its interval and every edge whose **source** lies in it. That is
the in-SSD invariant of DESIGN §2: the gather side of gather-and-scatter is
always local to the shard — only aggregated destination features ever cross
the interconnect (CGTrans).

Edges per partition are padded to the max count so the device-side arrays are
regular (stackable into one (P, E_max) batch for ``repro.compat.shard_map``,
the version-portable entry point every sharded dataflow goes through).

**Islandized locality (I-GCN / COIN, PAPERS.md).** The interval split above
is id-order-arbitrary: on a graph whose vertex ids are scrambled, every
destination is remote and the idle-skip occupancy is dense. ``islandize``
computes — once per graph, on the host, exactly like ``gas.schedule_edges``
— a vertex *relabeling* that packs BFS-grown, boundary-refined islands of
connected vertices into contiguous id intervals aligned with the interval
cut ``partition_by_src`` will make. Running the interval partitioner on the
relabeled graph then gives each shard a community (fewer remote all_to_all
destination rows) and gives the destination-binned edge schedule a near
block-diagonal (row-block × edge-tile) occupancy (fewer live rounds).
The relabeling is a pure permutation: consumers translate ids through
``IslandPartition.relabel`` on the way in and un-permute outputs through
``inverse`` on the way out, so islandized ≡ interval bit-exact.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Tuple

import numpy as np

from repro.graph.structure import COOGraph


@dataclasses.dataclass
class PartitionedGraph:
    n_vertices: int
    n_parts: int
    part_size: int               # vertices per interval (padded)
    src: np.ndarray              # (P, E_max) int32, LOCAL src ids (src - lo)
    dst: np.ndarray              # (P, E_max) int32, GLOBAL dst ids
    weights: np.ndarray          # (P, E_max) float32
    mask: np.ndarray             # (P, E_max) bool — padding mask
    features: Optional[np.ndarray] = None  # (P, part_size, F) owner shards

    @property
    def e_max(self) -> int:
        return int(self.src.shape[1])


def interval_size(n_vertices: int, n_parts: int, *, pad_multiple: int = 8) -> int:
    """Vertices per interval: ceil(V/P) rounded up to ``pad_multiple``.

    The single source of truth for the interval cut — ``partition_by_src``
    and ``islandize`` must agree on it, or the islandized relabeling would
    pack islands against a different boundary than the one the partitioner
    cuts at.
    """
    part = -(-n_vertices // n_parts)             # ceil
    part = -(-part // pad_multiple) * pad_multiple
    return max(part, 1)


def partition_by_src(g: COOGraph, n_parts: int, *, pad_multiple: int = 8) -> PartitionedGraph:
    V = g.n_vertices
    part = interval_size(V, n_parts, pad_multiple=pad_multiple)
    owner = g.src // part
    order = np.argsort(owner, kind="stable")
    src, dst = g.src[order], g.dst[order]
    w = g.weights[order] if g.weights is not None else np.ones_like(src, np.float32)
    counts = np.bincount(owner, minlength=n_parts)
    e_max = max(int(counts.max()), 1) if counts.size else 1
    e_max = -(-e_max // pad_multiple) * pad_multiple

    ps = np.zeros((n_parts, e_max), np.int32)
    pd = np.zeros((n_parts, e_max), np.int32)
    pw = np.zeros((n_parts, e_max), np.float32)
    pm = np.zeros((n_parts, e_max), bool)
    # one scatter by (owner, rank-within-owner) — the sorted edge stream is
    # grouped by owner, so rank = position minus the owner's start offset
    starts = np.zeros(n_parts + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    owner_sorted = owner[order]
    rank = np.arange(src.size, dtype=np.int64) - starts[owner_sorted]
    ps[owner_sorted, rank] = src - owner_sorted * part  # local ids
    pd[owner_sorted, rank] = dst
    pw[owner_sorted, rank] = w
    pm[owner_sorted, rank] = True

    feats = None
    if g.features is not None:
        F = g.features.shape[1]
        # intervals are contiguous in id order: one flat copy, then reshape
        # (n_parts·part ≥ V always, so the tail rows are the zero padding)
        flat = np.zeros((n_parts * part, F), g.features.dtype)
        flat[:V] = g.features
        feats = flat.reshape(n_parts, part, F)

    return PartitionedGraph(V, n_parts, part, ps, pd, pw, pm, feats)


# ---------------------------------------------------------------------------
# islandized locality partitioning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IslandPartition:
    """A vertex relabeling packing locality islands into shard intervals.

    ``relabel[old_id] = new_id`` and ``inverse[new_id] = old_id`` are mutual
    inverses over ``[0, V)``. The contract with ``partition_by_src`` on the
    relabeled graph: every interval boundary ``p · part_size`` is also an
    island-packing boundary, so shard ``p`` owns exactly the islands (or
    island slices) packed into ``[p·part_size, (p+1)·part_size)``.
    """

    n_vertices: int
    n_parts: int
    part_size: int
    relabel: np.ndarray          # (V,) int32: old id → new id
    inverse: np.ndarray          # (V,) int32: new id → old id
    island_of: np.ndarray        # (V,) int32: island of each OLD id (diagnostic)
    n_islands: int

    def relabel_rows(self, rows: np.ndarray) -> np.ndarray:
        """Reorder per-OLD-vertex rows into NEW id order (e.g. a feature
        table before sharding): ``out[new_id] = rows[old_id]``."""
        return rows[self.inverse]

    def unrelabel_rows(self, rows: np.ndarray) -> np.ndarray:
        """Reorder per-NEW-vertex rows back to ORIGINAL id order (e.g. a
        full-graph output): ``out[old_id] = rows[new_id]``."""
        return rows[self.relabel]


def _undirected_csr(g: COOGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetrized adjacency of ``g`` as (indptr, indices) over old ids."""
    V = g.n_vertices
    es = np.concatenate([g.src, g.dst]).astype(np.int64)
    ed = np.concatenate([g.dst, g.src]).astype(np.int64)
    deg = np.bincount(es, minlength=V)
    indptr = np.zeros(V + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    order = np.argsort(es, kind="stable")
    return indptr, ed[order]


def islandize(g: COOGraph, n_parts: int, *, pad_multiple: int = 8,
              refine_passes: int = 2) -> IslandPartition:
    """Greedy BFS island growing + label-propagation boundary refinement.

    Host-side, O(V + E), computed once per graph (like ``schedule_edges``).
    Three stages:

    1. **Grow**: BFS from high-degree seeds over the symmetrized adjacency,
       capping each island at ``part_size`` vertices (an island can never
       straddle more shards than it must). BFS discovery order is recorded —
       it becomes the intra-island id order, which keeps tightly connected
       vertices in the same destination row block for the edge scheduler.
    2. **Refine**: label-propagation passes move boundary vertices to the
       neighboring island holding most of their edges (KL-style gain, with
       the same capacity cap), shrinking the cut the grow stage left.
    3. **Pack**: islands fill P bins of ``part_size`` best-fit-decreasing;
       when no remaining island fits a bin's residual space the largest one
       is *split* at the boundary (in BFS-rank order) so every bin before
       the last non-empty one is exactly full — that is what keeps the
       packed id intervals aligned with ``partition_by_src``'s cut.
    """
    V = g.n_vertices
    part = interval_size(V, n_parts, pad_multiple=pad_multiple)
    indptr, adj = _undirected_csr(g)
    deg = np.diff(indptr)

    island = np.full(V, -1, np.int32)
    bfs_rank = np.zeros(V, np.int64)
    n_islands = 0
    t = 0
    # hubs seed first: the densest neighborhoods anchor their own islands
    for s in np.argsort(-deg, kind="stable"):
        if island[s] >= 0:
            continue
        iid = n_islands
        n_islands += 1
        island[s] = iid
        q = deque([s])
        size = 1                                 # assigned = |popped| + |queued|
        while q:
            v = q.popleft()
            bfs_rank[v] = t
            t += 1
            if size >= part:
                continue                         # drain only — island is full
            for u in adj[indptr[v]:indptr[v + 1]]:
                if island[u] < 0 and size < part:
                    island[u] = iid
                    q.append(u)
                    size += 1

    # label-propagation refinement (capacity-capped KL-style moves)
    sizes = np.bincount(island, minlength=n_islands).astype(np.int64)
    for _ in range(max(refine_passes, 0)):
        moved = 0
        for v in range(V):
            nbr = adj[indptr[v]:indptr[v + 1]]
            if nbr.size == 0:
                continue
            cur = int(island[v])
            cnt = np.bincount(island[nbr], minlength=n_islands)
            best = int(np.argmax(cnt))
            if (best != cur and cnt[best] > cnt[cur]
                    and sizes[best] < part and sizes[cur] > 1):
                island[v] = best
                sizes[best] += 1
                sizes[cur] -= 1
                moved += 1
        if not moved:
            break

    # rebuild member lists: grouped by island, BFS-discovery order within
    grouped = np.lexsort((bfs_rank, island))
    sizes = np.bincount(island, minlength=n_islands).astype(np.int64)
    pool: List[np.ndarray] = [m for m in np.split(grouped, np.cumsum(sizes)[:-1])
                              if m.size]
    pool.sort(key=lambda m: -m.size)             # best-fit-decreasing

    new_order: List[np.ndarray] = []
    for _ in range(n_parts):
        cap_left = part
        while cap_left > 0 and pool:
            pick = next((i for i, m in enumerate(pool) if m.size <= cap_left), None)
            if pick is None:
                # nothing fits: split the largest island at the bin boundary
                # (BFS-rank prefix stays; the rest re-enters the pool) — this
                # fills the bin exactly, preserving interval alignment
                m = pool.pop(0)
                new_order.append(m[:cap_left])
                rest = m[cap_left:]
                j = next((i for i, mm in enumerate(pool) if mm.size <= rest.size),
                         len(pool))
                pool.insert(j, rest)
                cap_left = 0
            else:
                m = pool.pop(pick)
                new_order.append(m)
                cap_left -= m.size
        if not pool:
            break

    inverse = (np.concatenate(new_order).astype(np.int32) if new_order
               else np.zeros(0, np.int32))
    relabel = np.empty(V, np.int32)
    relabel[inverse] = np.arange(V, dtype=np.int32)
    return IslandPartition(V, n_parts, part, relabel, inverse, island, n_islands)


def relabel_graph(g: COOGraph, isl: IslandPartition) -> COOGraph:
    """``g`` with every vertex id renamed through ``isl.relabel``.

    Edge *order* is untouched (only endpoint names change) and weights ride
    along unchanged; the feature table is re-ordered so row ``new_id`` holds
    the old vertex's features. A pure permutation — aggregation results are
    bit-identical to the original graph's up to the same renaming.
    """
    r = isl.relabel
    feats = None
    if g.features is not None:
        feats = np.ascontiguousarray(isl.relabel_rows(g.features))
    return COOGraph(g.n_vertices, r[g.src].astype(np.int32),
                    r[g.dst].astype(np.int32), g.weights, feats)


def partition_graph(g: COOGraph, n_parts: int, *, method: str = "interval",
                    pad_multiple: int = 8, refine_passes: int = 2,
                    ) -> Tuple[PartitionedGraph, Optional[IslandPartition]]:
    """Partition ``g`` for the sharded dataflows.

    ``method="interval"`` is the plain contiguous-id split (islands=None);
    ``method="island"`` islandizes first and partitions the relabeled graph —
    the returned ``PartitionedGraph`` then lives in the NEW id space, and the
    accompanying ``IslandPartition`` is the map consumers need to translate
    ids in and un-permute outputs back (``GCNConfig.partition="island"``).
    """
    if method == "interval":
        return partition_by_src(g, n_parts, pad_multiple=pad_multiple), None
    if method == "island":
        isl = islandize(g, n_parts, pad_multiple=pad_multiple,
                        refine_passes=refine_passes)
        return partition_by_src(relabel_graph(g, isl), n_parts,
                                pad_multiple=pad_multiple), isl
    raise ValueError(f"unknown partition method {method!r} "
                     "(expected 'interval' or 'island')")


def remote_destination_rows(pg: PartitionedGraph) -> np.ndarray:
    """Per-shard count of DISTINCT live destination rows owned elsewhere.

    Under CGTrans each such row is one aggregated partial the shard must ship
    through the all_to_all — the deterministic, countable stand-in for
    "cross-interconnect traffic" that the islandized relabeling shrinks.
    """
    out = np.zeros(pg.n_parts, np.int64)
    for p in range(pg.n_parts):
        d = pg.dst[p][pg.mask[p]]
        out[p] = np.unique(d[d // pg.part_size != p]).size
    return out
