"""Synthetic graph generators.

* ``rmat`` — Graph500-style Kronecker/R-MAT (A=0.57,B=0.19,C=0.19), the
  generator behind the paper's Fig 16(b) "G500 dataset at different scales".
* ``table2_like`` — graphs with the vertex/edge/feature *ratios* of the
  paper's Table II datasets, scaled down by a factor so they fit in CI. The
  full-size Table II parameters feed the analytic cost model directly.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.graph.structure import COOGraph

# Paper Table II (full-size): name -> (nodes, edges, n_features)
TABLE_II: Dict[str, tuple] = {
    "Reddit": (37.3e6, 53.9e9, 602),
    "Movielens": (22.2e6, 59.2e9, 1000),
    "Amazon": (265.9e6, 9.5e9, 32),
    "OGBN-100M": (179.1e6, 5.0e9, 32),
    "Protein-PI": (9.1e6, 8.8e9, 512),
}


def rmat(scale: int, edge_factor: int = 16, *, a=0.57, b=0.19, c=0.19,
         seed: int = 0, weights: bool = False) -> COOGraph:
    """R-MAT graph with 2^scale vertices and edge_factor·2^scale edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        go_right = (r >= a) & (r < ab)          # B quadrant: dst high bit
        go_down = (r >= ab) & (r < abc)         # C quadrant: src high bit
        go_diag = r >= abc                      # D quadrant: both
        src |= ((go_down | go_diag).astype(np.int64)) << bit
        dst |= ((go_right | go_diag).astype(np.int64)) << bit
    w = rng.random(m).astype(np.float32) + 0.05 if weights else None
    return COOGraph(n, src.astype(np.int32), dst.astype(np.int32), w)


def _cluster_bounds(n_vertices: int, n_clusters: int):
    """(starts, sizes) of contiguous clusters covering every vertex.

    ``n_vertices % n_clusters`` remainder vertices are spread one-per-cluster
    over the first clusters (sizes differ by at most 1), and the cluster
    count is capped at ``n_vertices`` so no cluster is empty.
    """
    C = max(min(n_clusters, n_vertices), 1)
    base, extra = divmod(n_vertices, C)
    sizes = np.full(C, base, np.int64)
    sizes[:extra] += 1
    starts = np.zeros(C, np.int64)
    np.cumsum(sizes[:-1], out=starts[1:])
    return starts, sizes


def clustered_graph(n_vertices: int, n_edges: int, *, n_clusters: int = 8,
                    p_intra: float = 0.9, seed: int = 0, n_features: int = 0,
                    weights: bool = False) -> COOGraph:
    """Community-structured graph: ``p_intra`` of the edges stay inside a
    contiguous vertex cluster (planted-partition style).

    This is the favorable case of the paper's idle-skip buffer (Fig 11(c)):
    once the edge stream is destination-binned (``gas.schedule_edges``) the
    (row-block × edge-tile) occupancy is near block-diagonal, so the kernel
    skips almost every off-diagonal round. Uniform graphs are its adversary
    — every tile touches every block. Benchmarks and the idle-skip counter
    tests use this generator to demonstrate skipped tiles.

    Cluster sizes differ by at most one vertex (``_cluster_bounds``): the old
    ``V // C`` + clamp-to-``V-1`` scheme left the ``V % C`` remainder vertices
    with zero edge mass, and when ``C > V`` it piled every out-of-range
    cluster's mass onto vertex ``V-1``, skewing the degree distribution the
    skip-rate bench depends on.
    """
    rng = np.random.default_rng(seed)
    starts, sizes = _cluster_bounds(n_vertices, n_clusters)
    C = len(sizes)
    c_src = rng.integers(0, C, n_edges)
    c_dst = np.where(rng.random(n_edges) < p_intra,
                     c_src, rng.integers(0, C, n_edges))
    # uniform offset within each edge's own cluster: floor(u · size) with
    # u ∈ [0, 1) is exact per variable-size cluster, where a shared
    # integers(0, cs) draw was only valid for equal-size clusters
    src = (starts[c_src]
           + (rng.random(n_edges) * sizes[c_src]).astype(np.int64)).astype(np.int32)
    dst = (starts[c_dst]
           + (rng.random(n_edges) * sizes[c_dst]).astype(np.int64)).astype(np.int32)
    w = rng.random(n_edges).astype(np.float32) + 0.05 if weights else None
    feats = (rng.standard_normal((n_vertices, n_features)).astype(np.float32)
             if n_features else None)
    return COOGraph(n_vertices, src, dst, w, feats)


def uniform_graph(n_vertices: int, n_edges: int, *, seed: int = 0,
                  n_features: int = 0, weights: bool = False) -> COOGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges, dtype=np.int32)
    dst = rng.integers(0, n_vertices, n_edges, dtype=np.int32)
    w = rng.random(n_edges).astype(np.float32) + 0.05 if weights else None
    feats = (rng.standard_normal((n_vertices, n_features)).astype(np.float32)
             if n_features else None)
    return COOGraph(n_vertices, src, dst, w, feats)


def table2_like(name: str, *, scale_down: float = 1e4, seed: int = 0,
                max_features: int = 64) -> COOGraph:
    """A small graph preserving a Table II dataset's shape ratios."""
    nodes, edges, feats = TABLE_II[name]
    n = max(int(nodes / scale_down), 64)
    m = max(int(edges / scale_down), 4 * n)
    f = min(int(feats), max_features)
    g = rmat(int(np.ceil(np.log2(n))), max(m // (1 << int(np.ceil(np.log2(n)))), 1),
             seed=seed)
    rng = np.random.default_rng(seed + 1)
    g.features = rng.standard_normal((g.n_vertices, f)).astype(np.float32)
    return g
