"""Graph containers: COO (the paper's storage format) and CSR views.

Host-side representation is numpy (the "SSD-resident" data); device-side
mini-batches are padded, fixed-shape jnp arrays (regular shapes are the
paper's own load-balancing argument for GraphSAGE sampling).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class COOGraph:
    """Edge list graph. src/dst: (E,) int32; weights optional (E,) float32."""

    n_vertices: int
    src: np.ndarray
    dst: np.ndarray
    weights: Optional[np.ndarray] = None
    features: Optional[np.ndarray] = None  # (V, F) vertex features

    def __post_init__(self):
        assert self.src.shape == self.dst.shape
        self.src = self.src.astype(np.int32)
        self.dst = self.dst.astype(np.int32)

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def degree_out(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices)

    def degree_in(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices)

    def sort_by_dst(self) -> "COOGraph":
        order = np.argsort(self.dst, kind="stable")
        return COOGraph(
            self.n_vertices, self.src[order], self.dst[order],
            None if self.weights is None else self.weights[order], self.features)

    def sort_by_src(self) -> "COOGraph":
        order = np.argsort(self.src, kind="stable")
        return COOGraph(
            self.n_vertices, self.src[order], self.dst[order],
            None if self.weights is None else self.weights[order], self.features)

    def to_csr(self) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        """Returns (indptr (V+1,), indices=dst sorted by src, weights)."""
        g = self.sort_by_src()
        indptr = np.zeros(self.n_vertices + 1, np.int64)
        np.cumsum(np.bincount(g.src, minlength=self.n_vertices), out=indptr[1:])
        return indptr, g.dst, g.weights

    def undirected(self) -> "COOGraph":
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        w = None if self.weights is None else np.concatenate([self.weights] * 2)
        return COOGraph(self.n_vertices, src, dst, w, self.features)
