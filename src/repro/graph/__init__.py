from repro.graph.partition import (IslandPartition, PartitionedGraph,
                                   interval_size, islandize, partition_by_src,
                                   partition_graph, relabel_graph,
                                   remote_destination_rows)
from repro.graph.sampling import (device_sample, host_sample,
                                  host_sample_csr)
from repro.graph.structure import COOGraph
from repro.graph.synthetic import (TABLE_II, clustered_graph, rmat,
                                  table2_like, uniform_graph)

__all__ = [
    "IslandPartition", "PartitionedGraph", "interval_size", "islandize",
    "partition_by_src", "partition_graph", "relabel_graph",
    "remote_destination_rows",
    "device_sample", "host_sample", "host_sample_csr",
    "COOGraph", "TABLE_II", "clustered_graph", "rmat", "table2_like",
    "uniform_graph",
]
