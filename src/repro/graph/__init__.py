from repro.graph.partition import PartitionedGraph, partition_by_src
from repro.graph.sampling import (device_sample, host_sample,
                                  host_sample_csr)
from repro.graph.structure import COOGraph
from repro.graph.synthetic import (TABLE_II, clustered_graph, rmat,
                                  table2_like, uniform_graph)

__all__ = [
    "PartitionedGraph", "partition_by_src", "device_sample", "host_sample",
    "host_sample_csr",
    "COOGraph", "TABLE_II", "clustered_graph", "rmat", "table2_like",
    "uniform_graph",
]
