"""AdamW + cosine schedule + global-norm clipping + int8 error-feedback
gradient compression (pure JAX, no optax dependency).

Compression (``int8_ef``): gradients are per-leaf scale-quantized to int8
before the cross-pod (DCN) reduction and the quantization residual is carried
in optimizer state and re-added next step (error feedback), so the long-run
bias vanishes. This is the standard distributed-optimization trick for
bandwidth-bound DCN all-reduces; the quantize→(reduce)→dequantize pair lives
inside the jitted step so XLA schedules it with the collective.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


def cosine_lr(step: jax.Array, tc: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = tc.min_lr_ratio + (1 - tc.min_lr_ratio) * cos
    return tc.learning_rate * warm * scale


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# --- int8 error-feedback compression ---------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, residual):
    """Returns (dequantized grads as transmitted, new residual)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = q.astype(jnp.float32) * s
        return deq.astype(g.dtype), (g32 - deq).astype(jnp.float32)

    flat = jax.tree.map(one, grads, residual)
    return (jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple)))


# --- AdamW ------------------------------------------------------------------

def adamw_init(params, tc: TrainConfig) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    state = {"m": zeros(params), "v": zeros(params),
             "count": jnp.zeros((), jnp.int32)}
    if tc.grad_compression == "int8_ef":
        state["ef_residual"] = zeros(params)
    return state


def adamw_update(params, grads, opt_state, tc: TrainConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    metrics = {}
    if tc.grad_compression == "int8_ef":
        grads, new_res = compress_grads(grads, opt_state["ef_residual"])
        metrics["ef_residual_norm"] = global_norm(new_res)

    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    metrics["grad_norm"] = gnorm

    count = opt_state["count"] + 1
    lr = cosine_lr(count, tc)
    metrics["lr"] = lr
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_ = b1 * m + (1 - b1) * g32
        v_ = b2 * v + (1 - b2) * jnp.square(g32)
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + tc.eps)
        p32 = p.astype(jnp.float32)
        p_ = p32 - lr * (step + tc.weight_decay * p32)
        return p_.astype(p.dtype), m_, v_

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    if tc.grad_compression == "int8_ef":
        new_state["ef_residual"] = new_res
    return new_params, new_state, metrics


def opt_state_schema(param_schema, tc: TrainConfig):
    """Schema mirror of adamw_init for dry-run lowering (f32 m/v [+residual])."""
    import dataclasses as dc
    from repro.common.schema import ParamDef, tree_map_defs

    f32 = lambda d: dc.replace(d, dtype=jnp.float32, init="zeros")
    s = {"m": tree_map_defs(f32, param_schema),
         "v": tree_map_defs(f32, param_schema),
         "count": ParamDef((), (), init="zeros", dtype=jnp.int32)}
    if tc.grad_compression == "int8_ef":
        s["ef_residual"] = tree_map_defs(f32, param_schema)
    return s
