from repro.optim.adamw import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    cosine_lr,
    global_norm,
    opt_state_schema,
    quantize_int8,
)

__all__ = [
    "adamw_init", "adamw_update", "clip_by_global_norm", "compress_grads",
    "cosine_lr", "global_norm", "opt_state_schema", "quantize_int8",
]
