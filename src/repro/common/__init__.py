from repro.common.config import (
    LAYER_KINDS,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TrainConfig,
    reduced,
)
from repro.common.hw import V5E, ChipSpec
from repro.common.logical import (
    batch_axes,
    dp_size,
    named_sharding,
    to_physical,
    tree_to_physical,
    tree_to_shardings,
)
from repro.common.schema import (
    ParamDef,
    count_params,
    init_params,
    param_logical_specs,
    param_structs,
    stack,
)

__all__ = [
    "LAYER_KINDS", "ModelConfig", "ShapeConfig", "SHAPES", "TrainConfig",
    "reduced", "V5E", "ChipSpec", "batch_axes", "dp_size", "named_sharding",
    "to_physical", "tree_to_physical", "tree_to_shardings", "ParamDef",
    "count_params", "init_params", "param_logical_specs", "param_structs",
    "stack",
]
