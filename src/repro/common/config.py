"""Model / shape / training configuration dataclasses.

One ``ModelConfig`` covers every assigned architecture family; per-arch files in
``repro.configs`` instantiate it with the exact assigned numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds usable in ``pattern`` (the repeating block pattern):
#   attn    full causal self-attention + dense FFN
#   local   sliding-window self-attention + dense FFN
#   cross   cross-attention to encoder/vision memory + dense FFN
#   dec     decoder layer with BOTH self- and cross-attention + FFN (whisper)
#   enc     bidirectional self-attention + FFN (whisper encoder)
#   moe     full self-attention + MoE FFN (shared + routed experts)
#   rglru   RG-LRU recurrent block + dense FFN (griffin/recurrentgemma)
#   ssd     mamba2 state-space-duality mixer (no separate FFN)
LAYER_KINDS = ("attn", "local", "cross", "dec", "enc", "moe", "rglru", "ssd")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)
    first_k_dense: int = 0           # MoE: leading dense-FFN layers
    qkv_bias: bool = False
    window: int = 0                  # local attention window size
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0   # gemma3: global layers use a larger theta
    query_pre_attn_scalar: float = 0.0  # gemma2/3 custom attention scale
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_dense: int = 0              # FFN width for first_k_dense layers
    router_aux_coef: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- RG-LRU (recurrentgemma) ---
    lru_width: int = 0
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500              # frames after the (stubbed) conv frontend
    max_dec_pos: int = 0             # learned decoder positions (0 → per-shape)
    # --- VLM (llama-3.2-vision) ---
    vision_seq: int = 0              # stub patch-embedding sequence length
    # --- misc ---
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d_model)
    norm_eps: float = 1e-6
    act: str = "silu"                # silu | gelu
    norm_type: str = "rms"           # rms | ln (whisper uses LayerNorm)
    rms_zero_centered: bool = False  # gemma: weight stored as (1 + w)
    qk_norm: bool = False            # gemma3: RMSNorm on q and k heads
    post_norms: bool = False         # gemma2/3: post-attn and post-ffn norms
    mlp_gated: bool = True           # SwiGLU/GeGLU vs plain 2-matrix MLP
    mlp_bias: bool = False           # whisper: biases everywhere
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"             # none | block
    scan_layers: bool = True
    block_repeat: int = 1            # pattern periods per scan block (remat
                                     # stores one input per block: repeat>1
                                     # trades recompute for stored activations)
    # --- CGTrans integration (the paper's technique; see DESIGN §5) ---
    cgtrans_embedding: bool = False  # owner-aggregated embedding-grad scatter
    cgtrans_moe: bool = False        # combine-at-expert compressed all-to-all

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows: vocab padded to a 32-multiple so the table
        shards evenly on any mesh (standard practice; padded logits are
        masked to -inf — see models.embedding)."""
        return -(-self.vocab // 32) * 32

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind list of length n_layers."""
        kinds = []
        for i in range(self.n_layers):
            if i < self.first_k_dense:
                kinds.append("attn")
            else:
                kinds.append(self.pattern[(i - self.first_k_dense) % len(self.pattern)])
        return tuple(kinds)

    def validate(self) -> None:
        assert self.n_layers > 0 and self.d_model > 0
        for k in self.pattern:
            assert k in LAYER_KINDS, k
        if "moe" in self.pattern:
            assert self.n_experts > 0 and self.top_k > 0
        if "ssd" in self.pattern:
            assert self.ssm_state > 0
        if "local" in self.pattern:
            assert self.window > 0
        if self.is_encoder_decoder:
            assert self.n_enc_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1            # grad-accum microbatches per step
    grad_compression: str = "none"   # none | int8_ef (error-feedback int8)
    seed: int = 0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the *pattern* (the interesting structure) and shrinks everything
    else: width, layers (≥ one full pattern period), experts, vocab.
    """
    period = len(cfg.pattern)
    small = dict(
        n_layers=max(2 * period, cfg.first_k_dense + period),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2,
        d_ff=128,
        vocab=512,
        head_dim=16,
        window=min(cfg.window, 32) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_dense=128 if cfg.d_ff_dense else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16 if cfg.ssm_state else 256,
        lru_width=64 if cfg.lru_width else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq=32 if cfg.is_encoder_decoder else cfg.enc_seq,
        vision_seq=16 if cfg.vision_seq else 0,
        query_pre_attn_scalar=16.0 if cfg.query_pre_attn_scalar else 0.0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    small.update(overrides)
    out = dataclasses.replace(cfg, **small)
    out.validate()
    return out
