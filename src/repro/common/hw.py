"""Hardware constants.

Two hardware models coexist in this repo:

* the TPU v5e fleet the JAX system targets (roofline terms, §Roofline), and
* the paper's SPICE/trace-calibrated GRAPHIC constants (Table I) plus the
  storage-system constants its latency model needs — those live in
  ``repro.core.cost_model`` next to the model that consumes them.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip roofline constants (TPU v5e, per the assignment)."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    ici_link_bw: float = 50e9        # bytes/s per link
    hbm_bytes: float = 16e9          # capacity (used for fits-check commentary)
    vmem_bytes: float = 128 * 1024 * 1024 / 8  # ~16 MiB usable VMEM


V5E = ChipSpec()

# Mesh shapes required by the assignment.
SINGLE_POD_SHAPE = (16, 16)                 # ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)               # ("pod", "data", "model")
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_AXES = ("pod", "data", "model")
