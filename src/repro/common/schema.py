"""Parameter schemas: one declaration drives init, sharding, and dry-run.

A schema is a nested dict whose leaves are :class:`ParamDef`. From it we derive
  * ``init_params``   — real arrays (tests, examples),
  * ``param_specs``   — logical-spec pytree → PartitionSpecs per mesh,
  * ``param_structs`` — ShapeDtypeStructs (dry-run lowering, zero allocation),
  * ``count_params``  — exact parameter counts for MODEL_FLOPS,
  * ``stack``         — prepend a layers dim to every leaf (scan-stacked blocks).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.logical import LogicalSpec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: LogicalSpec                 # one logical axis name (or None) per dim
    init: str = "normal"                 # normal | zeros | ones | lecun | custom
    dtype: Any = jnp.float32
    scale: Optional[float] = None        # stddev override for "normal"
    custom: Optional[str] = None         # tag interpreted by custom initializers

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


Schema = Dict[str, Any]  # nested dict of ParamDef


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, schema: Schema):
    return jax.tree.map(fn, schema, is_leaf=_is_def)


def _init_leaf(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        std = d.scale if d.scale is not None else 0.02
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)
    if d.init == "lecun":
        fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[0], 1)
        std = 1.0 / math.sqrt(fan_in)
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)
    if d.init == "custom":
        return _custom_init(d, key)
    raise ValueError(f"unknown init {d.init!r}")


def _custom_init(d: ParamDef, key) -> jax.Array:
    if d.custom == "rglru_lambda":
        # c·softplus(Λ) s.t. recurrence gate a = exp(-8·softplus(Λ)·sigmoid(r))
        # initialised so a^c in [0.9, 0.999] (Griffin appendix).
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.9**2, 0.999**2)
        lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))  # inverse softplus
        return lam.astype(d.dtype)
    if d.custom == "ssm_a_log":
        # mamba2: A in [1, 16] per head, stored as log.
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(d.dtype)
    if d.custom == "ssm_dt_bias":
        # dt bias s.t. softplus(dt_bias) in [1e-3, 1e-1].
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(d.dtype)
    raise ValueError(f"unknown custom init {d.custom!r}")


def init_params(schema: Schema, key) -> Dict[str, Any]:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def param_logical_specs(schema: Schema):
    """Pytree of logical spec tuples (consumed by logical.tree_to_physical)."""
    return tree_map_defs(lambda d: tuple(d.logical), schema)


def param_structs(schema: Schema):
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), schema)


def count_params(schema: Schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=_is_def)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def stack(schema: Schema, n: int) -> Schema:
    """Prepend a scan (layers) dim of size ``n`` to every leaf."""

    def _stack(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d, shape=(n, *d.shape), logical=("layers", *d.logical)
        )

    return tree_map_defs(_stack, schema)
