"""Logical→physical axis mapping (MaxText-style logical axis rules).

Schemas annotate parameters/activations with *logical* axis names; a rule
table maps each logical name to a tuple of physical mesh axes. Resolution
drops physical axes that are absent from the current mesh, which makes the
same schema valid on a 1-device test mesh, the (16,16) single pod and the
(2,16,16) multi-pod — and is what makes elastic restore trivial.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxis = Optional[Union[str, Tuple[str, ...]]]
LogicalSpec = Tuple[LogicalAxis, ...]

# Default rule table. Each logical axis maps to an ordered tuple of physical
# axes; at resolution time we keep only the ones present in the mesh.
DEFAULT_RULES: dict = {
    # activation axes
    "batch": ("pod", "data"),          # DP over pod (DCN) and data (ICI)
    "seq": (),                         # sequence replicated by default
    "seq_shard": ("data",),            # SP: long-context sequence over data
    "seq_kv": ("model",),              # decode KV-cache seq dim (flash-decode
                                       # style: scores local, softmax psums tiny)
    "act_heads": ("model",),           # activation head dim over TP
    "act_ff": ("model",),
    # parameter axes. Weights are 2D-sharded: the contraction/"embed" dim over
    # "data" (ZeRO-3/FSDP — params + optimizer state divide by the FULL fleet,
    # GSPMD inserts the per-layer weight all-gather / grad reduce-scatter) and
    # the output dim over "model" (TP). 90B × 12 B of f32+Adam state = 4.1 GB
    # per chip on 256 chips instead of 66 GB with TP-only sharding.
    "embed": ("data",),                # FSDP axis of every weight matrix
    "vocab": ("model",),               # big embedding tables over TP (CGTrans)
    "heads": ("model",),               # attention heads over TP
    "kv_heads": ("model",),            # GQA kv heads over TP
    "ff": ("model",),                  # MLP hidden over TP
    "experts": ("model",),             # EP: experts over TP axis
    "lru": ("model",),                 # RG-LRU width over TP
    "ssm_heads": ("model",),           # mamba2 heads over TP
    "layers": (),                      # stacked-scan layer dim never sharded
    # graph engine axes
    "graph_part": ("data",),           # vertex/edge partitions = storage tier
    "feature": ("model",),             # vertex feature dim over TP
}


def resolve_axis(axis: LogicalAxis, mesh_axes: Iterable[str], rules=None):
    """Resolve one logical axis to physical mesh axes present in ``mesh_axes``."""
    rules = rules or DEFAULT_RULES
    if axis is None:
        return None
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    phys: list = []
    for name in names:
        for p in rules.get(name, ()):  # unknown logical name → replicated
            if p in mesh_axes and p not in phys:
                phys.append(p)
    if not phys:
        return None
    return phys[0] if len(phys) == 1 else tuple(phys)


def to_physical(spec: LogicalSpec, mesh: Mesh, rules=None) -> P:
    """Map a logical spec tuple to a PartitionSpec for ``mesh``.

    Guards against double-use of a physical axis (illegal in GSPMD): the
    first logical dim to claim a physical axis wins, later dims drop it.
    """
    mesh_axes = set(mesh.axis_names)
    used: set = set()
    out = []
    for axis in spec:
        phys = resolve_axis(axis, mesh_axes, rules)
        if phys is None:
            out.append(None)
            continue
        cand = (phys,) if isinstance(phys, str) else tuple(phys)
        cand = tuple(a for a in cand if a not in used)
        used.update(cand)
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            out.append(cand[0])
        else:
            out.append(cand)
    return P(*out)


def named_sharding(spec: LogicalSpec, mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, to_physical(spec, mesh, rules))


def tree_to_physical(spec_tree, mesh: Mesh, rules=None):
    """Map a pytree of logical specs to PartitionSpecs."""
    return jax.tree.map(
        lambda s: to_physical(s, mesh, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict),
    )


def tree_to_shardings(spec_tree, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, to_physical(s, mesh, rules)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict),
    )


def batch_axes(mesh: Mesh) -> tuple:
    """Physical axes implementing data parallelism on this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    size = 1
    for a in batch_axes(mesh):
        size *= mesh.shape[a]
    return size
