"""Hot-vertex embedding cache: an LRU of feature rows keyed by vertex id.

Degree-skewed graphs concentrate queries on a small set of hot vertices
(I-GCN's islandization argument), so a small LRU of previously-fetched
feature rows removes a large fraction of the SSD self-row finds. The cache
holds EXACT rows (bit copies of what the SSD find returned — features are
static at serve time), so a cache hit is indistinguishable from a fetch:
the serving tier asserts hit rows ≡ SSD-find rows bit-exactly.

Only the K=1 self-row lookups consult the cache; fan-out aggregation
segments always dispatch (their result is a *reduction*, not a row, so a
row cache cannot serve them).

Counters are the claim surface: ``hits``/``misses``/``hit_rate`` feed the
bench's hot-cache row.
"""

from __future__ import annotations

import collections
from typing import Dict, Tuple

import numpy as np


class HotVertexCache:
    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._rows: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._rows)

    def __contains__(self, vid: int) -> bool:
        return int(vid) in self._rows

    def lookup(self, ids: np.ndarray, n_features: int,
               dtype=np.float32) -> Tuple[np.ndarray, np.ndarray]:
        """(B,) ids → ((B, F) rows, (B,) hit mask). Miss rows are zero and
        hit rows are refreshed to most-recently-used; counters tick one per
        id (repeated ids in one batch each count — they each would have
        been an SSD find). ``dtype`` is the serving table's feature dtype —
        the result block the engine substitutes hit rows into — so hits
        stay bit copies on non-f32 tables (bf16 serving) instead of being
        silently promoted."""
        ids = np.asarray(ids).reshape(-1)
        rows = np.zeros((ids.shape[0], n_features), dtype)
        hit = np.zeros(ids.shape[0], bool)
        for i, vid in enumerate(ids):
            row = self._rows.get(int(vid))
            if row is None:
                self.misses += 1
                continue
            self._rows.move_to_end(int(vid))
            rows[i] = row
            hit[i] = True
            self.hits += 1
        return rows, hit

    def fill(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Insert fetched (id, row) pairs; least-recently-used rows evict
        once capacity is exceeded. Rows are stored in THEIR OWN dtype —
        bit copies of what the find returned is the whole exactness claim
        (an f32 coercion here used to break it for bf16 tables)."""
        ids = np.asarray(ids).reshape(-1)
        for vid, row in zip(ids, np.asarray(rows)):
            key = int(vid)
            if key in self._rows:
                self._rows.move_to_end(key)
            self._rows[key] = np.array(row, copy=True)
            if len(self._rows) > self.capacity:
                self._rows.popitem(last=False)
                self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"capacity": self.capacity, "resident": len(self._rows),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}
