"""The online serving engine: cross-request coalesced SSD command blocks.

Every prior entrypoint optimizes ONE training/inference step; production
GraphSAGE is thousands of concurrent single-query callers. This engine is
the paper's SSD command queue promoted to the serving front door: a
``RequestQueue`` accumulates seed sets from independent callers
(size-or-deadline trigger), and one drain fuses EVERY pending request into
ONE ``cgtrans.aggregate_multi`` command block — each request contributes a
K=1 self-row lookup segment and a fan-out aggregation segment, all tagged
with the caller's tenant id through the extended ``SegmentDescriptor``, so
the single response block scatters back to exactly the caller that issued
each segment.

The countable claims (deterministic — counted, never clocked):

* **finds-per-query**: a fused drain of N requests issues ONE
  ``gas_gather`` (``_multi_find``'s combined table gather) where the naive
  one-query-one-dispatch baseline (``fuse=False``) issues N — counted by
  ``gas.count_dispatches`` around every dispatch and accumulated into
  ``stats``;
* **collectives-per-query**: on a sharded mesh the fused block traces ONE
  ``all_gather`` + ONE ``all_to_all`` regardless of N (the
  ``serving_fetch/*`` contracts in ``analysis.contracts`` pin it at lint
  time; ``fetch_callable`` exposes the exact traced function for
  ``launch.jaxpr_stats``);
* **bit-exactness**: fused results ≡ sequential per-request results, bit
  for bit — neighbor samples are drawn at submit time and travel with the
  request, per-request segments are padded identically in both modes, and
  row reductions never mix rows across segments.

The hot-vertex cache (``HotVertexCache``) intercepts K=1 self-row lookups:
hits are masked OUT of the command block (their ids ride the ``-1``
dead-id encoding, so the SSD never sees them) and their rows come from the
cache — bit-exact, because the cache stores exactly what a previous find
returned and serve-time features are static. Misses fill the cache from
the fetched rows.

Health surface: a ``runtime.health.StepMonitor`` records every dispatch
(straggler z-scores over the robust MAD, with the median-fraction sigma
floor) and an optional ``Heartbeat`` beats once per dispatch;
``health_snapshot()`` is the controller's one-call view.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import cgtrans, gas
from repro.core import sparse as sparsefmt
from repro.graph.partition import islandize
from repro.graph.sampling import host_sample_csr
from repro.graph.structure import COOGraph
from repro.runtime.health import Heartbeat, StepMonitor
from repro.serving.cache import HotVertexCache
from repro.serving.queue import RequestQueue, ServeRequest


@dataclasses.dataclass
class ServeResult:
    """One caller's answer: its seeds' own rows + aggregated neighborhoods."""
    rid: int
    tenant: int
    self_rows: np.ndarray     # (B, F) the seeds' own feature rows
    agg_rows: np.ndarray      # (B, F) fan-out aggregation per seed
    from_cache: np.ndarray    # (B,) bool — self_row served by the hot cache


class ServingEngine:
    """Batches concurrent GraphSAGE queries into fused SSD command blocks.

    ``feats`` is the (V, F) serve-time feature table and ``indptr`` /
    ``indices`` its CSR adjacency; ``mesh`` shards the table along the
    ``data`` axis exactly like the training dataflows (``V`` must divide by
    the axis size). ``fuse=False`` degrades to the one-query-one-dispatch
    baseline — same results, N× the finds and collectives; it exists so the
    serving tier and the bench can assert the ratio, not for production
    use.

    ``partition="island"`` islandizes the table layout at build time
    (``repro.graph.partition.islandize`` over the CSR adjacency): seed and
    neighbor ids are translated through the relabel map as they enter the
    command block and results return positionally (already in caller id
    order), so ``HotVertexCache`` keys, tenant results, and the entire
    caller API stay in original vertex ids — bit-exact with
    ``partition="interval"``, asserted by the `part` tier with the cache on.
    """

    SHARED = -1   # tenant tag reserved for engine-owned (non-caller) segments

    def __init__(
        self,
        feats: np.ndarray,
        indptr: np.ndarray,
        indices: np.ndarray,
        *,
        fanout: int = 10,
        op: gas.Op = "add",
        dataflow: str = "cgtrans",
        impl: str = "xla",
        mesh: Optional[Mesh] = None,
        max_batch: int = 8,
        max_delay_s: float = 0.005,
        cache_capacity: int = 0,
        fuse: bool = True,
        scheduled: Optional[bool] = None,
        monitor: Optional[StepMonitor] = None,
        heartbeat: Optional[Heartbeat] = None,
        clock: Callable[[], float] = time.monotonic,
        sample_seed: int = 0,
        wire: str = "f32",
        features: str = "dense",
        partition: str = "interval",
    ):
        # serve whatever float dtype the table arrives in (bf16 tables are
        # the embed_lookup transport norm); only non-float tables coerce to
        # f32 — the ±inf identity/isfinite machinery of the max/min ops
        # needs a float domain. Hardcoding f32 here used to silently break
        # the cache's bit-exactness claim for bf16 features.
        feats = np.asarray(feats)
        if (not jnp.issubdtype(feats.dtype, jnp.floating)
                or feats.dtype.itemsize > 4):
            feats = feats.astype(np.float32)    # ints and f64 → f32, as ever
        if feats.ndim != 2:
            raise ValueError(f"feats must be (V, F), got {feats.shape}")
        self.n_vertices, self.n_features = feats.shape
        self.feat_dtype = feats.dtype
        self.mesh = mesh
        self.n_shards = (mesh.shape[cgtrans.AXIS]
                         if cgtrans.is_sharded(mesh) else 1)
        if self.n_vertices % self.n_shards:
            raise ValueError(
                f"V={self.n_vertices} must divide the data axis "
                f"({self.n_shards}-way) — pad the table at load time")
        self.indptr = np.asarray(indptr, np.int64)
        self.indices = np.asarray(indices, np.int64)
        if partition not in ("interval", "island"):
            raise ValueError(f"unknown partition {partition!r} "
                             "(expected 'interval' or 'island')")
        self.partition = partition
        self.islands = None
        self._relabel: Optional[np.ndarray] = None
        if partition == "island":
            # islandize the table layout ONCE at engine build (host-side,
            # like the edge schedule): shard p then owns a community, so
            # fused command blocks from locality-coherent callers touch
            # fewer remote shards. The CSR stays in ORIGINAL id space —
            # sampling, the hot cache, and every caller-visible id are
            # untouched; only the table rows and the ids entering the
            # command block live in the islandized space
            # (``_request_segments`` translates at enqueue, and results
            # scatter back positionally, i.e. already un-relabeled).
            src = np.repeat(np.arange(self.n_vertices, dtype=np.int32),
                            np.diff(self.indptr))
            isl = islandize(
                COOGraph(self.n_vertices, src, self.indices.astype(np.int32)),
                self.n_shards, pad_multiple=1)
            self.islands = isl
            self._relabel = isl.relabel
            feats = isl.relabel_rows(feats)
        self.feats = jnp.asarray(feats).reshape(
            self.n_shards, self.n_vertices // self.n_shards, self.n_features)
        self.fanout = int(fanout)
        self.op = op
        self.dataflow = dataflow
        self.impl = impl
        self.scheduled = scheduled
        self.wire = cgtrans._check_wire(wire, dataflow, features)
        self.features = sparsefmt.validate_features(features)
        # measured once per table at engine build (the edge-schedule
        # economics), AFTER any islandization reshuffle — a relabel can't
        # change the worst row, but measuring the concrete table keeps the
        # invariant local
        self.sparse_capacity = (
            sparsefmt.table_capacity(np.asarray(self.feats))
            if features == "sparse" else None)
        self.fuse = fuse
        self.sample_seed = int(sample_seed)
        self.clock = clock
        self.queue = RequestQueue(max_batch=max_batch,
                                  max_delay_s=max_delay_s, clock=clock)
        self.cache = (HotVertexCache(cache_capacity)
                      if cache_capacity else None)
        self.monitor = monitor or StepMonitor()
        self.heartbeat = heartbeat
        self.stats: Dict[str, int] = {
            "queries": 0, "dispatches": 0, "command_blocks": 0,
            "find": 0, "reduce": 0, "kernel_scatter": 0,
        }
        self._next_rid = 0
        self._results: Dict[int, ServeResult] = {}

    # -- caller side --------------------------------------------------------

    def submit(self, seeds: Sequence[int],
               tenant: Optional[int] = None) -> int:
        """Enqueue one caller's seed set; returns the request id. The
        neighbor sample is drawn NOW (rng keyed by request id) so fused and
        sequential dispatch aggregate the identical block."""
        seeds = np.asarray(seeds, np.int32).reshape(-1)
        if seeds.size == 0:
            raise ValueError("a request needs at least one seed")
        if seeds.min() < 0 or seeds.max() >= self.n_vertices:
            raise ValueError(
                f"seed out of range [0, {self.n_vertices}): {seeds}")
        rid = self._next_rid
        self._next_rid += 1
        nbrs, mask = host_sample_csr(self.indptr, self.indices, seeds,
                                     self.fanout,
                                     seed=self.sample_seed + rid)
        self.queue.push(ServeRequest(
            rid=rid, tenant=rid if tenant is None else int(tenant),
            seeds=seeds, nbrs=nbrs, mask=mask,
            enqueued_at=self.clock()))
        return rid

    def poll(self) -> int:
        """Dispatch one batch if the queue's trigger fired; returns the
        number of requests served (0 = trigger not armed)."""
        if not self.queue.ready():
            return 0
        reqs = self.queue.drain()
        self._dispatch(reqs)
        return len(reqs)

    def flush(self) -> int:
        """Dispatch everything pending regardless of trigger state."""
        served = 0
        while len(self.queue):
            reqs = self.queue.drain()
            self._dispatch(reqs)
            served += len(reqs)
        return served

    def result(self, rid: int) -> ServeResult:
        """Pop a completed request's result (KeyError if not served yet)."""
        return self._results.pop(rid)

    # -- the fused command block -------------------------------------------

    def _shape_block(self, ids: np.ndarray, mask: np.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
        """(R, K) host block → ((P, r, K) device pair, original R). Rows
        pad to a multiple of the shard count with all-masked rows — they
        ride the ``-1`` dead-id encoding, reduce to the op identity on
        whatever shard receives them, and are sliced off on return."""
        R, K = ids.shape
        P = self.n_shards
        r = -(-R // P)
        pad = P * r - R
        if pad:
            ids = np.concatenate([ids, np.zeros((pad, K), ids.dtype)])
            mask = np.concatenate([mask, np.zeros((pad, K), bool)])
        return (jnp.asarray(ids.reshape(P, r, K), jnp.int32),
                jnp.asarray(mask.reshape(P, r, K)), R)

    def _unshape(self, out: jnp.ndarray, n_rows: int) -> np.ndarray:
        """(P, r, F) device result → (n_rows, F) host rows, pad dropped.
        A writable copy — the cache substitutes hit rows in place."""
        return np.array(out, copy=True).reshape(-1, self.n_features)[:n_rows]

    def _request_segments(self, req: ServeRequest):
        """One request → its two command-block segments: the K=1 self-row
        lookup (hot-cache hits masked out) and the fan-out aggregation."""
        if self.cache is not None:
            cached_rows, hit = self.cache.lookup(req.seeds, self.n_features,
                                                 dtype=self.feat_dtype)
        else:
            cached_rows = None
            hit = np.zeros(req.seeds.shape[0], bool)
        lookup_ids = req.seeds[:, None].astype(np.int32)
        fan_ids = req.nbrs.astype(np.int32)
        if self._relabel is not None:
            # translate caller-visible ids into the islandized table space
            # at the command-block door; rows come back positionally (one
            # row per requested id), so no un-relabel is needed on
            # scatter-back and the cache above stays keyed on original ids
            lookup_ids = self._relabel[lookup_ids]
            fan_ids = self._relabel[fan_ids]
        lookup = (lookup_ids, ~hit[:, None])
        fan = (fan_ids, req.mask)
        return lookup, fan, cached_rows, hit

    def _build_blocks(self, reqs: List[ServeRequest]):
        """The fused command block for one drained batch: per request a
        (lookup, fan-out) segment pair, every segment tenant-tagged in the
        descriptor that scatter-back consults."""
        blocks, shapes, tenants, row_counts, cache_ctx = [], [], [], [], []
        for req in reqs:
            lookup, fan, cached_rows, hit = self._request_segments(req)
            for ids, mask in (lookup, fan):
                dev_ids, dev_mask, R = self._shape_block(ids, mask)
                blocks.append((dev_ids, dev_mask))
                shapes.append(dev_ids.shape[-2:])
                row_counts.append(R)
            tenants.extend([req.tenant, req.tenant])
            cache_ctx.append((cached_rows, hit))
        desc = cgtrans.segment_descriptor(shapes, tenants)
        return blocks, desc, row_counts, cache_ctx

    def _fetch(self, blocks):
        """ONE ``aggregate_multi`` call — the engine's only dispatch site
        (both fused and naive modes route here; they differ only in how
        many segments each call carries)."""
        return cgtrans.aggregate_multi(
            self.feats, blocks, mesh=self.mesh, dataflow=self.dataflow,
            op=self.op, impl=self.impl, scheduled=self.scheduled,
            wire=self.wire, features=self.features,
            sparse_capacity=self.sparse_capacity)

    def fetch_callable(self, reqs: Optional[List[ServeRequest]] = None):
        """(fn, args) of the exact fused fetch a drain of ``reqs`` (default:
        the current queue contents) would dispatch — hand it to
        ``launch.jaxpr_stats.collective_counts`` for the counted
        collectives-per-drain claim without touching engine state."""
        reqs = list(self.queue._pending) if reqs is None else reqs
        if not reqs:
            raise ValueError("nothing pending to trace")
        blocks, _, _, _ = self._build_blocks(reqs)

        def fn(feats, blocks_):
            return cgtrans.aggregate_multi(
                feats, blocks_, mesh=self.mesh, dataflow=self.dataflow,
                op=self.op, impl=self.impl, scheduled=self.scheduled,
                wire=self.wire, features=self.features,
                sparse_capacity=self.sparse_capacity)
        return fn, (self.feats, tuple(blocks))

    def _dispatch(self, reqs: List[ServeRequest]) -> None:
        if not reqs:
            return
        t0 = self.clock()
        blocks, desc, row_counts, cache_ctx = self._build_blocks(reqs)
        with gas.count_dispatches() as counts:
            if self.fuse:
                outs = self._fetch(blocks)
                self.stats["command_blocks"] += 1
            else:
                # one-query-one-dispatch baseline: each request's segment
                # pair goes out as its own command block
                outs = []
                for j in range(len(reqs)):
                    outs.extend(self._fetch(blocks[2 * j:2 * j + 2]))
                self.stats["command_blocks"] += len(reqs)
        for k in ("find", "reduce", "kernel_scatter"):
            self.stats[k] += counts[k]
        self.stats["dispatches"] += 1
        self.stats["queries"] += len(reqs)

        for j, req in enumerate(reqs):
            si_look, si_fan = 2 * j, 2 * j + 1
            if desc.tenants[si_look] != req.tenant:
                raise RuntimeError(
                    f"tenant scatter-back mismatch: segment {si_look} is "
                    f"tagged {desc.tenants[si_look]}, request {req.rid} "
                    f"belongs to {req.tenant}")
            self_rows = self._unshape(outs[si_look], row_counts[si_look])
            agg_rows = self._unshape(outs[si_fan], row_counts[si_fan])
            cached_rows, hit = cache_ctx[j]
            if self.cache is not None:
                if hit.any():
                    self_rows[hit] = cached_rows[hit]
                if (~hit).any():
                    self.cache.fill(req.seeds[~hit], self_rows[~hit])
            self._results[req.rid] = ServeResult(
                rid=req.rid, tenant=req.tenant, self_rows=self_rows,
                agg_rows=agg_rows, from_cache=hit)

        self.monitor.record(self.stats["dispatches"], self.clock() - t0)
        if self.heartbeat is not None:
            self.heartbeat.touch()

    # -- observability ------------------------------------------------------

    def finds_per_query(self) -> float:
        q = self.stats["queries"]
        return self.stats["find"] / q if q else 0.0

    def health_snapshot(self) -> Dict[str, object]:
        snap: Dict[str, object] = {
            "stats": dict(self.stats),
            "finds_per_query": self.finds_per_query(),
            "queue_depth": len(self.queue),
            "monitor": self.monitor.snapshot(),
        }
        if self.cache is not None:
            snap["cache"] = self.cache.snapshot()
        return snap
