"""Online serving: cross-request coalesced SSD command blocks.

The paper's command-queue batching promoted to the serving front door —
see ``repro.serving.engine`` for the claims and ``launch/serve.py
--workload graph`` for the runnable loop.
"""

from repro.serving.cache import HotVertexCache
from repro.serving.engine import ServeResult, ServingEngine
from repro.serving.queue import RequestQueue, ServeRequest

__all__ = ["HotVertexCache", "RequestQueue", "ServeRequest", "ServeResult",
           "ServingEngine"]
