"""The serving front door's request queue: size-or-deadline batching.

Concurrent callers submit independent seed sets; the queue accumulates them
until EITHER ``max_batch`` requests are pending (size trigger — the SSD
command block is full) OR the oldest request has waited ``max_delay_s``
(deadline trigger — latency floor for a trickle of traffic). The engine
polls ``ready()`` and ``drain()``s a batch; everything drained together
fuses into ONE coalesced command block.

The clock is injectable so the deadline trigger is deterministic under
test (pass a fake monotonic counter instead of ``time.monotonic``).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class ServeRequest:
    """One caller's query: aggregate ``fanout`` sampled neighbors per seed.

    The neighbor sample is drawn at SUBMIT time (host CSR sampler, rng keyed
    by the request id) and travels with the request — fused and sequential
    dispatch therefore aggregate the *identical* (nbrs, mask) block, which
    is what makes fused ≡ sequential a bit-exactness claim rather than a
    statistical one.
    """
    rid: int                  # engine-assigned request id (unique)
    tenant: int               # the CALLER the results must scatter back to
    seeds: np.ndarray         # (B,) int32 query vertex ids
    nbrs: np.ndarray          # (B, K) int32 sampled neighbor ids
    mask: np.ndarray          # (B, K) bool sample validity
    enqueued_at: float        # queue clock at submit


class RequestQueue:
    """FIFO accumulator with a size-or-deadline dispatch trigger."""

    def __init__(self, *, max_batch: int = 8, max_delay_s: float = 0.005,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.clock = clock
        self._pending: Deque[ServeRequest] = collections.deque()
        self.submitted = 0
        self.drained = 0

    def push(self, req: ServeRequest) -> None:
        self._pending.append(req)
        self.submitted += 1

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def oldest_wait(self) -> float:
        """Seconds the head-of-line request has been waiting (0 if empty)."""
        if not self._pending:
            return 0.0
        return self.clock() - self._pending[0].enqueued_at

    def ready(self) -> bool:
        """Dispatch trigger: the batch is full OR the head request's
        deadline has passed."""
        if not self._pending:
            return False
        return (len(self._pending) >= self.max_batch
                or self.oldest_wait >= self.max_delay_s)

    def drain(self, limit: Optional[int] = None) -> List[ServeRequest]:
        """Pop up to ``limit`` (default ``max_batch``) requests, FIFO."""
        n = min(len(self._pending),
                self.max_batch if limit is None else limit)
        out = [self._pending.popleft() for _ in range(n)]
        self.drained += len(out)
        return out

    def drain_all(self) -> List[ServeRequest]:
        return self.drain(limit=len(self._pending))
