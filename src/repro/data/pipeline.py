"""Deterministic data pipelines.

* ``TokenStream``    — synthetic LM token batches, deterministic in
  (seed, step, host), resumable from any step (stateless indexing — the
  fault-tolerance property: a restarted trainer regenerates the exact batch).
* ``ShardedTokenFiles`` — file-backed token shards + manifest (the production
  path): writer + resumable reader with per-host sharding.
* ``GraphBatchStream`` — GraphSAGE minibatches (seed ids + sampled 1/2-hop
  neighborhoods + labels) from a COO graph; ships only ids (CGTrans keeps raw
  features on the storage tier).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional

import numpy as np

from repro.graph.structure import COOGraph


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    host: int = 0
    n_hosts: int = 1
    with_frames: int = 0      # whisper: frame-embedding stub (enc_seq)
    with_vision: int = 0      # vlm: patch-embedding stub (vision_seq)
    d_model: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq_len + 1),
                            dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.with_frames:
            out["frames"] = rng.standard_normal(
                (self.batch, self.with_frames, self.d_model)).astype(np.float32)
        if self.with_vision:
            out["vision"] = rng.standard_normal(
                (self.batch, self.with_vision, self.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ShardedTokenFiles:
    """npy token shards + JSON manifest; deterministic resumable reads."""

    def __init__(self, root: str):
        self.root = root
        self.manifest_path = os.path.join(root, "manifest.json")

    @staticmethod
    def write(root: str, tokens: np.ndarray, shard_size: int = 1 << 16) -> None:
        os.makedirs(root, exist_ok=True)
        shards = []
        for i in range(0, len(tokens), shard_size):
            name = f"shard_{i // shard_size:05d}.npy"
            np.save(os.path.join(root, name), tokens[i:i + shard_size])
            shards.append(name)
        tmp = os.path.join(root, ".manifest.tmp")
        with open(tmp, "w") as f:
            json.dump({"shards": shards, "total": len(tokens)}, f)
        os.replace(tmp, os.path.join(root, "manifest.json"))

    def reader(self, batch: int, seq_len: int, *, start_step: int = 0,
               host: int = 0, n_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        with open(self.manifest_path) as f:
            manifest = json.load(f)
        data = np.concatenate(
            [np.load(os.path.join(self.root, s)) for s in manifest["shards"]])
        data = data.reshape(-1)
        span = batch * (seq_len + 1)
        step = start_step
        while True:
            off = ((step * n_hosts + host) * span) % max(len(data) - span, 1)
            chunk = data[off:off + span].reshape(batch, seq_len + 1).astype(np.int32)
            yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
            step += 1


@dataclasses.dataclass
class GraphBatchStream:
    """Minibatch sampler for 2-layer GraphSAGE (ids only on the wire)."""

    graph: COOGraph
    labels: np.ndarray            # (V,) int32 class labels
    n_parts: int                  # data-axis shards (seed sharding)
    batch_per_part: int
    k1: int = 10
    k2: int = 10
    seed: int = 0

    def __post_init__(self):
        self.indptr, self.indices, _ = self.graph.to_csr()

    def _sample(self, rng, seeds: np.ndarray, k: int):
        lo = self.indptr[seeds]
        hi = self.indptr[seeds + 1]
        deg = (hi - lo).astype(np.int64)
        offs = (rng.random((len(seeds), k)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
        idx = np.minimum(lo[:, None] + offs, len(self.indices) - 1)
        nbrs = self.indices[idx].astype(np.int32)
        mask = np.broadcast_to(deg[:, None] > 0, nbrs.shape)
        nbrs = np.where(mask, nbrs, seeds[:, None].astype(np.int32))
        return nbrs, mask

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        P, B = self.n_parts, self.batch_per_part
        seeds = rng.integers(0, self.graph.n_vertices, (P, B)).astype(np.int32)
        flat = seeds.reshape(-1)
        n1, m1 = self._sample(rng, flat, self.k1)
        lay1 = np.concatenate([flat[:, None], n1], axis=1).reshape(-1)
        n2, m2 = self._sample(rng, lay1, self.k2)
        return {
            "seeds": seeds,
            "nbrs1": n1.reshape(P, B, self.k1),
            "mask1": m1.reshape(P, B, self.k1),
            "nbrs2": n2.reshape(P, B * (1 + self.k1), self.k2),
            "mask2": m2.reshape(P, B * (1 + self.k1), self.k2),
            "labels": self.labels[seeds].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def synthetic_node_labels(feats: np.ndarray, n_classes: int, seed: int = 0) -> np.ndarray:
    """Learnable labels: argmax of a fixed random projection of features."""
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((feats.shape[1], n_classes)).astype(np.float32)
    return np.argmax(feats @ proj, axis=1).astype(np.int32)
