from repro.data.pipeline import (
    GraphBatchStream,
    ShardedTokenFiles,
    TokenStream,
    synthetic_node_labels,
)

__all__ = ["GraphBatchStream", "ShardedTokenFiles", "TokenStream",
           "synthetic_node_labels"]
