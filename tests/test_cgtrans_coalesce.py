"""Coalesced-request tier: one SSD command block ≡ two separate streams.

``cgtrans.aggregate_multi`` concatenates several sampled request segments
(e.g. ``sage_forward``'s K=1 self-row lookup + its 2-hop aggregation) into
ONE command block: one request broadcast, one kernel gather, one compressed
result shipment, one backward cotangent scatter. Four layers of guarantees:

1. **In-process equivalence matrix** — coalesced ≡ separate BIT-exact
   (values AND gradients, on integer-valued data where float addition is
   associative, so any dropped/duplicated/reordered contribution is a hard
   mismatch; the gradient cells additionally pin power-of-two fan-in so the
   mean shares ``u/cnt`` stay dyadic — the combined backward scatter may
   legally re-associate the sum, which must not cost a ulp) over
   dataflow × impl × {chunked, unchunked} × {scheduled on, off} on the
   single-shard reference path — plus ``sage_forward(coalesce=True)`` ≡
   ``coalesce=False`` end to end.
2. **Segment-descriptor invariants** (``_propcheck``) — offsets are exact
   prefix sums, split∘concat is the identity for arbitrary segment shapes,
   and chunk boundaries can never span a segment.
3. **Deterministic dispatch counters** — ``gas.count_dispatches`` (trace
   time, immune to jit caching and XLA passes): the coalesced fetch issues
   ONE ``find`` where the separate form issues two, and its VJP issues ONE
   backward kernel scatter where the separate form issues two. The
   collective count (all_gather/all_to_all: 2 → 1 on the sharded cgtrans
   dataflow) is asserted the same way inside the 8-way subprocess case.
4. **On-mesh matrix** (``distributed`` marker) — the
   dataflow × impl × {chunked, unchunked} × {scheduled on, off} grid on a
   REAL 8-way ``shard_map`` mesh via one shared subprocess run
   (``case_cgtrans_coalesce_parity``); each cell asserted as its own test.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.analysis.contracts import (SAGE_FETCH_DISPATCH,
                                      SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD)
from repro.core import cgtrans, gas

FLOWS = ("cgtrans", "baseline")
OPS = ("add", "max", "min", "or")


def _int_feats(rng, p, part, f, op):
    """Integer-valued float features — addition is associative, so
    coalesced ≡ separate can be asserted bit-for-bit."""
    x = rng.integers(-4, 5, (p, part, f)).astype(np.float32)
    if op == "or":
        return jnp.asarray((x > 0).astype(np.int32))
    return jnp.asarray(x)


def _two_blocks(rng, p, v, b, k1, k2):
    """A sage-shaped request pair: a K=1 all-valid lookup segment + a
    masked fan-out segment."""
    nb1 = jnp.asarray(rng.integers(0, v, (p, b, k1)).astype(np.int32))
    mk1 = jnp.ones((p, b, k1), bool)
    nb2 = jnp.asarray(rng.integers(0, v, (p, b + 3, k2)).astype(np.int32))
    mk2 = jnp.asarray(rng.random((p, b + 3, k2)) < 0.8)
    return (nb1, mk1), (nb2, mk2)


# ---------------------------------------------------------------------------
# 1. coalesced ≡ separate, bit-exact, values and gradients
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduled", [False, True])
@pytest.mark.parametrize("chunk", [None, 3])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("op", OPS)
def test_coalesced_equals_separate_bitexact(rng, op, impl, chunk, scheduled):
    P_, part, F = 2, 32, 8
    feats = _int_feats(rng, P_, part, F, op)
    b1, b2 = _two_blocks(rng, P_, P_ * part, 7, 1, 6)
    kw = dict(mesh=None, op=op, impl=impl, request_chunk=chunk,
              scheduled=scheduled)
    sep = [cgtrans.aggregate_sampled(feats, nb, mk, **kw)
           for nb, mk in (b1, b2)]
    coa = cgtrans.aggregate_multi(feats, (b1, b2), **kw)
    for i, (s, c) in enumerate(zip(sep, coa)):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(s),
                                      err_msg=f"segment {i} diverged")


@pytest.mark.parametrize("chunk", [None, 3])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_coalesced_grads_bitexact(rng, impl, chunk):
    """d_feats through the coalesced block ≡ through the separate calls,
    bit-for-bit: integer cotangents and power-of-two valid counts per seed,
    so every mean share ``u/cnt`` is dyadic and summation order (the one
    thing coalescing changes in the backward scatter) cannot shift a ulp."""
    P_, part, F = 2, 16, 4
    feats = _int_feats(rng, P_, part, F, "add")
    nb1 = jnp.asarray(rng.integers(0, P_ * part, (P_, 5, 1)).astype(np.int32))
    mk1 = jnp.ones((P_, 5, 1), bool)
    nb2 = jnp.asarray(rng.integers(0, P_ * part, (P_, 8, 4)).astype(np.int32))
    cnt = 2 ** rng.integers(0, 3, (P_, 8))          # 1, 2 or 4 valid samples
    mk2 = jnp.asarray(np.arange(4)[None, None, :] < cnt[..., None])
    b1, b2 = (nb1, mk1), (nb2, mk2)
    u1 = jnp.asarray(rng.integers(-3, 4, (P_, 5, F)).astype(np.float32))
    u2 = jnp.asarray(rng.integers(-3, 4, (P_, 8, F)).astype(np.float32))
    kw = dict(mesh=None, impl=impl, request_chunk=chunk)

    def loss_sep(f):
        a = cgtrans.aggregate_sampled(f, *b1, **kw)
        b = cgtrans.aggregate_sampled(f, *b2, **kw)
        return jnp.sum(a * u1) + jnp.sum(b * u2)

    def loss_coa(f):
        a, b = cgtrans.aggregate_multi(f, (b1, b2), **kw)
        return jnp.sum(a * u1) + jnp.sum(b * u2)

    gs = jax.grad(loss_sep)(feats)
    gc = jax.grad(loss_coa)(feats)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(gs))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_sage_forward_coalesce_flag_bitexact(rng, impl):
    """The deployment path: sage_forward(coalesce=True) ≡ the legacy
    two-body form, logits AND parameter gradients."""
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema, sage_forward

    P_, B, K1, K2, V, F = 2, 4, 3, 5, 64, 8
    feats = _int_feats(rng, P_, V // P_, F, "add")
    batch = {
        "seeds": jnp.asarray(rng.integers(0, V, (P_, B)).astype(np.int32)),
        "nbrs1": jnp.asarray(rng.integers(0, V, (P_, B, K1)).astype(np.int32)),
        "mask1": jnp.asarray(rng.random((P_, B, K1)) < 0.8),
        "nbrs2": jnp.asarray(
            rng.integers(0, V, (P_, B * (1 + K1), K2)).astype(np.int32)),
        "mask2": jnp.asarray(rng.random((P_, B * (1 + K1), K2)) < 0.8),
    }
    outs, grads = {}, {}
    for coalesce in (True, False):
        cfg = GCNConfig(n_features=F, hidden=8, n_classes=4, fanout=K2,
                        impl=impl, coalesce=coalesce)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        outs[coalesce] = sage_forward(params, feats, batch, cfg, mesh=None)
        grads[coalesce] = jax.grad(
            lambda p: jnp.sum(sage_forward(p, feats, batch, cfg, mesh=None)
                              ** 2))(params)
    np.testing.assert_array_equal(np.asarray(outs[True]),
                                  np.asarray(outs[False]))
    for (ka, ga), (kb, gb) in zip(sorted(grads[True].items()),
                                  sorted(grads[False].items())):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                   atol=1e-6, rtol=1e-6,
                                   err_msg=f"param {ka} grad diverged")


def test_multi_all_masked(rng):
    """A fully-masked segment must not contaminate its neighbors: segment 0
    reads 0 everywhere, segment 1 is unaffected."""
    P_, part, F = 2, 16, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb1 = jnp.asarray(rng.integers(0, P_ * part, (P_, 5, 3)).astype(np.int32))
    mk1 = jnp.zeros((P_, 5, 3), bool)
    nb2 = jnp.asarray(rng.integers(0, P_ * part, (P_, 4, 2)).astype(np.int32))
    mk2 = jnp.ones((P_, 4, 2), bool)
    for impl in ("xla", "pallas"):
        o1, o2 = cgtrans.aggregate_multi(feats, ((nb1, mk1), (nb2, mk2)),
                                         mesh=None, impl=impl)
        np.testing.assert_array_equal(np.asarray(o1), 0.0, err_msg=impl)
        ref = cgtrans.aggregate_sampled(feats, nb2, mk2, mesh=None, impl=impl)
        np.testing.assert_array_equal(np.asarray(o2), np.asarray(ref),
                                      err_msg=impl)


# ---------------------------------------------------------------------------
# 2. segment-descriptor invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_segments=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
    chunk=st.integers(1, 17),
)
def test_property_segment_descriptor_invariants(n_segments, seed, chunk):
    """Offsets are exact prefix sums; split∘concat is the identity; a chunk
    boundary can never span two segments (each segment streams its own
    command queue, so every chunk's rows carry one single K)."""
    rng = np.random.default_rng(seed)
    shapes = [(int(rng.integers(1, 9)), int(rng.integers(1, 6)))
              for _ in range(n_segments)]
    desc = cgtrans.segment_descriptor(shapes)

    assert desc.shapes == tuple(shapes)
    assert len(desc.id_offsets) == n_segments + 1
    assert len(desc.row_offsets) == n_segments + 1
    assert desc.id_offsets[0] == 0 and desc.row_offsets[0] == 0
    for i, (r, k) in enumerate(shapes):
        assert desc.id_offsets[i + 1] - desc.id_offsets[i] == r * k
        assert desc.row_offsets[i + 1] - desc.row_offsets[i] == r
    assert desc.n_ids == sum(r * k for r, k in shapes)
    assert desc.n_rows == sum(r for r, _ in shapes)

    # split ∘ concat = identity on the encoded stream
    blocks = []
    for r, k in shapes:
        nb = jnp.asarray(rng.integers(0, 100, (1, r, k)).astype(np.int32))
        mk = jnp.asarray(rng.random((1, r, k)) < 0.7)
        blocks.append((nb, mk))
    enc = cgtrans._encode_requests(blocks)
    assert enc.shape == (1, desc.n_ids)
    for i, (nb, mk) in enumerate(blocks):
        sl = enc[:, desc.id_offsets[i]:desc.id_offsets[i + 1]]
        np.testing.assert_array_equal(
            np.asarray(sl.reshape(nb.shape)),
            np.where(np.asarray(mk), np.asarray(nb), -1))

    # chunking partitions each segment's ROWS: every chunk is a slice of
    # exactly one segment (single K), never a straddle of two
    for r, k in shapes:
        for start in range(0, r, chunk):
            rows = min(chunk, r - start)
            assert rows >= 1 and rows * k <= r * k


def test_segment_descriptor_rejects_degenerate():
    with pytest.raises(ValueError):
        cgtrans.segment_descriptor([])
    with pytest.raises(ValueError):
        cgtrans.segment_descriptor([(4, 0)])


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 9),
    k2=st.integers(2, 6),
    chunk=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_multi_chunked_bitexact(b, k2, chunk, seed):
    """The chunked coalesced command queue is BIT-exact with the unchunked
    block for arbitrary chunk sizes — chunk boundaries respect the
    descriptor, so no seed's contributions ever split."""
    rng = np.random.default_rng(seed)
    P_, part, F = 2, 16, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    b1, b2 = _two_blocks(rng, P_, P_ * part, b, 1, k2)
    ref = cgtrans.aggregate_multi(feats, (b1, b2), mesh=None)
    out = cgtrans.aggregate_multi(feats, (b1, b2), mesh=None,
                                  request_chunk=chunk)
    for s, c in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(s))


# ---------------------------------------------------------------------------
# 3. deterministic dispatch counters (trace-time, jit/XLA-proof)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_dispatch_counts_halve(rng, impl):
    """The coalescing claim, counted: ONE find (table gather) where the
    two-stream form issues two — on both backends — and under pallas ONE
    backward kernel scatter where the separate form issues two (the
    combined gather's VJP scatters the whole cotangent block at once)."""
    P_, part, F = 2, 16, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    b1, b2 = _two_blocks(rng, P_, P_ * part, 5, 1, 4)

    def loss_sep(f):
        a = cgtrans.aggregate_sampled(f, *b1, mesh=None, impl=impl)
        b = cgtrans.aggregate_sampled(f, *b2, mesh=None, impl=impl)
        return jnp.sum(a) + jnp.sum(b)

    def loss_coa(f):
        a, b = cgtrans.aggregate_multi(f, (b1, b2), mesh=None, impl=impl)
        return jnp.sum(a) + jnp.sum(b)

    with gas.count_dispatches() as sep_f:
        jax.make_jaxpr(loss_sep)(feats)
    with gas.count_dispatches() as coa_f:
        jax.make_jaxpr(loss_coa)(feats)
    # the budgets come from analysis/contracts.py — the SINGLE source of
    # truth (finds 2 → 1; the K=1 segment stays a pure find, so exactly
    # one seed reduction runs either way)
    for key, counts in (("separate", sep_f), ("coalesced", coa_f)):
        for disp, want in SAGE_FETCH_DISPATCH[key].items():
            assert counts[disp] == want, (key, disp, dict(counts))

    with gas.count_dispatches() as sep_g:
        jax.make_jaxpr(jax.grad(loss_sep))(feats)
    with gas.count_dispatches() as coa_g:
        jax.make_jaxpr(jax.grad(loss_coa))(feats)
    assert sep_g["find"] == SAGE_FETCH_DISPATCH["separate"]["find"], sep_g
    assert coa_g["find"] == SAGE_FETCH_DISPATCH["coalesced"]["find"], coa_g
    if impl == "pallas":
        # forward+backward kernel dispatches: the separate form pays one
        # fused forward scatter + TWO backward cotangent scatters (one per
        # gather); coalesced pays one forward + ONE backward
        for key, counts in (("separate", sep_g), ("coalesced", coa_g)):
            want = SAGE_FETCH_KERNEL_SCATTERS_FWD_BWD[key]
            assert counts["kernel_scatter"] == want, (key, dict(counts))


def test_k1_segment_stays_pure_find(rng):
    """A lone K=1 block never dispatches a kernel scatter forward (PR 4's
    pure-find specialization survives coalescing)."""
    P_, part, F = 2, 16, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb1 = jnp.asarray(rng.integers(0, P_ * part, (P_, 5, 1)).astype(np.int32))
    mk1 = jnp.ones((P_, 5, 1), bool)
    with gas.count_dispatches() as c:
        jax.make_jaxpr(lambda f: cgtrans.aggregate_multi(
            f, ((nb1, mk1),), mesh=None, impl="pallas")[0])(feats)
    assert c["find"] == 1 and c["reduce"] == 0 and c["kernel_scatter"] == 0, c


# ---------------------------------------------------------------------------
# 4. the on-mesh matrix: every cell of the shared 8-way subprocess run
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("chunked", ["off", "on"])
def test_mesh_coalesce_cell(coalesce_parity_report, flow, impl, chunked):
    line = f"coalesce flow={flow} impl={impl} chunked={chunked} ok"
    assert line in coalesce_parity_report, (
        f"missing/failed matrix cell: {line!r}")


@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("sched", ["off", "on"])
def test_mesh_coalesce_scheduled(coalesce_parity_report, flow, sched):
    line = f"coalesce flow={flow} impl=pallas sched={sched} ok"
    assert line in coalesce_parity_report, (
        f"missing/failed scheduled cell: {line!r}")


@pytest.mark.distributed
def test_mesh_coalesce_collective_count(coalesce_parity_report):
    """The headline, asserted on the real 8-way mesh: collectives-per-step
    2 → 1 (all_gather AND all_to_all) on the cgtrans dataflow, halved on
    baseline, plus grads and the sage_forward train-step twin."""
    for line in (
        "coalesce collectives cgtrans separate=2 coalesced=1 ok",
        "coalesce collectives baseline halved ok",
        "coalesce grads flow=cgtrans ok",
        "coalesce grads flow=baseline ok",
        "coalesce sage-forward mesh parity ok",
    ):
        assert line in coalesce_parity_report, f"missing: {line!r}"
