"""Layer-library unit tests: norms, rope, chunked attention, caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.models import layers as L


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
                compute_dtype="float32", remat="none")
    base.update(kw)
    return ModelConfig(**base)


def test_rms_norm_unit_scale(rng):
    x = jnp.asarray(rng.standard_normal((2, 8, 32)).astype(np.float32)) * 7.0
    out = L.rms_norm(x, jnp.ones(32), 1e-6, zero_centered=False)
    rms = jnp.sqrt(jnp.mean(out**2, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_rms_zero_centered_matches_plain(rng):
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    a = L.rms_norm(x, jnp.zeros(32), 1e-6, zero_centered=True)
    b = L.rms_norm(x, jnp.ones(32), 1e-6, zero_centered=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_layer_norm_moments(rng):
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32)) * 3 + 5
    out = L.layer_norm(x, jnp.ones(32), jnp.zeros(32), 1e-6)
    np.testing.assert_allclose(np.asarray(out.mean(-1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out.std(-1)), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative(rng):
    hd = 16
    pos = jnp.arange(12)
    cos, sin = L.rope_tables(pos, hd, 10000.0)
    x = jnp.asarray(rng.standard_normal((1, 12, 2, hd)).astype(np.float32))
    rx = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(rx, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)), atol=1e-4)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = x[:, :1]
    k = x[:, 1:2]
    def dot_at(p):
        c1, s1 = L.rope_tables(jnp.array([p]), hd, 10000.0)
        c2, s2 = L.rope_tables(jnp.array([p + 3]), hd, 10000.0)
        return float(jnp.sum(L.apply_rope(q, c1, s1) * L.apply_rope(k, c2, s2)))
    assert abs(dot_at(0) - dot_at(7)) < 1e-3


def test_chunked_attention_equals_flash_ref(rng):
    from repro.kernels.flash_attention import flash_attention_ref
    B, S, H, Hkv, hd = 2, 96, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32)) * hd**-0.5
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)).astype(np.float32))
    for kw in (dict(causal=True), dict(causal=True, window=32),
               dict(causal=True, softcap=20.0), dict(causal=False)):
        got = L.chunked_attention(q, k, v, q_chunk=32, **kw)
        want = flash_attention_ref(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)


def test_ring_cache_decode_matches_full(rng):
    """Local-attention ring cache gives the same result as a full cache."""
    cfg = _cfg(pattern=("local",), window=8)
    key = jax.random.PRNGKey(0)
    from repro.common.schema import init_params
    p = init_params(L.attn_schema(cfg), key)
    S = 24
    x = jnp.asarray(rng.standard_normal((1, S, 32)).astype(np.float32))
    ctx = lambda s: L.LayerCtx(
        cfg=cfg, rope_local=L.rope_tables(jnp.arange(s) if np.ndim(s) == 0 else s, cfg.hd, 1e4),
        rope_global=L.rope_tables(jnp.arange(s) if np.ndim(s) == 0 else s, cfg.hd, 1e4))
    full = L.attn_apply(p, x, ctx(S), kind="local")
    # prefill S-1 then decode last token
    c = ctx(S - 1)
    _, cache = L.attn_prefill(p, x[:, :S - 1], c, kind="local", cache_len=S)
    assert cache["k"].shape[1] == cfg.window   # ring, not full
    pos = jnp.array(S - 1, jnp.int32)
    cd = L.LayerCtx(cfg=cfg,
                    rope_local=L.rope_tables(pos[None], cfg.hd, 1e4),
                    rope_global=L.rope_tables(pos[None], cfg.hd, 1e4), pos=pos)
    out, _ = L.attn_decode(p, x[:, S - 1:], cache, cd, kind="local")
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=1e-3)


def test_gqa_repeats_heads_correctly(rng):
    """GQA with Hkv=H and duplicated kv == MHA." """
    B, S, H, hd = 1, 16, 4, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, 2, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, 2, hd)).astype(np.float32))
    a = L.chunked_attention(q, k, v, causal=True)
    b = L.chunked_attention(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2), causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mask_bias_window():
    bias = np.asarray(L._mask_bias(jnp.arange(6), jnp.arange(6), causal=True, window=3))
    for i in range(6):
        for j in range(6):
            visible = j <= i and i - j < 3
            assert (bias[i, j] == 0) == visible
