"""The compressed wire format (``repro.core.wire``): codec properties on
the host, and the parity matrix on the real 8-way mesh.

Layer 1 (runs everywhere, 1 device): the codecs are PURE transforms, so
their contracts are property-testable without a mesh — delta id streams
round-trip with ``-1`` sentinels intact, bf16 is bit-exact on small
integers, int8 error is bounded by the per-row scale, non-finite entries
ride the sentinel code and decode to the op identity, and the "exact"
trailing columns are bit copies.

Layer 2 (``@pytest.mark.distributed``): one subprocess run of
``distributed_cases.case_wire_parity`` on 8 fake devices; each test here
asserts one printed cell — same pattern as the pallas/coalesce/grad tiers.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _propcheck import given, settings, strategies as st
from repro.core import cgtrans, wire


# ---------------------------------------------------------------------------
# 1. codec properties (host-level, no mesh)
# ---------------------------------------------------------------------------

def test_validate_rejects_unknown_format():
    with pytest.raises(ValueError, match="unknown wire format"):
        wire.validate("q4")
    for w in wire.WIRE_FORMATS:
        assert wire.validate(w) == w


def test_delta_fit_gate_is_the_int16_boundary():
    assert wire.delta_ids_fit(wire.ID_DELTA_MAX_V)
    assert not wire.delta_ids_fit(wire.ID_DELTA_MAX_V + 1)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-1, wire.ID_DELTA_MAX_V - 1),
                min_size=1, max_size=64),
       st.integers(1, 4))
def test_delta_ids_roundtrip_identity(ids, rows):
    """Any in-gate id stream — sorted or not, ``-1`` dead ids anywhere —
    decodes back bit-for-bit (the decode is an int32 cumsum, so whatever
    the encode summed to comes back exactly)."""
    block = jnp.asarray(np.tile(np.asarray(ids, np.int32), (rows, 1)))
    out = wire.delta_decode_ids(wire.delta_encode_ids(block))
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(block))


def test_delta_ids_wire_is_int16():
    enc = wire.delta_encode_ids(jnp.asarray([[0, 5, -1, 3]], jnp.int32))
    assert enc.dtype == jnp.int16     # half the all_gather bytes — the claim


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-256, 256), min_size=1, max_size=32))
def test_bf16_bitexact_on_small_integers(vals):
    """Integer payloads with |x| ≤ 256 fit bf16's 8 mantissa bits — the
    precondition the grad-parity tiers' bit-exact claim rests on."""
    x = jnp.asarray(np.asarray(vals, np.float32)[None])
    out = wire.decode_payload(wire.encode_payload(x, "bf16"), "bf16")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_bf16_preserves_inf_identities():
    x = jnp.asarray([[np.inf, -np.inf, 3.0]], np.float32)
    out = wire.decode_payload(wire.encode_payload(x, "bf16"), "bf16")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=32),
       st.integers(0, 10**6))
def test_int8_roundtrip_error_bounded_by_row_scale(vals, seed):
    """|decode(encode(x)) − x| ≤ scale/2 per entry, with the SAME scale the
    encoder used (``wire.int8_row_scale`` is exported exactly so this bound
    is asserted against the encoder's own number, not a re-derivation)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.permutation(np.asarray(vals, np.float32))[None])
    out = wire.decode_payload(wire.encode_payload(x, "int8"), "int8")
    scale = np.asarray(wire.int8_row_scale(x))[..., None]
    err = np.abs(np.asarray(out) - np.asarray(x))
    assert (err <= scale / 2 + 1e-6).all(), (err.max(), scale.max())


def test_int8_sentinel_decodes_to_op_identity():
    """±inf entries (the max/min identity rows of a partial block) ship as
    the reserved −128 code and decode back to the requested identity —
    never to a quantized garbage value."""
    x = jnp.asarray([[np.inf, -np.inf, 2.0, -2.0]], np.float32)
    for ident in (0.0, float(np.inf), float(-np.inf)):
        out = np.asarray(wire.decode_payload(
            wire.encode_payload(x, "int8", identity=ident), "int8",
            identity=ident))
        assert out[0, 0] == ident and out[0, 1] == ident
        np.testing.assert_allclose(out[0, 2:], [2.0, -2.0], atol=2.0 / 127)


def test_int8_zero_row_roundtrips_to_zero():
    x = jnp.zeros((3, 8), jnp.float32)
    out = wire.decode_payload(wire.encode_payload(x, "int8"), "int8")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=3))
def test_int8_exact_columns_are_bit_copies(vals):
    """``n_exact`` trailing columns (the op="add" contribution counts) ride
    as 4 bitcast int8 columns each — EXACT, so means never divide by a
    quantized count."""
    exact = np.asarray(vals, np.float32)[None]        # (1, n_exact)
    n_exact = exact.shape[-1]
    x = jnp.asarray(np.concatenate(
        [np.linspace(-9, 9, 5, dtype=np.float32)[None], exact], axis=-1))
    out = np.asarray(wire.decode_payload(
        wire.encode_payload(x, "int8", n_exact=n_exact), "int8",
        n_exact=n_exact))
    np.testing.assert_array_equal(out[..., 5:], np.asarray(x)[..., 5:])


def test_f32_wire_is_the_identity():
    x = jnp.asarray([[1.5, -2.5]], np.float32)
    assert wire.encode_payload(x, "f32") is x
    assert wire.decode_payload(x, "f32") is x


# ---------------------------------------------------------------------------
# 2. entrypoint plumbing (host-level, unsharded)
# ---------------------------------------------------------------------------

def _tiny_sampled(wire_fmt, dataflow="cgtrans"):
    rng = np.random.default_rng(0)
    feats = jnp.asarray(np.round(rng.standard_normal((1, 16, 8)) * 5.0)
                        .astype(np.float32))
    nbrs = jnp.asarray(rng.integers(0, 16, (1, 4, 3)).astype(np.int32))
    mask = jnp.ones((1, 4, 3), bool)
    return cgtrans.aggregate_sampled(feats, nbrs, mask, mesh=None,
                                     dataflow=dataflow, wire=wire_fmt)


def test_entrypoints_reject_unknown_wire():
    with pytest.raises(ValueError, match="unknown wire format"):
        _tiny_sampled("q4")


def test_baseline_dataflow_rejects_narrow_wire():
    """The baseline ships RAW feature rows — there is no partial block to
    quantize — so asking for a narrow wire on it is a config error, not a
    silent no-op."""
    with pytest.raises(ValueError, match="baseline"):
        _tiny_sampled("bf16", dataflow="baseline")
    # f32 on baseline stays legal (it IS the raw wire)
    _tiny_sampled("f32", dataflow="baseline")


def test_unsharded_path_ignores_wire_bitexactly():
    """With no mesh there is no collective and therefore no wire — every
    format returns the identical local computation."""
    ref = np.asarray(_tiny_sampled("f32"))
    for w in ("bf16", "int8"):
        np.testing.assert_array_equal(np.asarray(_tiny_sampled(w)), ref)


# ---------------------------------------------------------------------------
# 3. the on-mesh matrix: every cell of the shared 8-way subprocess run
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_bf16_sampled_bitexact(wire_parity_report, op, impl):
    line = f"wire path=sampled op={op} impl={impl} bf16 exact ok"
    assert line in wire_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_mesh_bf16_edges_bitexact(wire_parity_report, op):
    line = f"wire path=edges op={op} bf16 exact ok"
    assert line in wire_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_bf16_multi_bitexact(wire_parity_report, impl):
    line = f"wire path=multi impl={impl} bf16 exact ok"
    assert line in wire_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_bf16_grads_bitexact(wire_parity_report, impl):
    """The headline: the backward wire (custom_vjp cotangent shipment) is
    as lossless as the forward on dyadic payloads."""
    line = f"wire grad path=sampled impl={impl} bf16 exact ok"
    assert line in wire_parity_report, f"missing/failed cell: {line!r}"
    assert "wire grad path=multi bf16 exact ok" in wire_parity_report


@pytest.mark.distributed
@pytest.mark.parametrize("path", ["sampled", "edges"])
def test_mesh_int8_bounded(wire_parity_report, path):
    line = f"wire path={path} int8 bounded ok"
    assert line in wire_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
def test_mesh_delta_gate_falls_back_raw(wire_parity_report):
    assert "wire delta-fallback raw-int32 ids ok" in wire_parity_report


@pytest.mark.distributed
def test_mesh_wire_changes_bytes_never_counts(wire_parity_report):
    assert "wire collective counts ok" in wire_parity_report


@pytest.mark.distributed
def test_mesh_serving_on_bf16_wire(wire_parity_report):
    assert "wire serving bf16 exact ok" in wire_parity_report
