"""Gradient-parity differential tier for the differentiable FAST-GAS path.

The paper's find-and-compute symmetry is that the backward pass is itself
GAS work — the backward of a scatter-add is a gather, the backward of a
gather is a scatter — so ``impl="pallas"`` must differentiate end-to-end
through the same kernel the forward uses. Four layers of guarantees:

1. **In-process grad matrix** — ``jax.grad`` parity pallas ≡ xla ≡ a
   central-finite-difference reference over dataflow × op × {full-graph,
   sampled} × {chunked, unchunked} on the single-shard reference path,
   including ragged (non-tile-aligned) edge counts and all-masked inputs.
2. **Property tests** (``_propcheck``) — the ``scan_request_chunks`` VJP is
   *exactly* chunked ≡ unchunked (asserted bit-for-bit on integer-valued
   data, where float addition is associative, so any dropped or duplicated
   contribution shows up as a hard mismatch); and the
   ``gas_scatter_weighted`` pallas VJP equals ``jax.grad`` of the jnp
   oracle for random masks/weights on all four ops.
3. **NaN regression** — seeds with no valid sample used to hold the ±inf
   max/min identity, which autodiff turns into ``0·inf = NaN``; identity
   rows are now masked at the terminal finalize and the all-masked-seed
   grad must be finite (and zero) on both backends.
4. **On-mesh matrix** (``distributed`` marker) — the full grad grid on a
   REAL 8-way ``shard_map`` mesh via one shared subprocess run
   (``case_cgtrans_grad_parity``), plus a 3-step ``make_sage_train_step``
   smoke: ``cfg.impl="pallas"`` trains, the loss decreases, and per-step
   params match ``impl="xla"`` to fp32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import cgtrans, gas

GRAD_OPS = ("add", "max", "min")     # "or" is flat (zero grads) — see below
FLOWS = ("cgtrans", "baseline")


def _grad_close(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=tol, rtol=tol)


def _fd_directional(f, x, v, eps=1e-2):
    """Central-difference directional derivative ⟨∇f, v⟩ at ``x``."""
    return (float(f(x + eps * v)) - float(f(x - eps * v))) / (2 * eps)


def _masked_linear_loss(out, u):
    """⟨mask(out), u⟩ — linear in ``out`` so finite differences are exact up
    to float32 noise; ±inf rows (full-graph vertices with no in-edge) are
    masked exactly the way ``gcn_forward_full`` consumes the aggregation."""
    return jnp.sum(jnp.where(jnp.isfinite(out), out, 0.0) * u)


# ---------------------------------------------------------------------------
# 1. in-process grad matrix (single-shard reference path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduled", [False, True])
@pytest.mark.parametrize("op", GRAD_OPS)
@pytest.mark.parametrize("e", [37, 128])      # ragged + tile-aligned
def test_edges_grad_pallas_vs_xla_vs_fd(rng, op, e, scheduled):
    P_, part, F = 2, 16, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, part, (P_, e)).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, P_ * part, (P_, e)).astype(np.int32))
    w = jnp.asarray(rng.standard_normal((P_, e)).astype(np.float32))
    m = jnp.asarray(rng.random((P_, e)) < 0.8)
    u = jnp.asarray(rng.standard_normal(feats.shape).astype(np.float32))

    def loss(f, wts, impl):
        out = cgtrans.aggregate_edges(f, src, dst, wts, m, mesh=None,
                                      op=op, impl=impl, scheduled=scheduled)
        return _masked_linear_loss(out, u)

    grads = {impl: jax.grad(lambda f, wts: loss(f, wts, impl),
                            argnums=(0, 1))(feats, w)
             for impl in ("xla", "pallas")}
    _grad_close(grads["pallas"][0], grads["xla"][0])
    _grad_close(grads["pallas"][1], grads["xla"][1])

    # finite-difference reference, one random direction per argument
    vf = jnp.asarray(rng.standard_normal(feats.shape).astype(np.float32))
    vw = jnp.asarray(rng.standard_normal(w.shape).astype(np.float32))
    fd_f = _fd_directional(lambda f: loss(f, w, "xla"), feats, vf)
    fd_w = _fd_directional(lambda wts: loss(feats, wts, "xla"), w, vw)
    for impl in ("xla", "pallas"):
        np.testing.assert_allclose(
            float(jnp.vdot(grads[impl][0], vf)), fd_f, atol=1e-2, rtol=1e-2,
            err_msg=f"{impl} d_feats vs finite differences")
        np.testing.assert_allclose(
            float(jnp.vdot(grads[impl][1], vw)), fd_w, atol=1e-2, rtol=1e-2,
            err_msg=f"{impl} d_weights vs finite differences")


@pytest.mark.parametrize("op", GRAD_OPS)
@pytest.mark.parametrize("chunk", [None, 1, 5])
def test_sampled_grad_pallas_vs_xla_vs_fd(rng, op, chunk):
    P_, part, F, B, K = 2, 16, 4, 7, 3
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, B, K)).astype(np.int32))
    mk = jnp.asarray(rng.random((P_, B, K)) < 0.8)
    u = jnp.asarray(rng.standard_normal((P_, B, F)).astype(np.float32))

    def loss(f, impl):
        out = cgtrans.aggregate_sampled(f, nb, mk, mesh=None, op=op,
                                        impl=impl, request_chunk=chunk)
        return jnp.sum(out * u)     # identity rows are already masked to 0

    grads = {impl: jax.grad(lambda f: loss(f, impl))(feats)
             for impl in ("xla", "pallas")}
    _grad_close(grads["pallas"], grads["xla"])

    v = jnp.asarray(rng.standard_normal(feats.shape).astype(np.float32))
    fd = _fd_directional(lambda f: loss(f, "xla"), feats, v)
    for impl in ("xla", "pallas"):
        np.testing.assert_allclose(float(jnp.vdot(grads[impl], v)), fd,
                                   atol=1e-2, rtol=1e-2,
                                   err_msg=f"{impl} vs finite differences")


@pytest.mark.parametrize("op", GRAD_OPS)
def test_sampled_grad_chunked_matches_unchunked(rng, op):
    """Chunk boundaries must not change the VJP: same grads for any depth."""
    P_, part, F, B, K = 2, 16, 4, 13, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, B, K)).astype(np.int32))
    mk = jnp.asarray(rng.random((P_, B, K)) < 0.8)
    u = jnp.asarray(rng.standard_normal((P_, B, F)).astype(np.float32))

    def grad_at(impl, chunk):
        return jax.grad(lambda f: jnp.sum(cgtrans.aggregate_sampled(
            f, nb, mk, mesh=None, op=op, impl=impl, request_chunk=chunk) * u)
        )(feats)

    for impl in ("xla", "pallas"):
        ref = grad_at(impl, None)
        for chunk in (1, 3, 64):
            _grad_close(grad_at(impl, chunk), ref)


def test_or_grads_are_zero(rng):
    """op="or" is flat almost everywhere: the oracle differentiates to exact
    zeros through its int cast and the pallas VJP must agree."""
    P_, part, F, B, K = 2, 16, 4, 5, 3
    feats01 = jnp.asarray(
        (rng.random((P_, part, F)) < 0.5).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, B, K)).astype(np.int32))
    mk = jnp.asarray(rng.random((P_, B, K)) < 0.8)
    for impl in ("xla", "pallas"):
        g = jax.grad(lambda f: jnp.sum(cgtrans.aggregate_sampled(
            f, nb, mk, mesh=None, op="or", impl=impl).astype(jnp.float32))
        )(feats01)
        np.testing.assert_array_equal(np.asarray(g), 0.0, err_msg=impl)


# ---------------------------------------------------------------------------
# 2. property tests: scan VJP exactness; kernel VJP vs the jnp oracle
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    chunk=st.integers(1, 40),
    r=st.integers(1, 13),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_scan_request_chunks_vjp_exact(chunk, r, k, seed):
    """The VJP of the chunked request stream is BIT-EXACT with the unchunked
    body call. Integer-valued float data keeps every partial sum exactly
    representable, so the assertion is order-independent and any chunk-
    boundary contribution that is dropped, duplicated, or routed to the
    wrong row is a hard bitwise failure — not tolerance noise."""
    rng = np.random.default_rng(seed)
    n_rows, F = 11, 3
    table = jnp.asarray(rng.integers(-8, 9, (n_rows, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, n_rows, (r, k)).astype(np.int32))
    mk = jnp.asarray(rng.random((r, k)) < 0.7)
    u = jnp.asarray(rng.integers(-4, 5, (r, F)).astype(np.float32))

    def body(t, nb_c, m_c):
        rows = jnp.take(t, nb_c.reshape(-1), axis=0).reshape(
            nb_c.shape[0], -1, F)
        return (rows * m_c[..., None]).sum(1)

    def loss(t, chunked):
        if chunked:
            out = cgtrans.scan_request_chunks(
                lambda nb_c, m_c: body(t, nb_c, m_c), nb, mk, chunk)
        else:
            out = body(t, nb, mk)
        return jnp.sum(out * u)

    g_chunked = jax.grad(lambda t: loss(t, True))(table)
    g_full = jax.grad(lambda t: loss(t, False))(table)
    np.testing.assert_array_equal(np.asarray(g_chunked), np.asarray(g_full))


@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(1, 200),
    r=st.integers(1, 40),
    op=st.sampled_from(("add", "max", "min", "or")),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_scatter_weighted_vjp_matches_oracle(e, r, op, seed):
    """The pallas custom VJP of ``gas_scatter_weighted`` ≡ ``jax.grad`` of
    the jnp oracle for random masks/weights on all four ops — including
    duplicated values (max/min gradient ties must split exactly like XLA's
    even-among-ties convention) and for "or" the oracle's exact zeros."""
    rng = np.random.default_rng(seed)
    F = 4
    dst = jnp.asarray(rng.integers(0, r, e).astype(np.int32))
    if op == "or":
        vals = jnp.asarray((rng.random((e, F)) < 0.5).astype(np.float32))
    else:
        vals = jnp.asarray(rng.integers(-5, 6, (e, F)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(e).astype(np.float32))
    m = jnp.asarray(rng.random(e) < 0.7)
    u = jnp.asarray(rng.standard_normal((r, F)).astype(np.float32))

    def loss(v, wts, impl):
        out = gas.gas_scatter_weighted(dst, v, wts, m, r, op=op, impl=impl)
        return _masked_linear_loss(out.astype(jnp.float32), u)

    gx = jax.grad(lambda v, wts: loss(v, wts, "xla"), argnums=(0, 1))(vals, w)
    gp = jax.grad(lambda v, wts: loss(v, wts, "pallas"), argnums=(0, 1))(vals, w)
    _grad_close(gp[0], gx[0])
    _grad_close(gp[1], gx[1])


def test_backward_scatter_routes_through_kernel(rng, monkeypatch):
    """The acceptance bar: the backward really dispatches the FAST-GAS
    kernel — not a silent XLA fallback. Count kernel-wrapper invocations
    (both the plain and the fused dispatch — the gather VJP and the
    tie-count router now use the fused entry) around ``jax.vjp``: the
    pallas gather's forward is a plain take (zero kernel calls) but pulling
    its cotangent MUST hit the kernel (the backward of a gather is a
    scatter), and the max-scatter's backward must hit it again for the
    tie-count router."""
    from repro.kernels.gas_scatter import ops as gas_ops

    count = {"n": 0}
    real_plain = gas_ops.gas_scatter
    real_fused = gas_ops.gas_scatter_fused

    def counting_plain(*args, **kwargs):
        count["n"] += 1
        return real_plain(*args, **kwargs)

    def counting_fused(*args, **kwargs):
        count["n"] += 1
        return real_fused(*args, **kwargs)

    monkeypatch.setattr(gas_ops, "gas_scatter", counting_plain)
    monkeypatch.setattr(gas_ops, "gas_scatter_fused", counting_fused)

    table = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 16, 23).astype(np.int32))
    out, pull = jax.vjp(lambda t: gas.gas_gather(t, ids, impl="pallas"), table)
    fwd_calls = count["n"]
    assert fwd_calls == 0, "the pallas gather forward is a plain take"
    pull(jnp.ones_like(out))
    assert count["n"] > fwd_calls, (
        "gather cotangent did not dispatch the FAST-GAS kernel")

    dst = jnp.asarray(rng.integers(0, 8, 23).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((23, 4)).astype(np.float32))
    w = jnp.ones((23,), jnp.float32)
    m = jnp.ones((23,), bool)
    out, pull = jax.vjp(
        lambda v: gas.gas_scatter_weighted(dst, v, w, m, 8, op="max",
                                           impl="pallas"), vals)
    before = count["n"]
    pull(jnp.ones_like(out))
    assert count["n"] > before, (
        "max-op tie-count router did not dispatch the FAST-GAS kernel")


# ---------------------------------------------------------------------------
# 3. NaN regression: the all-masked-seed gradient
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", GRAD_OPS)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_all_masked_seed_grad_finite_and_zero(rng, op, impl):
    """Seeds with zero valid samples used to hold ±inf for max/min; an
    unmasked downstream consumer then produced 0·inf = NaN gradients. The
    terminal finalize now masks identity rows, so the forward reads 0 and
    the grad is exactly zero — no NaN on either backend, no downstream
    ``isfinite`` guard required."""
    P_, part, F, B, K = 2, 16, 4, 5, 3
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, B, K)).astype(np.int32))
    mk = jnp.zeros((P_, B, K), bool)                  # every seed all-masked

    def loss(f):
        out = cgtrans.aggregate_sampled(f, nb, mk, mesh=None, op=op,
                                        impl=impl)
        return jnp.sum(out ** 2)                      # deliberately unmasked

    val, g = jax.value_and_grad(loss)(feats)
    assert np.isfinite(float(val)), (op, impl, float(val))
    assert bool(jnp.isfinite(g).all()), (op, impl)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


@pytest.mark.parametrize("op", ["max", "min"])
def test_partially_masked_seed_grad_unaffected_by_identity_rows(rng, op):
    """Masking the identity rows must not disturb live seeds' grads: a mixed
    batch (one all-masked seed among live ones) grads identically to the
    same batch with the dead seed's rows simply absent from the loss."""
    P_, part, F, K = 1, 16, 4, 3
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, part, (P_, 3, K)).astype(np.int32))
    mk = np.ones((P_, 3, K), bool)
    mk[0, 1] = False                                  # dead seed in the middle
    mk = jnp.asarray(mk)
    u = jnp.asarray(rng.standard_normal((P_, 3, F)).astype(np.float32))
    live = jnp.asarray(np.array([1.0, 0.0, 1.0], np.float32))[None, :, None]

    for impl in ("xla", "pallas"):
        g_mixed = jax.grad(lambda f: jnp.sum(cgtrans.aggregate_sampled(
            f, nb, mk, mesh=None, op=op, impl=impl) * u))(feats)
        g_live = jax.grad(lambda f: jnp.sum(cgtrans.aggregate_sampled(
            f, nb, mk, mesh=None, op=op, impl=impl) * u * live))(feats)
        _grad_close(g_mixed, g_live)


# ---------------------------------------------------------------------------
# 4a. end-to-end: 3 pallas train steps ≡ 3 xla train steps (fp32 tolerance)
# ---------------------------------------------------------------------------

def test_sage_train_step_pallas_three_steps():
    """``make_sage_train_step(cfg.impl="pallas")`` is legal (the assertion is
    gone), the loss decreases over 3 steps, and every step's params match
    ``impl="xla"`` to fp32 tolerance — same data, same init."""
    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema
    from repro.data import GraphBatchStream, synthetic_node_labels
    from repro.graph import partition_by_src, uniform_graph
    from repro.optim import adamw_init
    from repro.train import make_sage_train_step

    g = uniform_graph(64, 512, seed=0, n_features=8)
    labels = synthetic_node_labels(g.features, 4)
    pg = partition_by_src(g, 2)
    feats = jnp.asarray(pg.features)
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=3,
                     weight_decay=0.0)
    stream = GraphBatchStream(g, labels, n_parts=2, batch_per_part=8,
                              k1=3, k2=3)
    # one repeated batch: descent on it is guaranteed, so "loss decreases"
    # tests the gradient's sign, not the sampling noise
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    batches = [batch] * 3

    trajectories = {}
    for impl in ("xla", "pallas"):
        cfg = GCNConfig(n_features=8, hidden=16, n_classes=4, fanout=3,
                        impl=impl)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw_init(params, tc),
                 "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_sage_train_step(cfg, tc, feats=feats, mesh=None))
        losses, snaps = [], []
        for b in batches:
            state, m = step(state, b)
            losses.append(float(m["total_loss"]))
            snaps.append(jax.tree.map(np.asarray, state["params"]))
        trajectories[impl] = (losses, snaps)

    xl, xs = trajectories["xla"]
    pl_, ps = trajectories["pallas"]
    assert pl_[-1] < pl_[0], f"pallas loss did not decrease: {pl_}"
    for i in range(3):
        np.testing.assert_allclose(pl_[i], xl[i], atol=1e-4, rtol=1e-4)
        flat_x = jax.tree.leaves(xs[i])
        flat_p = jax.tree.leaves(ps[i])
        for ax, ap in zip(flat_x, flat_p):
            np.testing.assert_allclose(ap, ax, atol=1e-5, rtol=1e-5,
                                       err_msg=f"params diverged at step {i}")


# ---------------------------------------------------------------------------
# 4b. the on-mesh grad matrix: every cell of the shared 8-way subprocess run
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("op", GRAD_OPS)
@pytest.mark.parametrize("path", ["edges", "sampled"])
def test_mesh_grad_parity_cell(grad_parity_report, path, op, flow):
    line = f"grad path={path} flow={flow} op={op} impl=pallas ok"
    assert line in grad_parity_report, (
        f"missing/failed grad matrix cell: {line!r}")


@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("chunk", [1, 3, 64])
def test_mesh_grad_parity_chunked(grad_parity_report, flow, chunk):
    line = f"grad path=sampled flow={flow} chunk={chunk} ok"
    assert line in grad_parity_report, (
        f"missing/failed chunked grad cell: {line!r}")


@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("op", ["add", "max"])
def test_mesh_grad_parity_scheduled_off(grad_parity_report, op, flow):
    """pallas grads default to the scheduled path on the mesh — these cells
    pin the scheduled=off (dense-occupancy) backward as its own axis."""
    line = f"grad path=edges flow={flow} op={op} impl=pallas sched=off ok"
    assert line in grad_parity_report, (
        f"missing/failed scheduled-off grad cell: {line!r}")


@pytest.mark.distributed
def test_mesh_grad_hoisted_schedule(grad_parity_report):
    """The hoisted deployment's backward on the real mesh: d_feats matches
    the unpermuted reference, d_weights un-permutes per shard."""
    assert "grad path=edges hoisted-schedule ok" in grad_parity_report


@pytest.mark.distributed
def test_mesh_pallas_train_parity(grad_parity_report):
    assert "train pallas-vs-xla 3-step parity ok" in grad_parity_report
