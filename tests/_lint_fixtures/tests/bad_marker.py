"""Planted unknown-marker violation (lint fixture — parsed, never
imported/collected): ``bogus_tier`` is not registered in pyproject."""

import pytest


@pytest.mark.bogus_tier
def check_nothing():
    assert True
