"""Planted f64-literal violations (lint fixture — parsed, never imported)."""

import numpy as np

ACC_DTYPE = np.float64


def promote(x):
    return x.astype("float64")
