"""A BARE ``lint: allow`` with no justification (lint fixture — parsed,
never imported): the suppression must NOT take effect."""

from jax.experimental import pallas  # noqa: F401  # lint: allow(compat-door)
