"""Planted compat-door violations (lint fixture — parsed, never imported)."""

from jax.experimental.shard_map import shard_map  # noqa: F401
from jax.sharding import AxisType  # noqa: F401


def build(mesh, fn):
    import jax

    return jax.shard_map(fn, mesh=mesh)
