"""A JUSTIFIED inline suppression (lint fixture — parsed, never imported):
this file must lint clean, demonstrating the escape hatch works."""

from jax.experimental import pallas  # noqa: F401  # lint: allow(compat-door): fixture — the justified-suppression escape hatch under test
