"""Planted collective-site violation (lint fixture — parsed, never
imported): a psum in a src/repro module outside the contract-covered
allowlist is uncounted cross-shard traffic."""

from jax import lax


def leak(x):
    return lax.psum(x, "data")
