"""Planted dispatch-coverage violations (lint fixture — parsed, never
imported): a PUBLIC function reaching a raw kernel entry with no
count_dispatches tick, and a pallas_call site outside the kernel modules."""

from repro.kernels.gas_scatter import kernel as K


def scatter_rows(dst, vals, n):
    return K.gas_scatter_pallas(dst, vals, n, op="add")


def call_kernel(pl, body, out_shape):
    return pl.pallas_call(body, out_shape=out_shape)
