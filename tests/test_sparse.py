"""Compressed-sparse features (``repro.core.sparse``): codec properties on
the host, the kernel-level feature-block skip, and the parity matrix on the
real 8-way mesh.

Layer 1 (runs everywhere, 1 device): the codec is a PURE transform, so its
contracts are property-testable without a mesh — encode/decode round-trips
bit-for-bit at any density (all-zero rows and density 1.0 included) while
the row fits the capacity, the bitmap popcount equals the packed length
the decode consumes, the ``sparse_fits`` gate falls back to the unchanged
dense path, and the fused kernel's feature-block skip is bit-exact while
executing strictly fewer rounds on zero-heavy values.

Layer 2 (``@pytest.mark.distributed``): one subprocess run of
``distributed_cases.case_sparse_parity`` on 8 fake devices; each test here
asserts one printed cell — same pattern as the pallas/wire/partition tiers.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _propcheck import given, settings, strategies as st
from repro.core import cgtrans, sparse
from repro.kernels.gas_scatter import kernel as K
from repro.kernels.gas_scatter import ops

pytestmark = pytest.mark.sparse


# ---------------------------------------------------------------------------
# 1. codec properties (host-level, no mesh)
# ---------------------------------------------------------------------------

def test_validate_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown features mode"):
        sparse.validate_features("blocky")
    for m in sparse.FEATURE_MODES:
        assert sparse.validate_features(m) == m


def test_alignment_mirrors_the_kernel_tile():
    """The packed width aligns to the SAME tile the fused kernel blocks
    features by — asserted so the two constants can never drift apart."""
    assert sparse.FEAT_ALIGN == K.FEAT_BLOCK


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=48),
       st.integers(0, 10**6), st.integers(0, 10))
def test_roundtrip_exact_at_measured_capacity(vals, seed, tenths):
    """encode→decode is bit-for-bit at ANY density — the rng thins the row
    to ``tenths/10`` density (0 = all-zero rows, 10 = fully dense) and the
    capacity is the measured ``table_capacity``, the entrypoints' choice."""
    rng = np.random.default_rng(seed)
    x = np.asarray(vals, np.float32)[None]
    x = np.where(rng.random(x.shape) < tenths / 10.0, x, 0.0)
    cap = sparse.table_capacity(x)
    packed, bitmap = sparse.encode_rows(jnp.asarray(x), cap)
    out = sparse.decode_rows(packed, bitmap, x.shape[-1])
    np.testing.assert_array_equal(np.asarray(out), x)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=48),
       st.integers(0, 10**6))
def test_popcount_equals_packed_length(vals, seed):
    """bitmap popcount ≡ the row's nonzero count ≡ the number of packed
    entries the decode consumes — the codec's internal consistency claim."""
    rng = np.random.default_rng(seed)
    x = np.asarray(vals, np.float32)[None]
    x = np.where(rng.random(x.shape) < 0.3, x, 0.0)
    cap = sparse.table_capacity(x)
    packed, bitmap = sparse.encode_rows(jnp.asarray(x), cap)
    nnz = int((x != 0).sum())
    assert int(sparse.popcount(bitmap)[0]) == nnz
    # the packed row holds exactly nnz leading values (zeros after)
    p = np.asarray(packed)[0]
    assert (p[nnz:] == 0).all()


def test_roundtrip_exact_at_density_one():
    x = np.arange(1, 257, dtype=np.float32).reshape(2, 128)
    cap = sparse.table_capacity(x)
    assert cap == 128 and not sparse.sparse_fits(cap, 128)
    packed, bitmap = sparse.encode_rows(jnp.asarray(x), cap)
    np.testing.assert_array_equal(
        np.asarray(sparse.decode_rows(packed, bitmap, 128)), x)


def test_encode_truncates_beyond_capacity_positionally():
    """Over-capacity rows lose their TRAILING nonzeros — the failure mode
    the static gate exists to rule out, pinned so it stays predictable."""
    x = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
    packed, bitmap = sparse.encode_rows(x, 2)
    np.testing.assert_array_equal(np.asarray(packed), [[1.0, 2.0]])
    out = np.asarray(sparse.decode_rows(packed, bitmap, 4))
    np.testing.assert_array_equal(out, [[1.0, 2.0, 0.0, 0.0]])


def test_fit_gate_boundary():
    """capacity + bitmap words must be strictly under F to win."""
    F = 64                      # 2 bitmap words
    assert sparse.sparse_fits(56, F)          # 56 + 2 < 64
    assert not sparse.sparse_fits(62, F)      # 62 + 2 = 64
    assert not sparse.sparse_fits(F, F)


def test_capacity_helpers_align_and_cap():
    assert sparse.bitmap_words(64) == 2 and sparse.bitmap_words(65) == 3
    assert sparse.worst_case_capacity(512, 0.1) == 128   # FEAT_ALIGN granule
    assert sparse.worst_case_capacity(512, 1.0) == 512
    assert sparse.worst_case_capacity(40, 0.1) == 8      # NARROW_ALIGN
    x = np.zeros((4, 256), np.float32)
    x[0, :5] = 1.0
    assert sparse.table_capacity(x) == 128               # 5 → one tile
    assert sparse.table_capacity(np.zeros((2, 16))) == 8  # all-zero: min align


def test_density_stats_measures():
    s = sparse.density_stats(np.asarray([[1.0, 0.0, 0.0, 2.0]]))
    assert s == {"nnz": 2, "total": 4, "density": 0.5}


# ---------------------------------------------------------------------------
# 2. entrypoint plumbing (host-level, unsharded)
# ---------------------------------------------------------------------------

def _tiny_sampled(features, capacity, dataflow="cgtrans", impl="xla"):
    rng = np.random.default_rng(0)
    f = np.round(rng.standard_normal((1, 16, 8)) * 5.0).astype(np.float32)
    f[rng.random(f.shape) > 0.3] = 0.0
    feats = jnp.asarray(f)
    nbrs = jnp.asarray(rng.integers(0, 16, (1, 4, 3)).astype(np.int32))
    mask = jnp.ones((1, 4, 3), bool)
    return cgtrans.aggregate_sampled(feats, nbrs, mask, mesh=None,
                                     dataflow=dataflow, impl=impl,
                                     features=features,
                                     sparse_capacity=capacity)


def test_entrypoints_reject_unknown_features():
    with pytest.raises(ValueError, match="unknown features mode"):
        _tiny_sampled("blocky", None)


def test_sparse_requires_a_capacity():
    with pytest.raises(ValueError, match="table_capacity"):
        _tiny_sampled("sparse", None)
    with pytest.raises(ValueError, match="capacity"):
        _tiny_sampled("sparse", 0)


def test_dense_rejects_a_stray_capacity():
    with pytest.raises(ValueError, match="only applies"):
        _tiny_sampled("dense", 4)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_unsharded_sparse_equals_dense_bitexact(impl):
    ref = np.asarray(_tiny_sampled("dense", None, impl=impl))
    out = np.asarray(_tiny_sampled("sparse", 4, impl=impl))
    np.testing.assert_array_equal(out, ref)


def test_gate_fallback_ships_dense_unchanged():
    """A capacity that can't beat dense (capacity + bitmap ≥ F) must take
    the EXACT dense path — same jaxpr-level computation, not a sparse
    round-trip that happens to agree."""
    ref = np.asarray(_tiny_sampled("dense", None))
    out = np.asarray(_tiny_sampled("sparse", 8))     # 8 + 1 ≥ 8 → fallback
    np.testing.assert_array_equal(out, ref)
    assert cgtrans._resolve_sparse("sparse", 8, 8) is None
    assert cgtrans._resolve_sparse("sparse", 4, 8) == 4


def test_unsharded_sparse_grads_equal_dense():
    rng = np.random.default_rng(1)
    f = np.round(rng.standard_normal((1, 16, 8)) * 5.0).astype(np.float32)
    f[rng.random(f.shape) > 0.3] = 0.0
    feats = jnp.asarray(f)
    nbrs = jnp.asarray(rng.integers(0, 16, (1, 4, 4)).astype(np.int32))
    mask = jnp.ones((1, 4, 4), bool)
    u = jnp.asarray(rng.integers(-4, 5, (1, 4, 8)).astype(np.float32))

    def loss(x, impl, features, cap):
        out = cgtrans.aggregate_sampled(x, nbrs, mask, mesh=None, impl=impl,
                                        features=features,
                                        sparse_capacity=cap)
        return jnp.sum(out * u)

    for impl in ("xla", "pallas"):
        gs = jax.grad(loss)(feats, impl, "sparse", 4)
        gd = jax.grad(loss)(feats, impl, "dense", None)
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(gd),
                                      err_msg=impl)


# ---------------------------------------------------------------------------
# 3. the kernel feature-block skip
# ---------------------------------------------------------------------------

def _zero_heavy_stream(seed=3):
    """A binned edge stream whose first half has all-zero values — in
    interpret mode the feature block spans the full padded width, so whole
    TILES must be value-dead for the skip to fire."""
    rng = np.random.default_rng(seed)
    E, F, R = 512, 24, 96
    dst = rng.integers(0, R, E).astype(np.int32)
    order = np.argsort(dst // K.ROW_BLOCK, kind="stable")
    vals = np.round(rng.standard_normal((E, F)) * 4.0).astype(np.float32)
    d, v = dst[order], vals[order].copy()
    v[: E // 2] = 0.0
    sched = ops.schedule_edges(jnp.asarray(d), None, R, assume_sorted=True)
    return jnp.asarray(d), jnp.asarray(v), R, sched


def test_feat_skip_stats_counts_fewer_rounds():
    _, v, _, sched = _zero_heavy_stream()
    live, band = ops.feat_skip_stats(sched, v)
    assert 0 < live < band, (live, band)
    # dense values: every banded round stays live
    live_d, band_d = ops.feat_skip_stats(sched, jnp.ones_like(v))
    assert live_d == band_d


def test_feat_skip_dispatch_is_bitexact():
    d, v, R, sched = _zero_heavy_stream()
    out = ops.gas_scatter_fused(d, v, None, None, R, op="add",
                                schedule=sched)
    ref = ops.gas_scatter_ref(d, v, R, op="add")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_feat_skip_work_rows_widen_only_for_add():
    """The work list carries the liveness columns exactly when the op can
    skip (add — zero is its identity); cmp ops keep the 4-wide rows."""
    d, v, R, sched = _zero_heavy_stream()
    assert sched.work.shape[1] == 4
    fill = jnp.where(v == 0, -jnp.inf, v)
    out = ops.gas_scatter_fused(d, fill, None, None, R, op="max",
                                schedule=sched)
    ref = ops.gas_scatter_ref(d, fill, R, op="max")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# 4. the on-mesh matrix: every cell of the shared 8-way subprocess run
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_sparse_sampled_bitexact(sparse_parity_report, flow, op, impl):
    line = f"sparse path=sampled flow={flow} op={op} impl={impl} exact ok"
    assert line in sparse_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_mesh_sparse_edges_bitexact(sparse_parity_report, flow, op):
    line = f"sparse path=edges flow={flow} op={op} exact ok"
    assert line in sparse_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_sparse_multi_bitexact(sparse_parity_report, flow, impl):
    line = f"sparse path=multi flow={flow} impl={impl} exact ok"
    assert line in sparse_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_sparse_grads_bitexact(sparse_parity_report, flow, impl):
    """The headline: the sparse gather's custom VJP and the sparse-shipment
    VJP reproduce the dense gradients bit for bit on integer data."""
    line = f"sparse grad path=sampled flow={flow} impl={impl} exact ok"
    assert line in sparse_parity_report, f"missing/failed cell: {line!r}"
    assert "sparse grad path=edges exact ok" in sparse_parity_report


@pytest.mark.distributed
def test_mesh_gate_fallback(sparse_parity_report):
    assert "sparse gate-fallback dense ok" in sparse_parity_report


@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
def test_mesh_sparse_composes_with_bf16_wire(sparse_parity_report, flow):
    line = f"sparse wire=bf16 flow={flow} exact ok"
    assert line in sparse_parity_report, f"missing/failed cell: {line!r}"


@pytest.mark.distributed
def test_mesh_sparse_changes_bytes_never_counts(sparse_parity_report):
    assert "sparse collective counts ok" in sparse_parity_report


@pytest.mark.distributed
def test_mesh_serving_on_sparse_features(sparse_parity_report):
    assert "sparse serving exact ok" in sparse_parity_report
