"""The partitioning tier: islandized locality ≡ interval, counted and exact.

Three layers:

* host-side invariants — ``islandize``'s relabeling is a permutation whose
  island packing aligns with ``partition_by_src``'s interval cut, the
  vectorized partitioner matches a loop reference on arbitrary graphs (and
  its degenerate shapes are pinned), and the synthetic generators honor
  their contracts (ids in range, determinism, ``p_intra``, remainder
  clusters carrying real mass);
* single-process parity — islandized ≡ interval bit-exact through
  ``gcn_forward_full`` (values AND grads on integer data), ``sage_forward``,
  and the ``ServingEngine`` with the hot cache on;
* the 8-way subprocess matrix (``distributed_cases.case_islandized_parity``
  via the ``island_parity_report`` session fixture) — the same claims on a
  real sharded mesh across dataflow × impl × op, plus the counted locality
  reductions (remote destination rows, dense occupancy rounds).
"""

import dataclasses

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.graph import (COOGraph, clustered_graph, interval_size, islandize,
                         partition_by_src, partition_graph, relabel_graph,
                         remote_destination_rows, rmat, uniform_graph)

pytestmark = pytest.mark.partition


def _shuffled_clustered(V, E, *, n_clusters, p_intra, seed, **kw):
    """A community graph whose vertex ids are scrambled — the adversarial
    case where the contiguous-interval split gets zero locality."""
    g = clustered_graph(V, E, n_clusters=n_clusters, p_intra=p_intra,
                        seed=seed, **kw)
    perm = np.random.default_rng(seed + 1000).permutation(V).astype(np.int32)
    feats = None if g.features is None else g.features[np.argsort(perm)]
    return COOGraph(V, perm[g.src], perm[g.dst], g.weights, feats)


# ---------------------------------------------------------------------------
# vectorized partition_by_src: loop-reference parity + degenerate shapes
# ---------------------------------------------------------------------------

def _partition_loop_reference(g, n_parts, pad_multiple=8):
    """The pre-vectorization per-partition fill loop, kept as the oracle."""
    V = g.n_vertices
    part = interval_size(V, n_parts, pad_multiple=pad_multiple)
    owner = g.src // part
    order = np.argsort(owner, kind="stable")
    src, dst = g.src[order], g.dst[order]
    w = g.weights[order] if g.weights is not None else np.ones_like(src, np.float32)
    counts = np.bincount(owner, minlength=n_parts)
    e_max = max(int(counts.max()), 1)
    e_max = -(-e_max // pad_multiple) * pad_multiple
    ps = np.zeros((n_parts, e_max), np.int32)
    pd = np.zeros((n_parts, e_max), np.int32)
    pw = np.zeros((n_parts, e_max), np.float32)
    pm = np.zeros((n_parts, e_max), bool)
    off = 0
    for p in range(n_parts):
        c = int(counts[p])
        ps[p, :c] = src[off:off + c] - p * part
        pd[p, :c] = dst[off:off + c]
        pw[p, :c] = w[off:off + c]
        pm[p, :c] = True
        off += c
    feats = None
    if g.features is not None:
        F = g.features.shape[1]
        feats = np.zeros((n_parts, part, F), g.features.dtype)
        for p in range(n_parts):
            lo, hi = p * part, min((p + 1) * part, V)
            if lo < V:
                feats[p, : hi - lo] = g.features[lo:hi]
    return ps, pd, pw, pm, feats


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 80), m=st.integers(0, 400),
       p=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 1000))
def test_vectorized_partition_matches_loop_reference(n, m, p, seed):
    g = uniform_graph(n, m, seed=seed, weights=True, n_features=3)
    pg = partition_by_src(g, p)
    ps, pd, pw, pm, feats = _partition_loop_reference(g, p)
    np.testing.assert_array_equal(pg.src, ps)
    np.testing.assert_array_equal(pg.dst, pd)
    np.testing.assert_array_equal(pg.weights, pw)
    np.testing.assert_array_equal(pg.mask, pm)
    np.testing.assert_array_equal(pg.features, feats)


def test_partition_more_parts_than_vertices():
    """V=5 over 8 parts: the padded interval is 8 wide, so shard 0 owns
    everything and shards 1..7 are empty tails — valid shapes, no edges,
    zero features."""
    g = uniform_graph(5, 40, seed=0, n_features=2)
    pg = partition_by_src(g, 8)
    assert pg.part_size == 8
    assert int(pg.mask[0].sum()) == 40
    assert not pg.mask[1:].any()
    np.testing.assert_array_equal(pg.features[0, :5], g.features)
    assert not pg.features[1:].any() and not pg.features[0, 5:].any()


def test_partition_empty_graph_with_empty_vertex_set():
    """V=0 (a shard pool before any table is loaded) partitions to fully
    padded, fully masked arrays instead of a divide-by-zero."""
    g = COOGraph(0, np.zeros(0, np.int32), np.zeros(0, np.int32))
    pg = partition_by_src(g, 4)
    assert pg.src.shape[0] == 4 and pg.e_max >= 1
    assert not pg.mask.any()


def test_partition_pad_inflation_leaves_trailing_owners_vertexless():
    """V=10 over 4 parts pads the interval to 8, so owners 2 and 3 exist in
    the shard grid but own no vertices: no edges, all-zero feature rows, and
    the first two shards carry the whole graph."""
    g = uniform_graph(10, 120, seed=1, n_features=3, weights=True)
    pg = partition_by_src(g, 4)
    assert pg.part_size == 8
    assert not pg.mask[2:].any()
    assert int(pg.mask.sum()) == 120
    assert not pg.features[2:].any()
    flat = pg.features.reshape(-1, 3)
    np.testing.assert_array_equal(flat[:10], g.features)
    # owner placement of every edge survives the pad inflation
    for p in range(2):
        m = pg.mask[p]
        glob = pg.src[p][m] + p * 8
        assert np.all(glob // 8 == p)


def test_interval_size_shared_helper():
    """``partition_by_src`` and ``islandize`` must cut at the same boundary;
    the shared helper is that contract."""
    for V, P, pad in [(100, 4, 8), (5, 8, 8), (256, 8, 1), (0, 2, 8)]:
        assert interval_size(V, P, pad_multiple=pad) >= 1
        g = uniform_graph(max(V, 1), 10, seed=0)
        if V:
            pg = partition_by_src(COOGraph(V, g.src % V, g.dst % V), P,
                                  pad_multiple=pad)
            assert pg.part_size == interval_size(V, P, pad_multiple=pad)
            isl = islandize(COOGraph(V, g.src % V, g.dst % V), P,
                            pad_multiple=pad)
            assert isl.part_size == pg.part_size


# ---------------------------------------------------------------------------
# islandize invariants
# ---------------------------------------------------------------------------

def test_islandize_relabel_is_permutation():
    g = _shuffled_clustered(200, 1600, n_clusters=8, p_intra=0.9, seed=2)
    isl = islandize(g, 4)
    np.testing.assert_array_equal(np.sort(isl.relabel), np.arange(200))
    np.testing.assert_array_equal(isl.relabel[isl.inverse], np.arange(200))
    np.testing.assert_array_equal(isl.inverse[isl.relabel], np.arange(200))
    assert isl.n_islands >= 1
    assert isl.island_of.min() >= 0 and isl.island_of.max() < isl.n_islands


def test_islandize_deterministic():
    g = _shuffled_clustered(150, 900, n_clusters=6, p_intra=0.85, seed=7)
    a, b = islandize(g, 4), islandize(g, 4)
    np.testing.assert_array_equal(a.relabel, b.relabel)


def test_islandize_capacity_and_interval_alignment():
    """No island exceeds one interval, and the packing fills every shard
    interval before spilling into the next (the alignment contract with
    ``partition_by_src``): vertices land densely in ``[0, V)`` new-id order
    so shard p's interval holds whole islands or a single split slice."""
    V, P = 300, 4
    g = _shuffled_clustered(V, 2400, n_clusters=10, p_intra=0.9, seed=5)
    isl = islandize(g, P)
    sizes = np.bincount(isl.island_of, minlength=isl.n_islands)
    assert sizes.max() <= isl.part_size
    # dense packing: new ids are exactly [0, V) (no holes), so every shard
    # interval before the tail is full
    assert isl.relabel.min() == 0 and isl.relabel.max() == V - 1


def test_relabel_graph_preserves_structure():
    g = _shuffled_clustered(120, 800, n_clusters=6, p_intra=0.9, seed=9,
                            n_features=4, weights=True)
    isl = islandize(g, 4)
    rg = relabel_graph(g, isl)
    # same edges up to renaming, in the SAME stream order
    np.testing.assert_array_equal(rg.src, isl.relabel[g.src])
    np.testing.assert_array_equal(rg.dst, isl.relabel[g.dst])
    np.testing.assert_array_equal(rg.weights, g.weights)
    # feature rows follow their vertex
    np.testing.assert_array_equal(rg.features[isl.relabel], g.features)
    # round-trip helpers
    np.testing.assert_array_equal(isl.unrelabel_rows(rg.features), g.features)
    np.testing.assert_array_equal(isl.relabel_rows(g.features), rg.features)


def test_islandize_reduces_remote_rows_and_dense_rounds():
    """The counted locality claim, host-scale: on a shuffled-id clustered
    graph the islandized partition strictly shrinks both the per-shard
    remote destination rows (the all_to_all payload proxy) and the dense
    (row-block × edge-tile) occupancy the idle-skip kernel would walk."""
    import jax.numpy as jnp
    from repro.kernels.gas_scatter import ops as gas_ops

    # big enough that the row grid has several 128-row blocks per shard —
    # below ~4 blocks the dense occupancy saturates in both layouts and the
    # round counter cannot separate them
    g = _shuffled_clustered(1024, 8192, n_clusters=8, p_intra=0.95, seed=3)
    ways = 8
    pg_i, _ = partition_graph(g, ways, method="interval")
    pg_s, isl = partition_graph(g, ways, method="island")
    assert isl is not None and pg_i.part_size == pg_s.part_size

    rr_i, rr_s = remote_destination_rows(pg_i), remote_destination_rows(pg_s)
    assert int(rr_s.sum()) < int(rr_i.sum())
    assert int(rr_s.max()) < int(rr_i.max())

    def dense_live(pg):
        live = 0
        for p in range(pg.n_parts):
            l, _ = gas_ops.dense_skip_stats(jnp.asarray(pg.dst[p]),
                                            jnp.asarray(pg.mask[p]),
                                            pg.n_parts * pg.part_size)
            live += int(l)
        return live

    assert dense_live(pg_s) < dense_live(pg_i)


def test_partition_graph_unknown_method():
    g = uniform_graph(16, 32, seed=0)
    with pytest.raises(ValueError, match="unknown partition method"):
        partition_graph(g, 2, method="metis")


# ---------------------------------------------------------------------------
# synthetic generator invariants (satellites: ids, determinism, p_intra,
# remainder clusters, feature/weight round-trip)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda s: uniform_graph(97, 500, seed=s),
    lambda s: clustered_graph(97, 500, n_clusters=6, seed=s),   # 97 % 6 != 0
    lambda s: clustered_graph(5, 300, n_clusters=8, seed=s),    # C > V
    lambda s: rmat(6, 8, seed=s),
])
def test_generator_ids_in_range_and_deterministic(make):
    g1, g2, g3 = make(0), make(0), make(1)
    for g in (g1, g3):
        assert g.src.min() >= 0 and g.src.max() < g.n_vertices
        assert g.dst.min() >= 0 and g.dst.max() < g.n_vertices
    np.testing.assert_array_equal(g1.src, g2.src)
    np.testing.assert_array_equal(g1.dst, g2.dst)
    assert not (np.array_equal(g1.src, g3.src) and np.array_equal(g1.dst, g3.dst))


def test_clustered_p_intra_within_tolerance():
    """Fraction of intra-cluster edges ≈ p_intra + (1-p_intra)/C: the
    explicit p_intra draws plus the uniform re-draws that land home."""
    from repro.graph.synthetic import _cluster_bounds

    V, E, C = 120, 40000, 6
    for p_intra in (0.0, 0.5, 0.9):
        g = clustered_graph(V, E, n_clusters=C, p_intra=p_intra, seed=4)
        starts, sizes = _cluster_bounds(V, C)
        cluster = np.searchsorted(starts, np.arange(V), side="right") - 1
        frac = float((cluster[g.src] == cluster[g.dst]).mean())
        want = p_intra + (1.0 - p_intra) / C
        assert abs(frac - want) < 0.02, (p_intra, frac, want)


def test_clustered_remainder_degree_mass():
    """The regression the fix pins: remainder vertices (V % C != 0) carry
    real edge mass instead of silently dropping out, and C > V no longer
    piles every out-of-range cluster onto vertex V-1."""
    # V % C = 6: the old V//C grid made vertices 1024..1029 unreachable
    g = clustered_graph(1030, 16384, n_clusters=8, seed=0)
    deg = g.degree_out() + g.degree_in()
    assert deg[1024:].sum() > 0
    assert deg.max() < 4 * deg.mean()
    # C > V: the old clamp sent clusters 5..7 all to vertex 4
    g2 = clustered_graph(5, 2000, n_clusters=8, seed=0)
    deg2 = g2.degree_out() + g2.degree_in()
    assert deg2.max() < 2 * deg2.mean()
    assert deg2.min() > 0


def test_cluster_bounds_cover_all_vertices():
    from repro.graph.synthetic import _cluster_bounds

    for V, C in [(10, 3), (5, 8), (8, 8), (1, 1), (1030, 8)]:
        starts, sizes = _cluster_bounds(V, C)
        assert sizes.sum() == V
        assert sizes.max() - sizes.min() <= 1
        assert starts[0] == 0
        np.testing.assert_array_equal(starts[1:], np.cumsum(sizes)[:-1])


def test_features_weights_roundtrip_partition():
    g = clustered_graph(90, 700, n_clusters=5, p_intra=0.8, seed=6,
                        n_features=4, weights=True)
    pg = partition_by_src(g, 4)
    # weight multiset conserved (exact — weights are copied, never summed)
    assert sorted(pg.weights[pg.mask].tolist()) == sorted(g.weights.tolist())
    # features land on the owner shard, bit for bit
    flat = pg.features.reshape(-1, 4)
    np.testing.assert_array_equal(flat[:90], g.features)
    assert not flat[90:].any()


# ---------------------------------------------------------------------------
# single-process parity: islandized ≡ interval bit-exact
# ---------------------------------------------------------------------------

def _int_params(cfg, rng):
    import jax.numpy as jnp
    from repro.core.gcn import gcn_schema
    return {k: jnp.asarray(rng.integers(-2, 3, d.shape).astype(np.float32))
            for k, d in gcn_schema(cfg).items()}


def test_gcn_forward_full_island_parity_values_and_grads():
    """Full-graph islandized ≡ interval bit-exact on integer data — values
    and parameter gradients — after the output un-permute (row v of the
    flattened result is original vertex v in both layouts)."""
    import jax
    import jax.numpy as jnp
    from repro.core.gcn import GCNConfig, gcn_forward_full

    rng = np.random.default_rng(0)
    V, P, F, C = 96, 4, 6, 5
    g = _shuffled_clustered(V, 768, n_clusters=8, p_intra=0.9, seed=3)
    g.features = rng.integers(-3, 4, (V, F)).astype(np.float32)
    cfg_i = GCNConfig(n_features=F, hidden=8, n_classes=C, aggregate="add")
    cfg_s = dataclasses.replace(cfg_i, partition="island")
    pg_i, _ = partition_graph(g, P, method="interval")
    pg_s, isl = partition_graph(g, P, method="island")
    params = _int_params(cfg_i, rng)

    def run(p, pg, cfg, relabel):
        return gcn_forward_full(
            p, jnp.asarray(pg.features), jnp.asarray(pg.src),
            jnp.asarray(pg.dst), jnp.asarray(pg.weights),
            jnp.asarray(pg.mask), cfg, relabel=relabel)

    out_i = np.asarray(run(params, pg_i, cfg_i, None)).reshape(-1, C)
    out_s = np.asarray(run(params, pg_s, cfg_s, isl.relabel)).reshape(-1, C)
    np.testing.assert_array_equal(out_i[:V], out_s[:V])

    def loss(p, pg, cfg, relabel):
        return run(p, pg, cfg, relabel).reshape(-1, C)[:V].sum()

    g_i = jax.grad(loss)(params, pg_i, cfg_i, None)
    g_s = jax.grad(loss)(params, pg_s, cfg_s, isl.relabel)
    for k in params:
        np.testing.assert_array_equal(np.asarray(g_i[k]), np.asarray(g_s[k]))


def test_sage_forward_island_parity():
    """Sampled-path islandized ≡ interval bit-exact (identical rows fetched
    in identical order — holds even for float params)."""
    import jax.numpy as jnp
    from repro.core.gcn import GCNConfig, gcn_schema, sage_forward

    rng = np.random.default_rng(1)
    V, F, B, K1, K2 = 64, 5, 4, 3, 3
    g = _shuffled_clustered(V, 512, n_clusters=4, p_intra=0.9, seed=2)
    feats = rng.standard_normal((V, F)).astype(np.float32)
    g.features = feats
    cfg_i = GCNConfig(n_features=F, hidden=8, n_classes=4)
    cfg_s = dataclasses.replace(cfg_i, partition="island")
    isl = islandize(g, 1, pad_multiple=1)

    batch = {
        "seeds": jnp.asarray(rng.integers(0, V, (1, B)).astype(np.int32)),
        "nbrs1": jnp.asarray(rng.integers(0, V, (1, B, K1)).astype(np.int32)),
        "mask1": jnp.asarray(rng.random((1, B, K1)) < 0.8),
        "nbrs2": jnp.asarray(
            rng.integers(0, V, (1, B * (1 + K1), K2)).astype(np.int32)),
        "mask2": jnp.asarray(rng.random((1, B * (1 + K1), K2)) < 0.8),
    }
    params = {k: jnp.asarray(rng.standard_normal(d.shape).astype(np.float32))
              for k, d in gcn_schema(cfg_i).items()}
    t_i = jnp.asarray(feats).reshape(1, V, F)
    t_s = jnp.asarray(isl.relabel_rows(feats)).reshape(1, V, F)
    o_i = sage_forward(params, t_i, batch, cfg_i)
    o_s = sage_forward(params, t_s, batch, cfg_s, relabel=isl.relabel)
    np.testing.assert_array_equal(np.asarray(o_i), np.asarray(o_s))


def test_partition_knob_validation():
    """The knob and the relabel map travel together — a mismatch is a loud
    trace-time error, not a silent wrong-row aggregation."""
    import jax.numpy as jnp
    from repro.core.gcn import GCNConfig, sage_forward

    cfg_island = GCNConfig(n_features=2, hidden=4, n_classes=2,
                           partition="island")
    cfg_interval = GCNConfig(n_features=2, hidden=4, n_classes=2)
    cfg_bogus = GCNConfig(n_features=2, hidden=4, n_classes=2,
                          partition="hash")
    batch = {"seeds": jnp.zeros((1, 2), jnp.int32),
             "nbrs1": jnp.zeros((1, 2, 2), jnp.int32),
             "mask1": jnp.ones((1, 2, 2), bool),
             "nbrs2": jnp.zeros((1, 6, 2), jnp.int32),
             "mask2": jnp.ones((1, 6, 2), bool)}
    feats = jnp.zeros((1, 8, 2))
    with pytest.raises(ValueError, match="requires the IslandPartition"):
        sage_forward({}, feats, batch, cfg_island)
    with pytest.raises(ValueError, match="requires partition='island'"):
        sage_forward({}, feats, batch, cfg_interval,
                     relabel=np.arange(8, dtype=np.int32))
    with pytest.raises(ValueError, match="unknown cfg.partition"):
        sage_forward({}, feats, batch, cfg_bogus)


@pytest.mark.serving
def test_serving_engine_island_parity_with_cache():
    """Two engines over the same graph — interval vs island layout, hot
    cache ON — answer identical queries bit-exactly with identical cache
    behavior, and the island engine's caller API stays in original ids."""
    from repro.serving import ServingEngine

    rng = np.random.default_rng(2)
    V, F = 64, 5
    g = _shuffled_clustered(V, 512, n_clusters=4, p_intra=0.9, seed=8)
    feats = rng.standard_normal((V, F)).astype(np.float32)
    indptr, indices, _ = g.to_csr()
    kw = dict(fanout=4, max_batch=4, max_delay_s=1e9, cache_capacity=16)
    eng_i = ServingEngine(feats, indptr, indices, **kw)
    eng_s = ServingEngine(feats, indptr, indices, partition="island", **kw)
    assert eng_s.islands is not None

    seeds = [3, 9, 3, 17]
    for _wave in range(2):                     # wave 2 hits the cache
        rids = [(eng_i.submit([s]), eng_s.submit([s])) for s in seeds]
        eng_i.flush()
        eng_s.flush()
        for ri, rs in rids:
            a, b = eng_i.result(ri), eng_s.result(rs)
            np.testing.assert_array_equal(a.self_rows, b.self_rows)
            np.testing.assert_array_equal(a.agg_rows, b.agg_rows)
            np.testing.assert_array_equal(a.from_cache, b.from_cache)
    assert eng_i.cache.snapshot() == eng_s.cache.snapshot()
    # the cache is keyed on caller-visible ids in BOTH engines — the
    # islandized engine never leaks relabeled ids into the cache key space
    for s in set(seeds):
        assert s in eng_s.cache and s in eng_i.cache


def test_serving_engine_rejects_unknown_partition():
    from repro.serving import ServingEngine

    feats = np.zeros((8, 2), np.float32)
    indptr = np.zeros(9, np.int64)
    indices = np.zeros(0, np.int64)
    with pytest.raises(ValueError, match="unknown partition"):
        ServingEngine(feats, indptr, indices, partition="hash")


# ---------------------------------------------------------------------------
# the 8-way subprocess matrix (session fixture runs it once)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_islandized_parity_on_mesh(island_parity_report):
    assert "islandized parity ok" in island_parity_report


@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_islandized_value_cell(island_parity_report, flow, impl, op):
    assert (f"island parity path=edges flow={flow} op={op} impl={impl} ok"
            in island_parity_report)


@pytest.mark.distributed
@pytest.mark.parametrize("flow", ["cgtrans", "baseline"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("op", ["add", "max"])
def test_islandized_grad_cell(island_parity_report, flow, impl, op):
    assert (f"island parity grad flow={flow} op={op} impl={impl} ok"
            in island_parity_report)


@pytest.mark.distributed
def test_islandized_sage_and_serving_cells(island_parity_report):
    assert "island sage parity ok" in island_parity_report
    assert "island serving parity cache=on ok" in island_parity_report


@pytest.mark.distributed
def test_islandized_locality_counted_on_mesh(island_parity_report):
    """The subprocess case prints the counted reductions; both must be
    strict on the 8-way mesh."""
    assert "island locality remote_rows" in island_parity_report
    assert "island locality dense_rounds" in island_parity_report
