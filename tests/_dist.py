"""Shared runner for the multi-device subprocess cases.

A plain helper module (same pattern as ``_propcheck``) so both
``test_distributed.py`` and ``conftest.py`` can import it without relying on
``conftest`` being importable as a module (it is not under
``--import-mode=importlib``).
"""

import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)


def run_distributed_case(case: str, timeout: int = 480) -> str:
    """Run one tests/distributed_cases.py case in an 8-fake-device
    subprocess (the main pytest process stays on the 1-device topology) and
    return its stdout; pytest.fail with the child's output on any failure —
    an import/compat break in the subprocess must read as itself, not as
    ``assert 1 == 0`` around a CompletedProcess repr."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_HERE, "..", "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    cmd = [sys.executable, os.path.join(_HERE, "distributed_cases.py"), case]
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired as e:
        pytest.fail(
            f"case {case!r} timed out after {timeout}s\n"
            f"--- captured stdout ---\n{e.stdout or ''}\n"
            f"--- captured stderr ---\n{e.stderr or ''}",
            pytrace=False)
    if proc.returncode != 0:
        pytest.fail(
            f"case {case!r} exited {proc.returncode}\n"
            f"--- child stdout ---\n{proc.stdout}\n"
            f"--- child stderr ---\n{proc.stderr}",
            pytrace=False)
    return proc.stdout
