"""The bench-drift gate (``scripts/check_bench_drift.py``).

CI's bench-smoke lane regenerates the collective-bytes JSON on every push
and diffs the counter/ratio rows against the committed
``BENCH_collective_bytes.json``. These tests drive the script the way the
workflow does — as a subprocess, asserting on its exit code — so the gate
itself is covered: the committed file must agree with itself, and a
planted one-byte counter edit must fail the run and be named in the
report.
"""

import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
SCRIPT = REPO / "scripts" / "check_bench_drift.py"
COMMITTED = REPO / "BENCH_collective_bytes.json"
sys.path.insert(0, str(REPO / "scripts"))

pytestmark = pytest.mark.sparse


def run_drift(fresh, committed):
    return subprocess.run(
        [sys.executable, str(SCRIPT), str(fresh), str(committed)],
        capture_output=True, text=True)


def test_committed_file_agrees_with_itself():
    res = run_drift(COMMITTED, COMMITTED)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "No drift" in res.stdout


def test_planted_counter_edit_fails_and_is_named(tmp_path):
    doc = json.loads(COMMITTED.read_text())
    # plant a +1 on the first counted byte field of a non-timing row —
    # the smallest drift the gate must catch
    from check_bench_drift import TIMING_MODES  # scripts/ on sys.path above
    row = next(r for r in doc["rows"]
               if r["mode"] not in TIMING_MODES and "bytes" in r)
    row["bytes"] += 1
    edited = tmp_path / "edited.json"
    edited.write_text(json.dumps(doc))
    res = run_drift(edited, COMMITTED)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "DRIFT" in res.stdout
    # the report names the drifted field with both values
    assert "bytes" in res.stdout
    assert str(row["bytes"]) in res.stdout
    assert str(row["bytes"] - 1) in res.stdout


def test_timing_fields_never_drift(tmp_path):
    doc = json.loads(COMMITTED.read_text())
    for r in doc["rows"]:
        for k in ("us", "us_per_shard", "loss"):
            if k in r:
                r[k] = r[k] * 3 + 1
    edited = tmp_path / "timing.json"
    edited.write_text(json.dumps(doc))
    res = run_drift(edited, COMMITTED)
    assert res.returncode == 0, res.stdout + res.stderr


def test_missing_rows_are_informational(tmp_path):
    doc = json.loads(COMMITTED.read_text())
    doc["rows"] = doc["rows"][: len(doc["rows"]) // 2]
    subset = tmp_path / "subset.json"
    subset.write_text(json.dumps(doc))
    res = run_drift(subset, COMMITTED)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "only in committed file" in res.stdout


def test_usage_error_is_distinct():
    res = subprocess.run([sys.executable, str(SCRIPT)],
                         capture_output=True, text=True)
    assert res.returncode == 2
