"""Schema system + logical axis resolution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.logical import DEFAULT_RULES, to_physical
from repro.common.schema import (ParamDef, count_params, init_params,
                                 param_logical_specs, param_structs, stack)


def _schema():
    return {"a": {"w": ParamDef((8, 16), ("embed", "ff"), init="lecun")},
            "b": ParamDef((16,), ("ff",), init="zeros")}


def test_init_specs_structs_consistent():
    s = _schema()
    params = init_params(s, jax.random.PRNGKey(0))
    structs = param_structs(s)
    specs = param_logical_specs(s)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(structs)
    for p_, s_ in zip(flat_p, flat_s):
        assert p_.shape == s_.shape and p_.dtype == s_.dtype
    assert count_params(s) == 8 * 16 + 16
    assert jax.tree.structure(params) == jax.tree.structure(structs)


def test_stack_prepends_layer_dim():
    s = stack(_schema(), 5)
    structs = param_structs(s)
    assert structs["a"]["w"].shape == (5, 8, 16)
    specs = param_logical_specs(s)
    assert specs["a"]["w"][0] == "layers"


class _FakeMesh:
    def __init__(self, axes):
        self.axis_names = tuple(axes)


def test_logical_resolution_drops_missing_axes():
    mesh2 = _FakeMesh(("data", "model"))
    mesh3 = _FakeMesh(("pod", "data", "model"))
    assert to_physical(("batch", None), mesh2) == P("data", None)
    assert to_physical(("batch", None), mesh3) == P(("pod", "data"), None)
    # 2D FSDP×TP weight sharding: vocab→model, embed→data
    assert to_physical(("vocab", "embed"), mesh2) == P("model", "data")
    # on a model-only mesh the FSDP axis drops away
    assert to_physical(("vocab", "embed"), _FakeMesh(("model",))) == P("model", None)
    # unknown logical name → replicated
    assert to_physical(("nonexistent",), mesh2) == P(None)


def test_logical_double_use_guard():
    """A physical axis may appear at most once in a spec (GSPMD rule)."""
    mesh = _FakeMesh(("data", "model"))
    spec = to_physical(("batch", "seq_shard", None), mesh)
    # batch claims "data"; seq_shard would claim it again → dropped
    assert spec == P("data", None, None)


def test_custom_inits_are_finite_and_in_range():
    import math
    d = ParamDef((64,), ("lru",), init="custom", custom="rglru_lambda")
    lam = init_params({"x": d}, jax.random.PRNGKey(1))["x"]
    a = np.exp(-8.0 * np.log1p(np.exp(np.asarray(lam))))
    assert np.all(a > 0.8) and np.all(a < 1.0)

    d2 = ParamDef((32,), (None,), init="custom", custom="ssm_dt_bias")
    dtb = init_params({"x": d2}, jax.random.PRNGKey(2))["x"]
    dt = np.log1p(np.exp(np.asarray(dtb)))
    assert np.all(dt >= 5e-4) and np.all(dt <= 0.2)
