"""Multi-device test payloads, executed in SUBPROCESSES (each sets its own
fake-device count before importing jax — the main pytest process stays at the
real 1-device topology).

Run directly:  python tests/distributed_cases.py <case-name>
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402


def case_cgtrans_equivalence():
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.graph import partition_by_src, uniform_graph, host_sample
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    g = uniform_graph(256, 4096, seed=1, n_features=16, weights=True)
    pg = partition_by_src(g, 8)
    feats = jnp.asarray(pg.features)
    args = (feats, jnp.asarray(pg.src), jnp.asarray(pg.dst),
            jnp.asarray(pg.weights), jnp.asarray(pg.mask))
    ref = cgtrans.aggregate_edges(*args, mesh=None)
    for flow in ("cgtrans", "baseline"):
        out = jax.jit(lambda *a, f=flow: cgtrans.aggregate_edges(
            *a, mesh=mesh, dataflow=f))(*args)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-3, (flow, err)

    seeds = rng.integers(0, 256, 64).astype(np.int32)
    nbrs, mask = host_sample(g, seeds, 10, seed=2)
    nb = jnp.asarray(nbrs.reshape(8, 8, 10))
    mk = jnp.asarray(mask.reshape(8, 8, 10))
    ref_s = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None)
    for flow in ("cgtrans", "baseline"):
        out = jax.jit(lambda f, n, m, fl=flow: cgtrans.aggregate_sampled(
            f, n, m, mesh=mesh, dataflow=fl))(feats, nb, mk)
        err = float(jnp.max(jnp.abs(out - ref_s)))
        assert err < 1e-3, (flow, err)
    print("cgtrans equivalence ok")


def case_cgtrans_pallas_parity():
    """The full differential matrix on a REAL 8-way mesh: for every
    (dataflow, op, path), impl="pallas" ≡ impl="xla" ≡ the single-shard
    reference — with ragged (non-tile-aligned) per-shard edge counts, one
    all-padded shard (mask all-False), int features for op="or", and the
    chunked request stream checked against the unchunked one.

    Prints one ``parity path=… flow=… op=… impl=… ok`` line per cell;
    tests/test_cgtrans_pallas.py parses them into per-cell test results.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.graph import partition_by_src, uniform_graph, host_sample
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    # E=1000 over 8 src-partitions → ragged live counts, padded to a
    # non-tile-aligned per-shard E (not a multiple of any kernel tile)
    g = uniform_graph(256, 1000, seed=1, n_features=16, weights=True)
    pg = partition_by_src(g, 8)
    feats = jnp.asarray(pg.features)
    feats_int = (jnp.abs(feats) > 0.5).astype(jnp.int32)   # op="or" features
    mask = np.asarray(pg.mask).copy()
    mask[3] = False                                        # all-padded shard
    mask = jnp.asarray(mask)
    eargs = (jnp.asarray(pg.src), jnp.asarray(pg.dst), jnp.asarray(pg.weights),
             mask)

    def close(a, b, tag, tol=1e-3):
        a = jnp.nan_to_num(a.astype(jnp.float32), posinf=9e9, neginf=-9e9)
        b = jnp.nan_to_num(b.astype(jnp.float32), posinf=9e9, neginf=-9e9)
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < tol, (tag, err)

    for op in ("add", "max", "min", "or"):
        f = feats_int if op == "or" else feats
        ref = cgtrans.aggregate_edges(f, *eargs, mesh=None, op=op)
        for flow in ("cgtrans", "baseline"):
            for impl in ("xla", "pallas"):
                out = jax.jit(lambda ff, *a, fl=flow, i=impl, o=op:
                              cgtrans.aggregate_edges(
                                  ff, *a, mesh=mesh, dataflow=fl, op=o,
                                  impl=i))(f, *eargs)
                close(out, ref, ("edges", flow, op, impl))
                print(f"parity path=edges flow={flow} op={op} impl={impl} ok")

    seeds = rng.integers(0, 256, 64).astype(np.int32)
    nbrs, smask = host_sample(g, seeds, 10, seed=2)
    nb = jnp.asarray(nbrs.reshape(8, 8, 10))
    mk = np.asarray(smask.reshape(8, 8, 10)).copy()
    mk[5] = False                                          # all-padded shard
    mk = jnp.asarray(mk)
    for op in ("add", "max", "min", "or"):
        f = feats_int if op == "or" else feats
        ref = cgtrans.aggregate_sampled(f, nb, mk, mesh=None, op=op)
        for flow in ("cgtrans", "baseline"):
            for impl in ("xla", "pallas"):
                out = jax.jit(lambda ff, n_, m_, fl=flow, i=impl, o=op:
                              cgtrans.aggregate_sampled(
                                  ff, n_, m_, mesh=mesh, dataflow=fl, op=o,
                                  impl=i))(f, nb, mk)
                close(out, ref, ("sampled", flow, op, impl))
                print(f"parity path=sampled flow={flow} op={op} impl={impl} ok")

    # chunked request stream ≡ unchunked, on the mesh, both dataflows
    ref = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None)
    for flow in ("cgtrans", "baseline"):
        for chunk in (1, 3, 64):
            out = jax.jit(lambda ff, n_, m_, fl=flow, c=chunk:
                          cgtrans.aggregate_sampled(
                              ff, n_, m_, mesh=mesh, dataflow=fl,
                              request_chunk=c))(feats, nb, mk)
            close(out, ref, ("chunked", flow, chunk))
            print(f"parity path=sampled flow={flow} chunk={chunk} ok")

    # scheduled=off pallas cells: the impl=pallas cells above run the
    # destination-binned schedule (the mesh default); pin the unscheduled
    # dense-occupancy grid as its own matrix axis on a reduced op set
    for op in ("add", "max"):
        f = feats
        ref_e = cgtrans.aggregate_edges(f, *eargs, mesh=None, op=op)
        ref_s = cgtrans.aggregate_sampled(f, nb, mk, mesh=None, op=op)
        for flow in ("cgtrans", "baseline"):
            out = jax.jit(lambda ff, *a, fl=flow, o=op:
                          cgtrans.aggregate_edges(
                              ff, *a, mesh=mesh, dataflow=fl, op=o,
                              impl="pallas", scheduled=False))(f, *eargs)
            close(out, ref_e, ("edges-unsched", flow, op))
            print(f"parity path=edges flow={flow} op={op} impl=pallas "
                  f"sched=off ok")
            out = jax.jit(lambda ff, n_, m_, fl=flow, o=op:
                          cgtrans.aggregate_sampled(
                              ff, n_, m_, mesh=mesh, dataflow=fl, op=o,
                              impl="pallas", scheduled=False))(f, nb, mk)
            close(out, ref_s, ("sampled-unsched", flow, op))
            print(f"parity path=sampled flow={flow} op={op} impl=pallas "
                  f"sched=off ok")

    # the HOISTED deployment (what PALLAS_CONFIG ships): schedule built once
    # per (partition, batch), edge list restructured at partition time, and
    # every aggregation consuming it through shard_map via schedule_applied —
    # plus the sharded gcn_forward_full auto-hoist wrapping the same plumbing
    sched = cgtrans.build_edge_schedule(eargs[1], mask, 256, mesh=mesh)
    p_args = cgtrans.apply_edge_schedule(sched, *eargs)
    ref = cgtrans.aggregate_edges(feats, *eargs, mesh=None, op="add")
    out = jax.jit(lambda ff, sc, *a: cgtrans.aggregate_edges(
        ff, *a, mesh=mesh, dataflow="cgtrans", op="add", impl="pallas",
        schedule=sc, schedule_applied=True))(feats, sched, *p_args)
    close(out, ref, ("edges hoisted",))
    print("parity path=edges flow=cgtrans hoisted-schedule ok")

    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_forward_full, gcn_schema
    params = init_params(
        gcn_schema(GCNConfig(n_features=16, hidden=8, n_classes=4)),
        jax.random.PRNGKey(0))
    gouts = {}
    for impl in ("xla", "pallas"):
        cfg = GCNConfig(n_features=16, hidden=8, n_classes=4, impl=impl)
        gouts[impl] = jax.jit(lambda pp, ff, c=cfg: gcn_forward_full(
            pp, ff, *eargs, c, mesh=mesh))(params, feats)
    close(gouts["pallas"], gouts["xla"], ("gcn-full hoisted",))
    print("parity gcn-full sharded hoisted-schedule ok")
    print("cgtrans pallas parity ok")


def case_cgtrans_coalesce_parity():
    """The coalesced-request matrix on a REAL 8-way mesh: for every
    (dataflow, impl, chunked, scheduled) cell, ``aggregate_multi`` over a
    sage-shaped request pair (a K=1 all-valid lookup segment + a masked
    fan-out segment) ≡ the two separate ``aggregate_sampled`` calls — with
    one all-masked seed shard, gradients, the deterministic
    collectives-per-step 2 → 1 assertion (jaxpr-level, immune to XLA
    combiner passes), and a ``sage_forward`` coalesce-flag parity twin.

    Prints one ``coalesce … ok`` line per cell;
    tests/test_cgtrans_coalesce.py parses them into per-cell test results.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.graph import partition_by_src, uniform_graph, host_sample
    from repro.launch.jaxpr_stats import collective_counts
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    g = uniform_graph(256, 1000, seed=1, n_features=16, weights=True)
    pg = partition_by_src(g, 8)
    feats = jnp.asarray(pg.features)

    seeds = rng.integers(0, 256, 64).astype(np.int32)
    nbrs, smask = host_sample(g, seeds, 10, seed=2)
    nb2 = jnp.asarray(nbrs.reshape(8, 8, 10))
    mk2 = np.asarray(smask.reshape(8, 8, 10)).copy()
    mk2[5] = False                                         # all-masked shard
    mk2 = jnp.asarray(mk2)
    nb1 = jnp.asarray(rng.integers(0, 256, (8, 6, 1)).astype(np.int32))
    mk1 = jnp.ones((8, 6, 1), bool)
    b1, b2 = (nb1, mk1), (nb2, mk2)

    def close(a, b, tag, tol=1e-3):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < tol, (tag, err)

    ref1 = cgtrans.aggregate_sampled(feats, nb1, mk1, mesh=None)
    ref2 = cgtrans.aggregate_sampled(feats, nb2, mk2, mesh=None)
    for flow in ("cgtrans", "baseline"):
        for impl in ("xla", "pallas"):
            for chunk in (None, 3):
                o1, o2 = jax.jit(lambda f, fl=flow, i=impl, c=chunk:
                                 cgtrans.aggregate_multi(
                                     f, (b1, b2), mesh=mesh, dataflow=fl,
                                     impl=i, request_chunk=c))(feats)
                close(o1, ref1, ("coalesce seg1", flow, impl, chunk))
                close(o2, ref2, ("coalesce seg2", flow, impl, chunk))
                tag = "on" if chunk else "off"
                print(f"coalesce flow={flow} impl={impl} chunked={tag} ok")
        # the scheduled axis (pallas defaults to scheduled on the mesh —
        # the cells above run it; pin scheduled=off explicitly too)
        for sched in (False, True):
            o1, o2 = jax.jit(lambda f, fl=flow, s=sched:
                             cgtrans.aggregate_multi(
                                 f, (b1, b2), mesh=mesh, dataflow=fl,
                                 impl="pallas", scheduled=s))(feats)
            close(o1, ref1, ("coalesce-sched seg1", flow, sched))
            close(o2, ref2, ("coalesce-sched seg2", flow, sched))
            print(f"coalesce flow={flow} impl=pallas "
                  f"sched={'on' if sched else 'off'} ok")

    # gradients: d_feats through the coalesced block ≡ the separate calls
    u1 = jnp.asarray(rng.standard_normal((8, 6, 16)).astype(np.float32))
    u2 = jnp.asarray(rng.standard_normal((8, 8, 16)).astype(np.float32))
    ref_g = jax.grad(lambda f: jnp.sum(
        cgtrans.aggregate_sampled(f, nb1, mk1, mesh=None) * u1) + jnp.sum(
        cgtrans.aggregate_sampled(f, nb2, mk2, mesh=None) * u2))(feats)
    for flow in ("cgtrans", "baseline"):
        for impl in ("xla", "pallas"):
            gc = jax.jit(jax.grad(
                lambda f, fl=flow, i=impl: (lambda a, b:
                                            jnp.sum(a * u1) + jnp.sum(b * u2))(
                    *cgtrans.aggregate_multi(f, (b1, b2), mesh=mesh,
                                             dataflow=fl, impl=i))))(feats)
            close(gc, ref_g, ("coalesce grad", flow, impl))
        print(f"coalesce grads flow={flow} ok")

    # the headline, counted deterministically at the jaxpr level:
    # collectives-per-step 2 → 1 on the cgtrans dataflow, halved on baseline
    def sep(f, fl):
        return (cgtrans.aggregate_sampled(f, nb1, mk1, mesh=mesh, dataflow=fl),
                cgtrans.aggregate_sampled(f, nb2, mk2, mesh=mesh, dataflow=fl))

    def coa(f, fl):
        return cgtrans.aggregate_multi(f, (b1, b2), mesh=mesh, dataflow=fl)

    # the expected counts come from analysis/contracts.py — the committed
    # budget table is the single source of truth (lint verifies it against
    # the abstract trace; this asserts it on the REAL mesh programs)
    from repro.analysis.contracts import SAGE_FETCH_COLLECTIVES
    cs = collective_counts(lambda f: sep(f, "cgtrans"), feats)
    cc = collective_counts(lambda f: coa(f, "cgtrans"), feats)
    for counts, budget in ((cs, SAGE_FETCH_COLLECTIVES["separate"]),
                           (cc, SAGE_FETCH_COLLECTIVES["coalesced"])):
        for coll, want in budget.items():
            assert counts[coll] == want, (coll, want, dict(counts))
    print("coalesce collectives cgtrans separate=2 coalesced=1 ok")
    bs = collective_counts(lambda f: sep(f, "baseline"), feats)
    bc = collective_counts(lambda f: coa(f, "baseline"), feats)
    assert bc["all_to_all"] * 2 == bs["all_to_all"], (dict(bs), dict(bc))
    assert bc["all_gather"] * 2 == bs["all_gather"], (dict(bs), dict(bc))
    print("coalesce collectives baseline halved ok")

    # sage_forward on the mesh: coalesce=True ≡ coalesce=False end to end
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema, sage_forward
    batch = {
        "seeds": jnp.asarray(rng.integers(0, 256, (8, 4)).astype(np.int32)),
        "nbrs1": jnp.asarray(rng.integers(0, 256, (8, 4, 3)).astype(np.int32)),
        "mask1": jnp.asarray(rng.random((8, 4, 3)) < 0.8),
        "nbrs2": jnp.asarray(rng.integers(0, 256, (8, 16, 5)).astype(np.int32)),
        "mask2": jnp.asarray(rng.random((8, 16, 5)) < 0.8),
    }
    logits = {}
    for coalesce in (True, False):
        cfg = GCNConfig(n_features=16, hidden=8, n_classes=4, fanout=5,
                        coalesce=coalesce)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        logits[coalesce] = jax.jit(lambda p, f, c=cfg: sage_forward(
            p, f, batch, c, mesh=mesh))(params, feats)
    close(logits[True], logits[False], ("sage coalesce parity",), tol=1e-5)
    print("coalesce sage-forward mesh parity ok")
    print("cgtrans coalesce parity ok")


def case_cgtrans_grad_parity():
    """The gradient matrix on a REAL 8-way mesh: for every (dataflow, op,
    path), ``jax.grad`` through impl="pallas" ≡ impl="xla" ≡ the
    single-shard reference — with ragged per-shard edge counts, one
    all-masked shard, weights grads on the edges path, the chunked request
    stream, and a 3-step pallas-vs-xla ``make_sage_train_step`` parity run.

    Prints one ``grad path=… flow=… op=… impl=… ok`` line per cell;
    tests/test_cgtrans_grad.py parses them into per-cell test results.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.graph import partition_by_src, uniform_graph, host_sample
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    g = uniform_graph(256, 1000, seed=1, n_features=16, weights=True)
    pg = partition_by_src(g, 8)
    feats = jnp.asarray(pg.features)
    mask = np.asarray(pg.mask).copy()
    mask[3] = False                                        # all-padded shard
    mask = jnp.asarray(mask)
    src, dst, wts = (jnp.asarray(pg.src), jnp.asarray(pg.dst),
                     jnp.asarray(pg.weights))
    u_e = jnp.asarray(rng.standard_normal(feats.shape).astype(np.float32))

    def close(a, b, tag, tol=1e-3):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < tol, (tag, err)

    def eloss(f, w, flow, op, impl, mesh_):
        out = cgtrans.aggregate_edges(f, src, dst, w, mask, mesh=mesh_,
                                      dataflow=flow, op=op, impl=impl)
        # mask the no-in-edge ±inf identities the way gcn_forward_full does
        return jnp.sum(jnp.where(jnp.isfinite(out), out, 0.0) * u_e)

    egrad = jax.jit(jax.grad(eloss, argnums=(0, 1)),
                    static_argnums=(2, 3, 4, 5))
    for op in ("add", "max", "min"):
        ref_f, ref_w = egrad(feats, wts, "cgtrans", op, "xla", None)
        for flow in ("cgtrans", "baseline"):
            for impl in ("xla", "pallas"):
                gf, gw = egrad(feats, wts, flow, op, impl, mesh)
                close(gf, ref_f, ("edges d_feats", flow, op, impl))
                close(gw, ref_w, ("edges d_weights", flow, op, impl))
                print(f"grad path=edges flow={flow} op={op} impl={impl} ok")

    seeds = rng.integers(0, 256, 64).astype(np.int32)
    nbrs, smask = host_sample(g, seeds, 10, seed=2)
    nb = jnp.asarray(nbrs.reshape(8, 8, 10))
    mk = np.asarray(smask.reshape(8, 8, 10)).copy()
    mk[5] = False                                          # all-padded shard
    mk = jnp.asarray(mk)
    u_s = jnp.asarray(rng.standard_normal((8, 8, 16)).astype(np.float32))

    def sloss(f, flow, op, impl, mesh_, chunk):
        out = cgtrans.aggregate_sampled(f, nb, mk, mesh=mesh_, dataflow=flow,
                                        op=op, impl=impl, request_chunk=chunk)
        return jnp.sum(out * u_s)      # identity rows read 0 on every op

    sgrad = jax.jit(jax.grad(sloss), static_argnums=(1, 2, 3, 4, 5))
    for op in ("add", "max", "min"):
        ref = sgrad(feats, "cgtrans", op, "xla", None, None)
        for flow in ("cgtrans", "baseline"):
            for impl in ("xla", "pallas"):
                gf = sgrad(feats, flow, op, impl, mesh, None)
                close(gf, ref, ("sampled d_feats", flow, op, impl))
                print(f"grad path=sampled flow={flow} op={op} impl={impl} ok")

    # chunked request stream: pallas grads, chunked ≡ unchunked, on the mesh
    ref = sgrad(feats, "cgtrans", "add", "xla", None, None)
    for flow in ("cgtrans", "baseline"):
        for chunk in (1, 3, 64):
            gf = sgrad(feats, flow, "add", "pallas", mesh, chunk)
            close(gf, ref, ("chunked grad", flow, chunk))
            print(f"grad path=sampled flow={flow} chunk={chunk} ok")

    # scheduled=off pallas grad cells (the pallas cells above run the mesh
    # default, i.e. scheduled): pin the unscheduled backward too
    def eloss_unsched(f, w, flow, op):
        out = cgtrans.aggregate_edges(f, src, dst, w, mask, mesh=mesh,
                                      dataflow=flow, op=op, impl="pallas",
                                      scheduled=False)
        return jnp.sum(jnp.where(jnp.isfinite(out), out, 0.0) * u_e)

    egrad_u = jax.jit(jax.grad(eloss_unsched, argnums=(0, 1)),
                      static_argnums=(2, 3))
    for op in ("add", "max"):
        ref_f, ref_w = egrad(feats, wts, "cgtrans", op, "xla", None)
        for flow in ("cgtrans", "baseline"):
            gf, gw = egrad_u(feats, wts, flow, op)
            close(gf, ref_f, ("edges d_feats unsched", flow, op))
            close(gw, ref_w, ("edges d_weights unsched", flow, op))
            print(f"grad path=edges flow={flow} op={op} impl=pallas "
                  f"sched=off ok")

    # the HOISTED deployment's backward: schedule built/applied once at
    # partition time, grads pulled through schedule_applied aggregation —
    # d_feats matches the unpermuted reference (edge order never touches
    # the row space), d_weights matches the reference permuted per shard
    sched = cgtrans.build_edge_schedule(dst, mask, 256, mesh=mesh)
    p_src, p_dst, p_wts, p_mask = cgtrans.apply_edge_schedule(
        sched, src, dst, wts, mask)

    def hloss(f, w):
        out = cgtrans.aggregate_edges(f, p_src, p_dst, w, p_mask, mesh=mesh,
                                      dataflow="cgtrans", op="add",
                                      impl="pallas", schedule=sched,
                                      schedule_applied=True)
        return jnp.sum(jnp.where(jnp.isfinite(out), out, 0.0) * u_e)

    ref_f, ref_w = egrad(feats, wts, "cgtrans", "add", "xla", None)
    gf, gw = jax.jit(jax.grad(hloss, argnums=(0, 1)))(feats, p_wts)
    close(gf, ref_f, ("hoisted d_feats",))
    close(gw, jnp.take_along_axis(ref_w, sched.perm, axis=1),
          ("hoisted d_weights",))
    print("grad path=edges hoisted-schedule ok")

    _train_parity_on_mesh(mesh)
    print("cgtrans grad parity ok")


def _train_parity_on_mesh(mesh):
    """3 ``make_sage_train_step`` steps on the 8-way mesh: impl="pallas"
    loss decreases and per-step params track impl="xla" to fp32 tolerance."""
    import jax
    import jax.numpy as jnp
    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema
    from repro.data import GraphBatchStream, synthetic_node_labels
    from repro.graph import partition_by_src, uniform_graph
    from repro.optim import adamw_init
    from repro.train import make_sage_train_step

    g = uniform_graph(128, 1024, seed=0, n_features=8)
    labels = synthetic_node_labels(g.features, 4)
    pg = partition_by_src(g, 8)
    feats = jnp.asarray(pg.features)
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=3,
                     weight_decay=0.0)
    stream = GraphBatchStream(g, labels, n_parts=8, batch_per_part=4,
                              k1=3, k2=3)
    # one repeated batch: descent on it is guaranteed (see the in-process
    # twin in tests/test_cgtrans_grad.py)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    batches = [batch] * 3

    runs = {}
    for impl in ("xla", "pallas"):
        cfg = GCNConfig(n_features=8, hidden=16, n_classes=4, fanout=3,
                        impl=impl)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw_init(params, tc),
                 "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_sage_train_step(cfg, tc, feats=feats, mesh=mesh))
        losses, snaps = [], []
        for b in batches:
            state, m = step(state, b)
            losses.append(float(m["total_loss"]))
            snaps.append(jax.tree.map(np.asarray, state["params"]))
        runs[impl] = (losses, snaps)

    xl, xs = runs["xla"]
    pl_, ps = runs["pallas"]
    assert pl_[-1] < pl_[0], f"pallas loss did not decrease: {pl_}"
    for i in range(3):
        np.testing.assert_allclose(pl_[i], xl[i], atol=1e-4, rtol=1e-4)
        for ax, ap in zip(jax.tree.leaves(xs[i]), jax.tree.leaves(ps[i])):
            np.testing.assert_allclose(ap, ax, atol=1e-4, rtol=1e-4,
                                       err_msg=f"params diverged at step {i}")
    print("train pallas-vs-xla 3-step parity ok")


def case_wire_parity():
    """The compressed-wire matrix on a REAL 8-way mesh (repro.core.wire):

    * bf16 wire ≡ f32 wire BIT-EXACT — values and gradients — on
      integer-valued features (|x| ≤ 5, fan-out sums ≤ 256 fit bf16's 8
      mantissa bits; dyadic counts keep the mean divisions exact), across
      sampled/multi/edges × add/max/min × xla/pallas;
    * int8 wire bounded error on float features (per-row scale/2 per hop);
    * the delta-id gate: V > 32767 falls back to the raw int32 id stream
      and still agrees with the reference;
    * collective counts: the narrow wire changes BYTES, never counts —
      except edges-add's pinned psum_scatter → all_to_all swap;
    * the serving engine on the bf16 wire ≡ the f32 engine bit for bit.

    Prints one ``wire … ok`` line per cell; tests/test_wire.py parses them.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.graph import partition_by_src, uniform_graph, host_sample
    from repro.launch.jaxpr_stats import collective_counts
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    g = uniform_graph(256, 1000, seed=1, n_features=16, weights=True)
    pg = partition_by_src(g, 8)
    # integer-valued f32 features in [-5, 5]: masked fan-out sums stay
    # ≤ 10·5 ≪ 256, so the bf16 wire is lossless by construction
    feats = jnp.asarray(np.round(np.asarray(pg.features) * 5.0)
                        .astype(np.float32))
    mask = np.asarray(pg.mask).copy()
    mask[3] = False                                        # all-padded shard
    mask = jnp.asarray(mask)
    eargs = (jnp.asarray(pg.src), jnp.asarray(pg.dst),
             jnp.asarray(pg.weights), mask)

    seeds = rng.integers(0, 256, 64).astype(np.int32)
    nbrs, smask = host_sample(g, seeds, 10, seed=2)
    nb = jnp.asarray(nbrs.reshape(8, 8, 10))
    mk = np.asarray(smask.reshape(8, 8, 10)).copy()
    mk[5] = False                                          # all-padded shard
    mk = jnp.asarray(mk)

    def exact(a, b, tag):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(tag))

    # -- bf16 ≡ f32 bit-exact values: sampled × op × impl -------------------
    for op in ("add", "max", "min"):
        for impl in ("xla", "pallas"):
            outs = {}
            for w in ("f32", "bf16"):
                outs[w] = jax.jit(lambda f, o=op, i=impl, ww=w:
                                  cgtrans.aggregate_sampled(
                                      f, nb, mk, mesh=mesh, op=o, impl=i,
                                      wire=ww))(feats)
            exact(outs["bf16"], outs["f32"], ("sampled", op, impl))
            print(f"wire path=sampled op={op} impl={impl} bf16 exact ok")

    # -- bf16 ≡ f32 bit-exact values: edges × op ----------------------------
    # (unit edge weights keep the payload integer-valued; untouched
    # destinations hold the ±inf identity on BOTH wires — inf compares
    # equal to inf, so assert_array_equal pins them too)
    ew = (eargs[0], eargs[1], jnp.ones_like(eargs[2]), eargs[3])
    for op in ("add", "max", "min"):
        outs = {}
        for w in ("f32", "bf16"):
            outs[w] = jax.jit(lambda f, o=op, ww=w: cgtrans.aggregate_edges(
                f, *ew, mesh=mesh, op=o, wire=ww))(feats)
        exact(outs["bf16"], outs["f32"], ("edges", op))
        print(f"wire path=edges op={op} bf16 exact ok")

    # -- bf16 ≡ f32 bit-exact: the coalesced command block ------------------
    nb1 = jnp.asarray(rng.integers(0, 256, (8, 6, 1)).astype(np.int32))
    mk1 = jnp.ones((8, 6, 1), bool)
    b1, b2 = (nb1, mk1), (nb, mk)
    for impl in ("xla", "pallas"):
        outs = {}
        for w in ("f32", "bf16"):
            outs[w] = jax.jit(lambda f, i=impl, ww=w: cgtrans.aggregate_multi(
                f, (b1, b2), mesh=mesh, impl=i, wire=ww))(feats)
        exact(outs["bf16"][0], outs["f32"][0], ("multi seg1", impl))
        exact(outs["bf16"][1], outs["f32"][1], ("multi seg2", impl))
        print(f"wire path=multi impl={impl} bf16 exact ok")

    # -- bf16 ≡ f32 bit-exact GRADIENTS -------------------------------------
    # dyadic setup: all-valid masks + K=4 make every mean division exact in
    # binary; integer cotangents in [-4, 4] stay dyadic through the 1/K —
    # the backward wire (the custom_vjp ships cotangents through the SAME
    # codec) is then lossless too
    nb4 = jnp.asarray(rng.integers(0, 256, (8, 8, 4)).astype(np.int32))
    mk4 = jnp.ones((8, 8, 4), bool)
    u = jnp.asarray(rng.integers(-4, 5, (8, 8, 16)).astype(np.float32))

    def sloss(f, impl, w):
        out = cgtrans.aggregate_sampled(f, nb4, mk4, mesh=mesh, impl=impl,
                                        wire=w)
        return jnp.sum(out * u)

    sgrad = jax.jit(jax.grad(sloss), static_argnums=(1, 2))
    for impl in ("xla", "pallas"):
        exact(sgrad(feats, impl, "bf16"), sgrad(feats, impl, "f32"),
              ("sampled grad", impl))
        print(f"wire grad path=sampled impl={impl} bf16 exact ok")

    u1 = jnp.asarray(rng.integers(-4, 5, (8, 6, 16)).astype(np.float32))

    def mloss(f, w):
        a, b = cgtrans.aggregate_multi(f, ((nb1, mk1), (nb4, mk4)),
                                       mesh=mesh, wire=w)
        return jnp.sum(a * u1) + jnp.sum(b * u)

    mgrad = jax.jit(jax.grad(mloss), static_argnums=(1,))
    exact(mgrad(feats, "bf16"), mgrad(feats, "f32"), ("multi grad",))
    print("wire grad path=multi bf16 exact ok")

    # -- int8 bounded error -------------------------------------------------
    # float features now; the bound is loose (one scale/2 per hop) but the
    # claim that matters — quantization stays a TRANSPORT error, never a
    # corruption — shows as a small fraction of the payload magnitude
    ffeats = jnp.asarray(pg.features)
    for path, fn in (("sampled", lambda f, w: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, wire=w)),
                     ("edges", lambda f, w: cgtrans.aggregate_edges(
                         f, *eargs, mesh=mesh, op="max", wire=w))):
        a = np.asarray(jax.jit(lambda f, fn=fn: fn(f, "int8"))(ffeats))
        b = np.asarray(jax.jit(lambda f, fn=fn: fn(f, "f32"))(ffeats))
        fin = np.isfinite(a) & np.isfinite(b)
        # identity rows (±inf / untouched) must agree EXACTLY between wires
        assert (np.isfinite(a) == np.isfinite(b)).all(), path
        err = np.abs(a[fin] - b[fin]).max()
        span = np.abs(b[fin]).max()
        assert err <= 0.02 * span + 1e-6, (path, err, span)
        print(f"wire path={path} int8 bounded ok")

    # -- the delta-id range gate: V over the int16 limit falls back ---------
    big_v = 2**16                      # > ID_DELTA_MAX_V → raw int32 ids
    bfeats = jnp.asarray(np.round(rng.standard_normal(
        (8, big_v // 8, 4)) * 5.0).astype(np.float32))
    bnb = jnp.asarray(rng.integers(0, big_v, (8, 4, 4)).astype(np.int32))
    bmk = jnp.ones((8, 4, 4), bool)
    outs = {}
    for w in ("f32", "bf16"):
        outs[w] = jax.jit(lambda f, ww=w: cgtrans.aggregate_sampled(
            f, bnb, bmk, mesh=mesh, wire=ww))(bfeats)
    exact(outs["bf16"], outs["f32"], ("delta fallback",))
    print("wire delta-fallback raw-int32 ids ok")

    # -- counts: bytes change, budgets don't (except edges-add's swap) ------
    for w in ("bf16", "int8"):
        cw = collective_counts(lambda f: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, wire=w), feats)
        c0 = collective_counts(lambda f: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, wire="f32"), feats)
        assert dict(cw) == dict(c0), (w, dict(cw), dict(c0))
        ce = collective_counts(lambda f: cgtrans.aggregate_edges(
            f, *eargs, mesh=mesh, op="add", wire=w), feats)
        assert ce["psum_scatter"] == 0 and ce["all_to_all"] == 1, dict(ce)
    print("wire collective counts ok")

    # -- the serving engine on the bf16 wire --------------------------------
    from repro.serving import ServingEngine
    V, F = 256, 16
    sfeats = np.round(rng.standard_normal((V, F)) * 5.0).astype(np.float32)
    indptr, indices, _ = g.to_csr()
    res = {}
    sseeds = rng.integers(0, V, 8)
    for w in ("f32", "bf16"):
        eng = ServingEngine(sfeats, indptr, indices, mesh=mesh, fanout=4,
                            wire=w, max_batch=8)
        rids = [eng.submit([int(s)]) for s in sseeds]
        assert eng.poll() == 8
        res[w] = [eng.result(r) for r in rids]
    for a, b in zip(res["bf16"], res["f32"]):
        exact(a.self_rows, b.self_rows, ("serving self",))
        exact(a.agg_rows, b.agg_rows, ("serving agg",))
    print("wire serving bf16 exact ok")
    print("wire parity ok")


def case_cgtrans_collective_bytes():
    """The paper's mechanism measured: cgtrans moves ≈ K× fewer collective
    bytes than baseline for fan-out K sampled aggregation."""
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    P_, part, F = 8, 64, 128
    B_loc, K = 32, 16
    feats = jnp.zeros((P_, part, F))
    nbrs = jnp.zeros((P_, B_loc, K), jnp.int32)
    mask = jnp.ones((P_, B_loc, K), bool)
    bytes_ = {}
    for flow in ("cgtrans", "baseline"):
        comp = jax.jit(lambda f, n, m, fl=flow: cgtrans.aggregate_sampled(
            f, n, m, mesh=mesh, dataflow=fl)).lower(feats, nbrs, mask).compile()
        bytes_[flow] = H.analyze(comp.as_text()).collective_bytes
    ratio = bytes_["baseline"] / bytes_["cgtrans"]
    assert ratio > K / 4, (bytes_, ratio)   # compression ≈ fan-out
    print(f"collective bytes: baseline={bytes_['baseline']:.0f} "
          f"cgtrans={bytes_['cgtrans']:.0f} ratio={ratio:.1f} ok")


def case_embedding_cgtrans():
    import jax
    import jax.numpy as jnp
    from repro.models.embedding import embed_lookup
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(2, 4)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (4, 8)).astype(np.int32))
    want = np.asarray(table)[np.asarray(ids)]
    got = jax.jit(lambda t, i: embed_lookup(t, i, mesh=mesh, cgtrans=True,
                                            compute_dtype=jnp.float32))(table, ids)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
    # gradient: owner-aggregated scatter equals dense one-hot gradient, on
    # both GAS backends (pallas = the FAST-GAS kernel in the custom VJP) and
    # with the chunked request stream on
    dense = jax.grad(lambda t: jnp.sum(jnp.take(t, ids, 0) ** 2))(table)
    for impl in ("xla", "pallas"):
        for chunk in (None, 5):
            def loss(t, impl=impl, chunk=chunk):
                e = embed_lookup(t, ids, mesh=mesh, cgtrans=True,
                                 compute_dtype=jnp.float32, impl=impl,
                                 request_chunk=chunk)
                return jnp.sum(e * e)
            g = jax.jit(jax.grad(loss))(table)
            np.testing.assert_allclose(np.asarray(g), np.asarray(dense),
                                       atol=1e-4, err_msg=f"{impl}/{chunk}")
    print("embedding cgtrans ok")


def case_elastic_checkpoint():
    """Save on a (4,2) mesh, restore onto (2,4) and 1-device — elastic."""
    import tempfile
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.checkpoint import CheckpointManager
    from repro.common.logical import to_physical
    from repro.launch.mesh import make_test_mesh

    spec_tree = {"w": ("vocab", "embed"), "b": (None,)}
    state = {"w": jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4),
             "b": jnp.ones(4)}
    mesh_a = make_test_mesh(4, 2)
    sharded = {
        "w": jax.device_put(state["w"], NamedSharding(mesh_a, to_physical(spec_tree["w"], mesh_a))),
        "b": jax.device_put(state["b"], NamedSharding(mesh_a, to_physical(spec_tree["b"], mesh_a))),
    }
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(sharded, 7)
        mesh_b = make_test_mesh(2, 4)
        restored, step = mgr.restore(state, mesh=mesh_b, spec_tree=spec_tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
        shard_shape = restored["w"].sharding.shard_shape(restored["w"].shape)
        # 2D FSDP×TP: vocab/model(4) × embed/data(2) on the new mesh
        assert shard_shape == (16, 2)
        plain, _ = mgr.restore(state)     # 1-device style restore
        np.testing.assert_array_equal(np.asarray(plain["w"]), np.asarray(state["w"]))
    print("elastic checkpoint ok")


def case_distributed_sage_training():
    """2-layer GraphSAGE + CGTrans trains on an 8-way storage mesh — with
    the chunked request stream on (the SSD command-queue analogue)."""
    import jax
    import jax.numpy as jnp
    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_schema
    from repro.data import GraphBatchStream, synthetic_node_labels
    from repro.graph import partition_by_src, uniform_graph
    from repro.launch.mesh import make_data_mesh
    from repro.optim import adamw_init
    from repro.train import make_sage_train_step

    mesh = make_data_mesh(8)
    g = uniform_graph(512, 8192, seed=0, n_features=16)
    labels = synthetic_node_labels(g.features, 4)
    pg = partition_by_src(g, 8)
    feats = jnp.asarray(pg.features)
    cfg = GCNConfig(n_features=16, hidden=32, n_classes=4, fanout=8,
                    request_chunk=8)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=60,
                     weight_decay=0.0)
    params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params, tc),
             "step": jnp.zeros((), jnp.int32)}
    stream = GraphBatchStream(g, labels, n_parts=8, batch_per_part=16, k1=4, k2=4)

    step = jax.jit(make_sage_train_step(cfg, tc, feats=feats, mesh=mesh))

    losses = []
    for i, batch in zip(range(60), stream):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        b["mask1"] = b["mask1"].astype(bool)
        b["mask2"] = b["mask2"].astype(bool)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    print(f"sage training ok: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


def case_pipeline_parallel():
    """GPipe fill–drain over a 2-stage 'pod' axis == sequential execution."""
    import jax
    import jax.numpy as jnp
    from repro.compat import AxisType, make_mesh
    from repro.train.pipeline import pipelined_apply, split_stages

    assert split_stages(10, 4) == ((0, 3), (3, 6), (6, 8), (8, 10))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)
    rng = np.random.default_rng(0)
    n_blocks, D = 6, 8
    W = jnp.asarray(rng.standard_normal((n_blocks, D, D)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.standard_normal((4, 2, 5, D)).astype(np.float32))

    def block_fn(x, w):
        return jnp.tanh(x @ w)

    # sequential reference
    ref = x
    for i in range(n_blocks):
        ref = block_fn(ref, W[i])

    with mesh:
        out = jax.jit(lambda w, xx: pipelined_apply(
            block_fn, w, xx, mesh=mesh))(W, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    print("pipeline parallel ok")


def case_islandized_parity():
    """Islandized ≡ interval on a REAL 8-way mesh, plus the counted wins.

    The graph is the adversarial case: a clustered_graph whose vertex ids
    are SCRAMBLED, so the contiguous-interval split gets zero locality while
    ``islandize`` recovers the communities. Edges are deduplicated and the
    integer feature table is per-column injective over vertices, so max/min
    have a unique winner per (destination, column) — the even-split tie
    convention then never mixes non-dyadic fractions and every gradient sum
    is an integer, making bit-exactness hold under any edge reordering.

    Cells (tests/test_partition.py parses the lines):
    * values: aggregate_edges island ≡ interval, un-permuted, across
      dataflow × op × impl;
    * grads: d/d_feats of a masked integer-cotangent loss, same matrix
      (add/max);
    * sage_forward island ≡ interval (and one optimizer step through
      make_sage_train_step(relabel=), bit-exact params);
    * ServingEngine(partition="island") ≡ interval with the hot cache ON;
    * counted locality: remote destination rows and dense occupancy rounds
      both strictly reduced.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.core.gcn import GCNConfig, gcn_schema, sage_forward
    from repro.graph import (COOGraph, clustered_graph, partition_by_src,
                             partition_graph, remote_destination_rows)
    from repro.kernels.gas_scatter import ops as gas_ops
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    V, E0, F = 256, 2048, 8
    g0 = clustered_graph(V, E0, n_clusters=8, p_intra=0.92, seed=3)
    perm = rng.permutation(V).astype(np.int32)
    src, dst = perm[g0.src], perm[g0.dst]
    # dedupe (src, dst) pairs: duplicate edges are exact max/min ties whose
    # even-split backward would go non-dyadic
    pairs = np.unique(np.stack([src, dst], 1), axis=0)
    # per-column injective integer features: column f holds v - 128 + f with
    # alternating sign, so every destination's max/min winner is unique
    feats = ((np.arange(V)[:, None] - V // 2 + np.arange(F)[None, :])
             * np.where(np.arange(F) % 2 == 0, 1.0, -1.0)).astype(np.float32)
    g = COOGraph(V, pairs[:, 0].astype(np.int32),
                 pairs[:, 1].astype(np.int32), None, feats)

    pg_i, _ = partition_graph(g, 8, method="interval")
    pg_s, isl = partition_graph(g, 8, method="island")
    assert isl is not None and pg_i.part_size == pg_s.part_size
    part = pg_i.part_size

    # -- counted locality: both reductions strict on the 8-way mesh ---------
    # (counted on a graph big enough for several 128-row blocks per shard —
    # the parity graph above keeps the matrix cheap, but its 2-block row
    # grid saturates the dense occupancy in both layouts)
    gl0 = clustered_graph(1024, 8192, n_clusters=8, p_intra=0.95, seed=3)
    permL = np.random.default_rng(1003).permutation(1024).astype(np.int32)
    gl = COOGraph(1024, permL[gl0.src], permL[gl0.dst])
    lpg_i, _ = partition_graph(gl, 8, method="interval")
    lpg_s, _ = partition_graph(gl, 8, method="island")
    rr_i = remote_destination_rows(lpg_i)
    rr_s = remote_destination_rows(lpg_s)
    assert int(rr_s.sum()) < int(rr_i.sum()), (rr_i, rr_s)
    assert int(rr_s.max()) < int(rr_i.max()), (rr_i, rr_s)
    print(f"island locality remote_rows interval={int(rr_i.sum())} "
          f"island={int(rr_s.sum())} ok")

    def dense_live(pg):
        live = 0
        for p in range(8):
            l, _ = gas_ops.dense_skip_stats(
                jnp.asarray(pg.dst[p]), jnp.asarray(pg.mask[p]),
                8 * pg.part_size)
            live += int(l)
        return live

    dl_i, dl_s = dense_live(lpg_i), dense_live(lpg_s)
    assert dl_s < dl_i, (dl_i, dl_s)
    print(f"island locality dense_rounds interval={dl_i} island={dl_s} ok")

    def exact(a, b, tag):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(tag))

    def unpermute(flat_rows):
        """(8·part, F) islandized rows → original vertex order, rows [0, V)."""
        return np.asarray(flat_rows).reshape(8 * part, -1)[isl.relabel]

    args_i = (jnp.asarray(pg_i.features), jnp.asarray(pg_i.src),
              jnp.asarray(pg_i.dst), jnp.asarray(pg_i.weights),
              jnp.asarray(pg_i.mask))
    args_s = (jnp.asarray(pg_s.features), jnp.asarray(pg_s.src),
              jnp.asarray(pg_s.dst), jnp.asarray(pg_s.weights),
              jnp.asarray(pg_s.mask))

    # -- values: dataflow × op × impl ---------------------------------------
    agg = jax.jit(
        lambda a, flow, op, impl: cgtrans.aggregate_edges(
            *a, mesh=mesh, dataflow=flow, op=op, impl=impl),
        static_argnums=(1, 2, 3))
    for flow in ("cgtrans", "baseline"):
        for op in ("add", "max", "min"):
            for impl in ("xla", "pallas"):
                out_i = np.asarray(agg(args_i, flow, op, impl))
                out_s = np.asarray(agg(args_s, flow, op, impl))
                exact(out_i.reshape(8 * part, F)[:V],
                      unpermute(out_s), (flow, op, impl))
                print(f"island parity path=edges flow={flow} op={op} "
                      f"impl={impl} ok")

    # -- grads: d/d_feats of an integer-cotangent loss, add/max -------------
    u = rng.integers(-3, 4, (V, F)).astype(np.float32)
    u_i = np.zeros((8 * part, F), np.float32)
    u_i[:V] = u
    u_s = np.zeros((8 * part, F), np.float32)
    u_s[:V] = u[isl.inverse]                # cotangent follows its vertex
    u_i, u_s = (jnp.asarray(u_i.reshape(8, part, F)),
                jnp.asarray(u_s.reshape(8, part, F)))

    def loss(f, rest, ct, flow, op, impl):
        out = cgtrans.aggregate_edges(f, *rest, mesh=mesh, dataflow=flow,
                                      op=op, impl=impl)
        return jnp.sum(jnp.where(jnp.isfinite(out), out, 0.0) * ct)

    dgrad = jax.jit(jax.grad(loss), static_argnums=(3, 4, 5))
    for flow in ("cgtrans", "baseline"):
        for op in ("add", "max"):
            for impl in ("xla", "pallas"):
                g_i = np.asarray(dgrad(args_i[0], args_i[1:], u_i,
                                       flow, op, impl))
                g_s = np.asarray(dgrad(args_s[0], args_s[1:], u_s,
                                       flow, op, impl))
                exact(g_i.reshape(8 * part, F)[:V],
                      unpermute(g_s.reshape(8 * part, F)),
                      ("grad", flow, op, impl))
                print(f"island parity grad flow={flow} op={op} "
                      f"impl={impl} ok")

    # -- sage_forward + one optimizer step ----------------------------------
    import dataclasses as _dc

    from repro.common.config import TrainConfig
    from repro.common.schema import init_params
    from repro.optim import adamw_init
    from repro.train import make_sage_train_step

    B, K1, K2 = 4, 3, 3
    cfg_i = GCNConfig(n_features=F, hidden=16, n_classes=4, fanout=K1)
    cfg_s = _dc.replace(cfg_i, partition="island")
    batch = {
        "seeds": jnp.asarray(rng.integers(0, V, (8, B)).astype(np.int32)),
        "nbrs1": jnp.asarray(rng.integers(0, V, (8, B, K1)).astype(np.int32)),
        "mask1": jnp.asarray(rng.random((8, B, K1)) < 0.8),
        "nbrs2": jnp.asarray(
            rng.integers(0, V, (8, B * (1 + K1), K2)).astype(np.int32)),
        "mask2": jnp.asarray(rng.random((8, B * (1 + K1), K2)) < 0.8),
        "labels": jnp.asarray(rng.integers(0, 4, (8, B)).astype(np.int32)),
    }
    params = init_params(gcn_schema(cfg_i), jax.random.PRNGKey(0))
    t_i = jnp.asarray(pg_i.features)
    t_s = jnp.asarray(pg_s.features)
    for impl in ("xla", "pallas"):
        ci = _dc.replace(cfg_i, impl=impl)
        cs = _dc.replace(cfg_s, impl=impl)
        o_i = jax.jit(lambda p, f: sage_forward(p, f, batch, ci, mesh=mesh)
                      )(params, t_i)
        o_s = jax.jit(lambda p, f: sage_forward(
            p, f, batch, cs, mesh=mesh, relabel=isl.relabel))(params, t_s)
        exact(o_i, o_s, ("sage", impl))
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=1,
                     weight_decay=0.0)
    snaps = {}
    for name, cfg, t, rl in (("interval", cfg_i, t_i, None),
                             ("island", cfg_s, t_s, isl.relabel)):
        p0 = init_params(gcn_schema(cfg_i), jax.random.PRNGKey(1))
        st = {"params": p0, "opt": adamw_init(p0, tc),
              "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_sage_train_step(cfg, tc, feats=t, mesh=mesh,
                                            relabel=rl))
        st, _m = step(st, batch)
        snaps[name] = jax.tree.map(np.asarray, st["params"])
    for k in snaps["interval"]:
        exact(snaps["interval"][k], snaps["island"][k], ("train", k))
    print("island sage parity ok")

    # -- serving: cache ON, fused blocks, tenants — original-id API --------
    from repro.serving import ServingEngine

    indptr, indices, _ = g.to_csr()
    # integer-valued serve table: the fan-out segment's partial sums group
    # by owner shard, which the relabeling changes — integer addition is
    # order-invariant, float addition only to 1 ulp
    sfeats = np.round(rng.standard_normal((V, F)) * 5.0).astype(np.float32)
    kw = dict(fanout=4, mesh=mesh, max_batch=8, max_delay_s=1e9,
              cache_capacity=32)
    eng_i = ServingEngine(sfeats, indptr, indices, **kw)
    eng_s = ServingEngine(sfeats, indptr, indices, partition="island", **kw)
    seeds = [3, 9, 3, 17, 40, 9, 77, 130]
    for _wave in range(2):                     # wave 2 exercises cache hits
        rids = [(eng_i.submit([s]), eng_s.submit([s])) for s in seeds]
        eng_i.flush()
        eng_s.flush()
        for ri, rs in rids:
            a, b = eng_i.result(ri), eng_s.result(rs)
            exact(a.self_rows, b.self_rows, ("serve self", ri))
            exact(a.agg_rows, b.agg_rows, ("serve agg", ri))
            exact(a.from_cache, b.from_cache, ("serve cache", ri))
    assert eng_i.cache.snapshot() == eng_s.cache.snapshot()
    assert eng_s.cache.snapshot()["hits"] > 0
    print("island serving parity cache=on ok")

    print("islandized parity ok")


def case_sparse_parity():
    """The compressed-sparse feature matrix on a REAL 8-way mesh
    (repro.core.sparse):

    * sparse ≡ dense BIT-EXACT — values and gradients — on integer-valued
      ~10%-dense features, across sampled × add/max/min × cgtrans/baseline
      × xla/pallas, plus the multi and edges entrypoints;
    * the capacity gate: a capacity that can't beat dense falls back to the
      unchanged dense path (still bit-exact);
    * sparse composes with the bf16 wire (baseline raw-row shipment packs
      quantized nonzeros + bitmap) — still exact on small integers;
    * collective counts: the format changes BYTES, never counts;
    * the serving engine on sparse features ≡ the dense engine bit for bit.

    Prints one ``sparse … ok`` line per cell; tests/test_sparse.py parses
    them.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import cgtrans
    from repro.core import sparse as sparsefmt
    from repro.graph import partition_by_src, uniform_graph, host_sample
    from repro.launch.jaxpr_stats import collective_counts
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(8)
    rng = np.random.default_rng(0)
    g = uniform_graph(256, 1000, seed=1, n_features=16, weights=True)
    pg = partition_by_src(g, 8)
    # integer-valued features at ~10% density: round to ints (bit-exact
    # addition in any order), then zero most entries so the measured
    # table_capacity clears the sparse_fits gate
    fdense = np.round(np.asarray(pg.features) * 5.0).astype(np.float32)
    keep = rng.random(fdense.shape) < 0.1
    feats = jnp.asarray(np.where(keep, np.where(fdense == 0, 1.0, fdense), 0.0))
    cap = sparsefmt.table_capacity(np.asarray(feats))
    F = feats.shape[-1]
    assert sparsefmt.sparse_fits(cap, F), (cap, F)
    mask = np.asarray(pg.mask).copy()
    mask[3] = False                                        # all-padded shard
    mask = jnp.asarray(mask)
    eargs = (jnp.asarray(pg.src), jnp.asarray(pg.dst),
             jnp.ones_like(jnp.asarray(pg.weights)), mask)

    seeds = rng.integers(0, 256, 64).astype(np.int32)
    nbrs, smask = host_sample(g, seeds, 10, seed=2)
    nb = jnp.asarray(nbrs.reshape(8, 8, 10))
    mk = np.asarray(smask.reshape(8, 8, 10)).copy()
    mk[5] = False                                          # all-padded shard
    mk = jnp.asarray(mk)

    def exact(a, b, tag):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(tag))

    # -- sparse ≡ dense values: sampled × flow × op × impl ------------------
    for flow in ("cgtrans", "baseline"):
        for op in ("add", "max", "min"):
            for impl in ("xla", "pallas"):
                outs = {}
                for feat_mode, c in (("dense", None), ("sparse", cap)):
                    outs[feat_mode] = jax.jit(
                        lambda f, fl=flow, o=op, i=impl, fm=feat_mode, cc=c:
                        cgtrans.aggregate_sampled(
                            f, nb, mk, mesh=mesh, dataflow=fl, op=o, impl=i,
                            features=fm, sparse_capacity=cc))(feats)
                exact(outs["sparse"], outs["dense"],
                      ("sampled", flow, op, impl))
                print(f"sparse path=sampled flow={flow} op={op} impl={impl} "
                      "exact ok")

    # -- sparse ≡ dense values: edges × flow × op ---------------------------
    for flow in ("cgtrans", "baseline"):
        for op in ("add", "max", "min"):
            outs = {}
            for feat_mode, c in (("dense", None), ("sparse", cap)):
                outs[feat_mode] = jax.jit(
                    lambda f, fl=flow, o=op, fm=feat_mode, cc=c:
                    cgtrans.aggregate_edges(
                        f, *eargs, mesh=mesh, dataflow=fl, op=o,
                        features=fm, sparse_capacity=cc))(feats)
            exact(outs["sparse"], outs["dense"], ("edges", flow, op))
            print(f"sparse path=edges flow={flow} op={op} exact ok")

    # -- sparse ≡ dense: the coalesced command block ------------------------
    nb1 = jnp.asarray(rng.integers(0, 256, (8, 6, 1)).astype(np.int32))
    mk1 = jnp.ones((8, 6, 1), bool)
    for flow in ("cgtrans", "baseline"):
        for impl in ("xla", "pallas"):
            outs = {}
            for feat_mode, c in (("dense", None), ("sparse", cap)):
                outs[feat_mode] = jax.jit(
                    lambda f, fl=flow, i=impl, fm=feat_mode, cc=c:
                    cgtrans.aggregate_multi(
                        f, ((nb1, mk1), (nb, mk)), mesh=mesh, dataflow=fl,
                        impl=i, features=fm, sparse_capacity=cc))(feats)
            exact(outs["sparse"][0], outs["dense"][0],
                  ("multi seg1", flow, impl))
            exact(outs["sparse"][1], outs["dense"][1],
                  ("multi seg2", flow, impl))
            print(f"sparse path=multi flow={flow} impl={impl} exact ok")

    # -- sparse ≡ dense GRADIENTS -------------------------------------------
    # dyadic setup (the wire-parity recipe): all-valid masks + K=4 keep the
    # mean divisions exact; integer cotangents keep every sum bit-exact
    nb4 = jnp.asarray(rng.integers(0, 256, (8, 8, 4)).astype(np.int32))
    mk4 = jnp.ones((8, 8, 4), bool)
    u = jnp.asarray(rng.integers(-4, 5, (8, 8, 16)).astype(np.float32))

    def sloss(f, flow, impl, feat_mode, c):
        out = cgtrans.aggregate_sampled(
            f, nb4, mk4, mesh=mesh, dataflow=flow, impl=impl,
            features=feat_mode, sparse_capacity=c)
        return jnp.sum(out * u)

    sgrad = jax.jit(jax.grad(sloss), static_argnums=(1, 2, 3, 4))
    for flow in ("cgtrans", "baseline"):
        for impl in ("xla", "pallas"):
            exact(sgrad(feats, flow, impl, "sparse", cap),
                  sgrad(feats, flow, impl, "dense", None),
                  ("sampled grad", flow, impl))
            print(f"sparse grad path=sampled flow={flow} impl={impl} "
                  "exact ok")

    def eloss(f, feat_mode, c):
        out = cgtrans.aggregate_edges(
            f, *eargs, mesh=mesh, op="add", features=feat_mode,
            sparse_capacity=c)
        return jnp.sum(out * jnp.asarray(
            rng2.integers(-4, 5, out.shape).astype(np.float32)))

    rng2 = np.random.default_rng(9)
    ge_s = jax.jit(jax.grad(eloss), static_argnums=(1, 2))(feats, "sparse", cap)
    rng2 = np.random.default_rng(9)
    ge_d = jax.jit(jax.grad(eloss), static_argnums=(1, 2))(feats, "dense", None)
    exact(ge_s, ge_d, ("edges grad",))
    print("sparse grad path=edges exact ok")

    # -- the capacity gate: no-win capacity ships dense unchanged -----------
    out_gate = jax.jit(lambda f: cgtrans.aggregate_sampled(
        f, nb, mk, mesh=mesh, features="sparse",
        sparse_capacity=F))(feats)   # F + bitmap ≥ F → gate fails
    out_ref = jax.jit(lambda f: cgtrans.aggregate_sampled(
        f, nb, mk, mesh=mesh))(feats)
    exact(out_gate, out_ref, ("gate fallback",))
    print("sparse gate-fallback dense ok")

    # -- sparse × bf16 wire: the baseline raw-row shipment ------------------
    # (baseline + narrow wire is ONLY legal with sparse features — the
    # packed nonzeros quantize like partials; integer values ≤ 5 keep the
    # bf16 leg lossless, so the composition is still exact)
    for flow in ("cgtrans", "baseline"):
        out_w = jax.jit(lambda f, fl=flow: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, dataflow=fl, wire="bf16",
            features="sparse", sparse_capacity=cap))(feats)
        out_d = jax.jit(lambda f, fl=flow: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, dataflow=fl))(feats)
        exact(out_w, out_d, ("bf16 wire", flow))
        print(f"sparse wire=bf16 flow={flow} exact ok")

    # -- counts: the format changes bytes, never counts ---------------------
    for flow in ("cgtrans", "baseline"):
        cs = collective_counts(lambda f, fl=flow: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, dataflow=fl, features="sparse",
            sparse_capacity=cap), feats)
        cd = collective_counts(lambda f, fl=flow: cgtrans.aggregate_sampled(
            f, nb, mk, mesh=mesh, dataflow=fl), feats)
        assert dict(cs) == dict(cd), (flow, dict(cs), dict(cd))
    print("sparse collective counts ok")

    # -- the serving engine on sparse features ------------------------------
    from repro.serving import ServingEngine
    V = 256
    sfeats = np.asarray(feats).reshape(V, F)
    indptr, indices, _ = g.to_csr()
    res = {}
    sseeds = rng.integers(0, V, 8)
    for feat_mode in ("dense", "sparse"):
        eng = ServingEngine(sfeats, indptr, indices, mesh=mesh, fanout=4,
                            features=feat_mode, max_batch=8)
        rids = [eng.submit([int(s)]) for s in sseeds]
        assert eng.poll() == 8
        res[feat_mode] = [eng.result(r) for r in rids]
    assert res_cap_fits(sfeats)
    for a, b in zip(res["sparse"], res["dense"]):
        exact(a.self_rows, b.self_rows, ("serving self",))
        exact(a.agg_rows, b.agg_rows, ("serving agg",))
    print("sparse serving exact ok")
    print("sparse parity ok")


def res_cap_fits(sfeats):
    """The serving cell only demonstrates compression if the measured
    capacity actually clears the gate on this table."""
    from repro.core import sparse as sparsefmt
    return sparsefmt.sparse_fits(sparsefmt.table_capacity(sfeats),
                                 sfeats.shape[-1])


CASES = {n[len("case_"):]: f for n, f in list(globals().items())
         if n.startswith("case_")}

if __name__ == "__main__":
    if len(sys.argv) != 2 or sys.argv[1] not in CASES:
        sys.exit(f"usage: {sys.argv[0]} <case>\n"
                 f"cases: {', '.join(sorted(CASES))}")
    CASES[sys.argv[1]]()
