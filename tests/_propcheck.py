"""Offline property-testing shim: ``hypothesis`` when installed, otherwise a
minimal deterministic fallback so the seed suite collects and runs with zero
network access.

Usage (the only import style the suite uses):

    from _propcheck import given, settings, strategies as st

When the real ``hypothesis`` is importable we re-export it untouched — full
shrinking, database, the works. When it is absent, ``given`` expands each
property test into a fixed deck of examples: every strategy contributes its
boundary values first (min/max/empty-ish), then pseudo-random draws from a
``random.Random`` seeded by the test name — deterministic across runs and
machines, no global state.

The fallback implements exactly the strategy surface this repo uses:
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``lists``. Grow it
when a test needs more; anything fancier should gate on ``HAVE_HYPOTHESIS``.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import struct
    import zlib

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A draw function plus a deck of boundary examples tried first."""

        def __init__(self, draw, boundaries=()):
            self._draw = draw
            self.boundaries = tuple(boundaries)

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def example(self, rng: random.Random, i: int):
            if i < len(self.boundaries):
                return self.boundaries[i]
            return self._draw(rng)

    def _f32(x: float) -> float:
        """Round-trip through float32 (hypothesis ``width=32`` semantics)."""
        return struct.unpack("f", struct.pack("f", x))[0]

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             boundaries=(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float, *,
                   allow_nan: bool = False, allow_infinity: bool = False,
                   width: int = 64) -> _Strategy:
            cast = _f32 if width == 32 else float
            lo, hi = cast(min_value), cast(max_value)

            def draw(r):
                return cast(r.uniform(lo, hi))

            mid = cast((lo + hi) / 2)
            return _Strategy(draw, boundaries=(lo, hi, cast(0.0) if lo <= 0.0 <= hi else mid))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda r: r.random() < 0.5, boundaries=(False, True))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements),
                             boundaries=(elements[0], elements[-1]))

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(r):
                n = r.randint(min_size, max_size)
                return [elements.draw(r) for _ in range(n)]

            smallest = [b for b in elements.boundaries[:max(min_size, 1)]]
            while len(smallest) < min_size:
                smallest.append(elements.boundaries[0])
            return _Strategy(draw, boundaries=(smallest[:max_size] or smallest,))

    strategies = _Strategies()

    def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_ignored):
        """Decorator-factory: records ``max_examples`` on the (possibly
        already ``given``-wrapped) function; everything else is a no-op."""

        def deco(fn):
            fn._propcheck_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Expand the property into a deterministic example deck.

        The wrapped test runs ``max_examples`` times (from ``@settings`` or
        the default): boundary combinations first, then seeded random draws.
        The RNG seed is derived from the test's qualified name, so a deck
        never shifts because an unrelated test was added."""

        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # hypothesis semantics: positional strategies bind to the
            # RIGHTMOST parameters; everything is passed by name so pytest
            # fixtures (leftmost params) compose correctly
            pos_names = names[len(names) - len(arg_strategies):]
            bound = dict(zip(pos_names, arg_strategies), **kw_strategies)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_propcheck_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    kw = {k: s.example(rng, i) for k, s in bound.items()}
                    try:
                        fn(*args, **kwargs, **kw)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on example {i}: {kw!r}") from e

            # pytest resolves fixtures from the signature (following
            # __wrapped__) — strip the strategy-bound params so they are not
            # mistaken for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=[
                p for k, p in sig.parameters.items() if k not in bound])
            return wrapper

        return deco
