"""Serving tier: cross-request fused command blocks (ci.sh --tier serve).

The ``repro.serving.ServingEngine`` claims, asserted deterministically:

1. **Fused ≡ sequential, bit-exact** — a drained batch of N concurrent
   requests fused into ONE ``aggregate_multi`` command block returns every
   caller exactly what its own one-query-one-dispatch block would have
   (integer-valued features, so any cross-request contamination is a hard
   mismatch), across impl × op and on the sharded mesh.
2. **Counted ratios** — ``gas.count_dispatches``: the fused drain issues
   ONE find for any N while the naive baseline issues N; on the 8-way mesh
   the fused drain traces ONE all_gather + ONE all_to_all
   (``launch.jaxpr_stats``), budgets imported from the ``SERVE_FETCH_*``
   tables in ``analysis.contracts`` — the single source of truth the
   ``serving_fetch/*`` lint contracts also pin.
3. **Trigger semantics** — the queue dispatches on size OR deadline,
   deterministic under an injected clock.
4. **Hot-vertex cache** — a hit returns bit-exactly the rows an SSD find
   returns, hits are masked out of the command block, and the LRU evicts.
5. **Tenant scatter-back** — the extended ``SegmentDescriptor`` tags every
   segment with its caller; results never cross callers.
6. **Health surface** — every dispatch lands in the ``StepMonitor`` and
   beats the ``Heartbeat``.
"""

import numpy as np
import pytest

import jax

from repro.analysis.contracts import (SERVE_CONTRACT_N,
                                      SERVE_FETCH_COLLECTIVES,
                                      SERVE_FETCH_FINDS)
from repro.core import cgtrans
from repro.graph import uniform_graph
from repro.serving import HotVertexCache, RequestQueue, ServeRequest, \
    ServingEngine

pytestmark = pytest.mark.serving

V, F = 64, 8


def _graph_feats(rng):
    g = uniform_graph(V, 6 * V, seed=3)
    indptr, indices, _ = g.to_csr()
    feats = rng.integers(-5, 6, (V, F)).astype(np.float32)
    return indptr, indices, feats


def _fake_clock(step=0.001):
    t = [0.0]

    def clock():
        t[0] += step
        return t[0]
    return clock


def _mk_engine(indptr, indices, feats, **kw):
    kw.setdefault("fanout", 5)
    kw.setdefault("max_batch", SERVE_CONTRACT_N)
    kw.setdefault("clock", _fake_clock())
    return ServingEngine(feats, indptr, indices, **kw)


def _submit_batch(eng, seeds_list):
    return [eng.submit(s, tenant=100 + j) for j, s in enumerate(seeds_list)]


# ---------------------------------------------------------------------------
# 1 + 2. fused ≡ sequential bit-exact, with counted finds-per-query
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("op", ["add", "max"])
def test_fused_equals_sequential_bitexact(rng, impl, op):
    indptr, indices, feats = _graph_feats(rng)
    seeds_list = ([[int(s)] for s in rng.integers(0, V, SERVE_CONTRACT_N - 2)]
                  + [rng.integers(0, V, 3).tolist(),
                     rng.integers(0, V, 2).tolist()])     # mixed batch sizes
    engines = {}
    for fuse in (True, False):
        eng = _mk_engine(indptr, indices, feats, impl=impl, op=op, fuse=fuse,
                         max_batch=len(seeds_list))
        rids = _submit_batch(eng, seeds_list)
        assert eng.poll() == len(seeds_list)
        engines[fuse] = (eng, [eng.result(r) for r in rids])

    ef, rf = engines[True]
    en, rn = engines[False]
    for a, b in zip(rf, rn):
        assert a.rid == b.rid and a.tenant == b.tenant
        np.testing.assert_array_equal(a.self_rows, b.self_rows)
        np.testing.assert_array_equal(a.agg_rows, b.agg_rows)

    # the counted claim: ONE find per fused drain, one PER QUERY naively
    n = len(seeds_list)
    assert ef.stats["find"] == SERVE_FETCH_FINDS["fused"]
    assert en.stats["find"] == SERVE_FETCH_FINDS["naive_per_query"] * n
    assert ef.finds_per_query() < en.finds_per_query()
    assert ef.stats["command_blocks"] == 1
    assert en.stats["command_blocks"] == n
    # batching amortizes the transmission, never the per-caller math
    assert ef.stats["reduce"] == en.stats["reduce"] == n


def test_self_rows_are_the_feature_rows(rng):
    """The K=1 lookup segment really is a row fetch: every caller's
    self_rows equal the feature table's rows for its seeds."""
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats)
    seeds_list = [rng.integers(0, V, 2).tolist()
                  for _ in range(SERVE_CONTRACT_N)]
    rids = _submit_batch(eng, seeds_list)
    eng.poll()
    for rid, seeds in zip(rids, seeds_list):
        np.testing.assert_array_equal(eng.result(rid).self_rows, feats[seeds])


# ---------------------------------------------------------------------------
# 3. trigger semantics (deterministic via the injected clock)
# ---------------------------------------------------------------------------

def test_size_trigger_fires_at_max_batch(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats, max_batch=4, max_delay_s=1e9)
    for j in range(3):
        eng.submit([j])
        assert eng.poll() == 0          # below batch size, far from deadline
    eng.submit([3])
    assert eng.poll() == 4              # size trigger
    assert len(eng.queue) == 0


def test_deadline_trigger_fires_on_oldest_wait(rng):
    indptr, indices, feats = _graph_feats(rng)
    t = [0.0]
    eng = _mk_engine(indptr, indices, feats, max_batch=64, max_delay_s=0.01,
                     clock=lambda: t[0])
    eng.submit([1])
    t[0] = 0.005
    assert eng.poll() == 0              # young request, small batch
    eng.submit([2])
    t[0] = 0.011                        # head-of-line passed the deadline
    assert eng.poll() == 2              # the WHOLE pending batch goes out
    assert eng.stats["command_blocks"] == 1


def test_flush_dispatches_in_max_batch_chunks(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats, max_batch=4, max_delay_s=1e9)
    rids = [eng.submit([j % V]) for j in range(10)]
    assert eng.flush() == 10
    assert eng.stats["command_blocks"] == 3     # 4 + 4 + 2
    for r in rids:
        eng.result(r)                           # everyone got an answer


def test_queue_validation():
    with pytest.raises(ValueError):
        RequestQueue(max_batch=0)
    with pytest.raises(ValueError):
        RequestQueue(max_delay_s=-1.0)
    q = RequestQueue(max_batch=2, clock=lambda: 0.0)
    assert not q.ready() and q.oldest_wait == 0.0
    q.push(ServeRequest(0, 0, np.asarray([1]), np.zeros((1, 2), np.int32),
                        np.ones((1, 2), bool), 0.0))
    assert not q.ready()


# ---------------------------------------------------------------------------
# 4. the hot-vertex cache
# ---------------------------------------------------------------------------

def test_cache_hit_returns_same_rows_as_ssd_find(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats, max_batch=4, cache_capacity=16)
    seeds = [3, 7, 9, 11]
    first = [eng.submit([s]) for s in seeds]
    eng.poll()
    cold = [eng.result(r) for r in first]
    second = [eng.submit([s]) for s in seeds]
    eng.poll()
    warm = [eng.result(r) for r in second]
    for s, a, b in zip(seeds, cold, warm):
        assert not a.from_cache.any() and b.from_cache.all()
        # the hit rows ARE the find rows, bit for bit
        np.testing.assert_array_equal(b.self_rows, a.self_rows)
        np.testing.assert_array_equal(b.self_rows, feats[[s]])
        # and the aggregation is untouched by the cache (fresh sample, but
        # same semantics — its rows come from the SSD block either way)
    snap = eng.cache.snapshot()
    assert snap["hits"] == 4 and snap["misses"] == 4
    assert snap["hit_rate"] == 0.5


def test_cache_does_not_change_results_vs_uncached(rng):
    """Cache on ≡ cache off, bit-exact — hits substitute rows a previous
    find returned, and features are static at serve time."""
    indptr, indices, feats = _graph_feats(rng)
    outs = {}
    for cap in (0, 8):
        eng = _mk_engine(indptr, indices, feats, max_batch=4,
                         cache_capacity=cap)
        rids = []
        for wave in range(3):                    # overlapping seed waves
            rids += [eng.submit([(3 * wave + j) % 16]) for j in range(4)]
        eng.flush()
        outs[cap] = [eng.result(r) for r in rids]
    for a, b in zip(outs[0], outs[8]):
        np.testing.assert_array_equal(a.self_rows, b.self_rows)
        np.testing.assert_array_equal(a.agg_rows, b.agg_rows)
    assert not any(r.from_cache.any() for r in outs[0])
    assert any(r.from_cache.any() for r in outs[8])


def test_cache_lru_eviction_and_counters():
    cache = HotVertexCache(2)
    cache.fill(np.asarray([1, 2]), np.ones((2, 3), np.float32))
    rows, hit = cache.lookup(np.asarray([1]), 3)     # 1 is now MRU
    assert hit.all()
    cache.fill(np.asarray([5]), np.zeros((1, 3), np.float32))
    assert 1 in cache and 5 in cache and 2 not in cache   # LRU 2 evicted
    assert cache.evictions == 1
    rows, hit = cache.lookup(np.asarray([2, 5]), 3)
    assert list(hit) == [False, True]
    assert cache.hits == 2 and cache.misses == 1
    with pytest.raises(ValueError):
        HotVertexCache(0)


# ---------------------------------------------------------------------------
# 5. tenant scatter-back
# ---------------------------------------------------------------------------

def test_tenant_tags_ride_the_descriptor(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats, max_batch=4)
    for j in range(4):
        eng.submit([j], tenant=500 + j)
    reqs = list(eng.queue._pending)
    _, desc, _, _ = eng._build_blocks(reqs)
    assert desc.tenants == (500, 500, 501, 501, 502, 502, 503, 503)
    for j in range(4):
        assert desc.segments_of(500 + j) == (2 * j, 2 * j + 1)
    # descriptor-level validation
    with pytest.raises(ValueError):
        cgtrans.segment_descriptor([(2, 1), (2, 3)], tenants=[7])
    with pytest.raises(ValueError):
        cgtrans.segment_descriptor([(2, 1)]).segments_of(0)


def test_tenant_scatter_back_never_crosses_callers(rng):
    """Every caller in a fused batch receives exactly what a PRIVATE engine
    (same sampling key) returns for its request — with per-caller DISTINCT
    features on every seed row, any cross-tenant leak is a hard mismatch."""
    indptr, indices, _ = _graph_feats(rng)
    # make every row globally unique so no two callers can alias
    feats = (np.arange(V, dtype=np.float32)[:, None] * 8
             + np.arange(F, dtype=np.float32)[None, :] + 1.0)
    eng = _mk_engine(indptr, indices, feats)
    seeds_list = [rng.integers(0, V, 2).tolist()
                  for _ in range(SERVE_CONTRACT_N)]
    rids = _submit_batch(eng, seeds_list)
    assert eng.poll() == SERVE_CONTRACT_N
    for j, (rid, seeds) in enumerate(zip(rids, seeds_list)):
        got = eng.result(rid)
        assert got.tenant == 100 + j
        # private replay: rid 0 of a fresh engine with sample_seed shifted
        # to this request's key draws the identical neighbor sample
        solo = _mk_engine(indptr, indices, feats, sample_seed=rid)
        srid = solo.submit(seeds, tenant=got.tenant)
        solo.flush()
        want = solo.result(srid)
        np.testing.assert_array_equal(got.self_rows, want.self_rows)
        np.testing.assert_array_equal(got.agg_rows, want.agg_rows)


# ---------------------------------------------------------------------------
# 6. health wiring
# ---------------------------------------------------------------------------

def test_health_surface_records_every_dispatch(rng, tmp_path):
    from repro.runtime.health import Heartbeat

    indptr, indices, feats = _graph_feats(rng)
    hb_path = str(tmp_path / "hb")
    eng = _mk_engine(indptr, indices, feats, max_batch=2,
                     heartbeat=Heartbeat(hb_path), cache_capacity=4)
    assert not Heartbeat.is_alive(hb_path)
    for j in range(4):
        eng.submit([j])
        eng.poll()
    assert Heartbeat.is_alive(hb_path)
    snap = eng.health_snapshot()
    assert snap["stats"]["dispatches"] == 2
    assert snap["monitor"]["steps"] == 2
    assert snap["queue_depth"] == 0
    assert 0.0 <= snap["cache"]["hit_rate"] <= 1.0
    assert snap["finds_per_query"] == pytest.approx(2 / 4)


def test_engine_input_validation(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats)
    with pytest.raises(ValueError):
        eng.submit([])
    with pytest.raises(ValueError):
        eng.submit([V])                  # out of range
    with pytest.raises(ValueError):
        ServingEngine(feats[None], indptr, indices)   # not (V, F)


# ---------------------------------------------------------------------------
# sharded cells: the collective counts on the fake 8-way mesh
# ---------------------------------------------------------------------------

_mesh_cells = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs the 8-device topology (ci.sh --tier serve sets XLA_FLAGS)")


@_mesh_cells
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mesh_fused_equals_sequential(rng, impl):
    from repro.launch.mesh import make_data_mesh

    indptr, indices, feats = _graph_feats(rng)
    mesh = make_data_mesh(8)
    seeds_list = [[int(s)] for s in rng.integers(0, V, SERVE_CONTRACT_N)]
    res = {}
    for fuse in (True, False):
        eng = _mk_engine(indptr, indices, feats, mesh=mesh, impl=impl,
                         fuse=fuse)
        rids = _submit_batch(eng, seeds_list)
        assert eng.poll() == SERVE_CONTRACT_N
        res[fuse] = (eng, [eng.result(r) for r in rids])
    for a, b in zip(res[True][1], res[False][1]):
        np.testing.assert_array_equal(a.self_rows, b.self_rows)
        np.testing.assert_array_equal(a.agg_rows, b.agg_rows)
    assert res[True][0].stats["find"] == 1
    assert res[False][0].stats["find"] == SERVE_CONTRACT_N


@_mesh_cells
def test_mesh_collectives_per_query_drop(rng):
    """The acceptance headline on the mesh: a queue of N≥8 single-seed
    requests dispatches ONE command block tracing ONE all_gather + ONE
    all_to_all — collectives-per-query 1/N vs the baseline's 1 — with the
    budgets imported from the contracts tables."""
    from repro.launch.jaxpr_stats import collective_counts
    from repro.launch.mesh import make_data_mesh

    indptr, indices, feats = _graph_feats(rng)
    mesh = make_data_mesh(8)
    eng = _mk_engine(indptr, indices, feats, mesh=mesh)
    for j in range(SERVE_CONTRACT_N):
        eng.submit([int((7 * j) % V)], tenant=j)
    reqs = list(eng.queue._pending)

    fn, args = eng.fetch_callable(reqs)
    fused = collective_counts(fn, *args)
    for coll, want in SERVE_FETCH_COLLECTIVES["fused"].items():
        assert fused[coll] == want, (coll, dict(fused))

    # the naive trace: one command block per request
    blocks = args[1]

    def naive(f, blocks_):
        outs = []
        for j in range(SERVE_CONTRACT_N):
            outs.extend(cgtrans.aggregate_multi(
                f, blocks_[2 * j:2 * j + 2], mesh=mesh, dataflow="cgtrans"))
        return tuple(outs)

    base = collective_counts(naive, args[0], blocks)
    for coll, per_q in SERVE_FETCH_COLLECTIVES["naive_per_query"].items():
        assert base[coll] == per_q * SERVE_CONTRACT_N, (coll, dict(base))
        # per-query strictly below the baseline
        assert fused[coll] / SERVE_CONTRACT_N < per_q


# ---------------------------------------------------------------------------
# 7. dtype fidelity + degenerate inputs (the PR-8 bugfix sweep)
# ---------------------------------------------------------------------------

def test_bf16_feature_table_served_bitexact(rng):
    """The engine serves whatever float dtype the table arrives in
    (``feats = np.asarray(feats, np.float32)`` used to silently promote
    bf16 tables, breaking the cache's bit-copy claim): results come back
    bf16, and cache-on ≡ cache-off bit for bit through REAL hits."""
    import jax.numpy as jnp

    indptr, indices, feats = _graph_feats(rng)
    bfeats = np.asarray(jnp.asarray(feats, jnp.bfloat16))
    res = {}
    seeds = rng.integers(0, V, 4)
    for cap in (0, V):
        eng = _mk_engine(indptr, indices, bfeats, cache_capacity=cap)
        assert eng.feat_dtype == bfeats.dtype
        rids = []
        for batch in (seeds, seeds):        # second batch = all repeats
            rids += _submit_batch(eng, [[int(s)] for s in batch])
            assert eng.flush() == len(batch)
        res[cap] = [eng.result(r) for r in rids]
        if cap:
            assert eng.cache.hits > 0                      # hits really hit
    for a, b in zip(res[0], res[V]):
        assert a.self_rows.dtype == bfeats.dtype
        np.testing.assert_array_equal(a.self_rows, b.self_rows)
        np.testing.assert_array_equal(a.agg_rows, b.agg_rows)


def test_non_float_and_f64_tables_coerce_to_f32(rng):
    """Integer tables (no ±inf identity domain) and f64 tables (the f64
    dtype-flow rule) still coerce — only SERVABLE float dtypes pass
    through."""
    indptr, indices, feats = _graph_feats(rng)
    for table in (feats.astype(np.int32), feats.astype(np.float64)):
        eng = _mk_engine(indptr, indices, table)
        assert eng.feat_dtype == np.float32
        rid = eng.submit([3])
        eng.flush()
        assert eng.result(rid).self_rows.dtype == np.float32


def test_engine_rejects_narrow_wire_on_baseline(rng):
    indptr, indices, feats = _graph_feats(rng)
    with pytest.raises(ValueError, match="baseline"):
        _mk_engine(indptr, indices, feats, dataflow="baseline", wire="bf16")
    with pytest.raises(ValueError, match="unknown wire format"):
        _mk_engine(indptr, indices, feats, wire="fp8")


def test_flush_empty_queue_is_a_noop(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats)
    assert eng.flush() == 0
    assert eng.stats["dispatches"] == 0     # no phantom dispatch recorded
    assert eng.stats["command_blocks"] == 0


def test_drain_limit_zero_returns_empty_without_side_effects(rng):
    indptr, indices, feats = _graph_feats(rng)
    eng = _mk_engine(indptr, indices, feats)
    eng.submit([1]), eng.submit([2])
    q = eng.queue
    assert q.drain(limit=0) == []
    assert len(q) == 2 and q.drained == 0 and q.submitted == 2
    assert eng.flush() == 2                 # the requests are still whole


@_mesh_cells
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_mesh_pad_rows_reduce_to_identity(rng, op):
    """``_shape_block`` pads each segment to a multiple of the shard count
    with all-masked rows; those rows must reduce to the op identity and be
    sliced off — NEVER leak into a caller's rows. All-negative features
    make a leak a hard mismatch (a pad row surfacing as 0 beats every real
    max), and B=1 seeds on the 8-way mesh force 7 pad rows per segment."""
    from repro.launch.mesh import make_data_mesh

    indptr, indices, feats = _graph_feats(rng)
    feats = -np.abs(feats) - 1.0            # strictly negative table
    seeds_list = [[int(s)] for s in rng.integers(0, V, 3)]  # 3 % 8 != 0 too
    res = {}
    for mesh in (make_data_mesh(8), None):
        eng = _mk_engine(indptr, indices, feats, mesh=mesh, op=op,
                         max_batch=len(seeds_list))
        rids = _submit_batch(eng, seeds_list)
        eng.flush()
        res[mesh is None] = [eng.result(r) for r in rids]
    for a, b in zip(res[False], res[True]):
        np.testing.assert_array_equal(a.self_rows, b.self_rows)
        np.testing.assert_array_equal(a.agg_rows, b.agg_rows)
        assert (a.self_rows < 0).all()      # no identity/zero leak
