"""GAS-engine graph algorithms vs networkx oracles (the paper's §3.4 suite)."""

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import algorithms as alg
from repro.graph import rmat, uniform_graph


def _nx_digraph(g, weights=False):
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n_vertices))
    for i in range(g.n_edges):
        w = float(g.weights[i]) if weights else 1.0
        u, v = int(g.src[i]), int(g.dst[i])
        if not G.has_edge(u, v) or G[u][v]["weight"] > w:
            G.add_edge(u, v, weight=w)
    return G


@pytest.mark.parametrize("seed", [0, 1])
def test_bfs_matches_networkx(seed):
    g = uniform_graph(80, 400, seed=seed)
    G = _nx_digraph(g)
    lengths = nx.single_source_shortest_path_length(G, 0)
    got = np.asarray(alg.bfs(jnp.asarray(g.src), jnp.asarray(g.dst), g.n_vertices, 0))
    for v in range(g.n_vertices):
        if v in lengths:
            assert got[v] == pytest.approx(lengths[v]), v
        else:
            assert np.isinf(got[v]), v


@pytest.mark.parametrize("seed", [0, 3])
def test_sssp_matches_networkx(seed):
    g = uniform_graph(60, 400, seed=seed, weights=True)
    G = _nx_digraph(g, weights=True)
    dist = nx.single_source_dijkstra_path_length(G, 0)
    got = np.asarray(alg.sssp(jnp.asarray(g.src), jnp.asarray(g.dst),
                              jnp.asarray(g.weights), g.n_vertices, 0))
    for v in range(g.n_vertices):
        if v in dist:
            np.testing.assert_allclose(got[v], dist[v], rtol=1e-5)
        else:
            assert np.isinf(got[v])


def test_cc_matches_networkx():
    g = uniform_graph(100, 120, seed=2)   # sparse → several components
    G = nx.Graph()
    G.add_nodes_from(range(g.n_vertices))
    G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    labels = np.asarray(alg.connected_components(
        jnp.asarray(g.src), jnp.asarray(g.dst), g.n_vertices))
    for comp in nx.connected_components(G):
        comp = sorted(comp)
        assert len({int(labels[v]) for v in comp}) == 1
        assert int(labels[comp[0]]) == comp[0]  # min-id labeling


def test_feature_embedding_equals_matmul(rng):
    g = uniform_graph(50, 300, seed=1, weights=True)
    feats = jnp.asarray(rng.standard_normal((50, 8)).astype(np.float32))
    out = alg.feature_embedding(jnp.asarray(g.src), jnp.asarray(g.dst),
                                jnp.asarray(g.weights), feats)
    A = np.zeros((50, 50), np.float32)
    for u, v, w in zip(g.src, g.dst, g.weights):
        A[v, u] += w
    np.testing.assert_allclose(np.asarray(out), A @ np.asarray(feats),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                min_size=1, max_size=200))
def test_gas_sort_property(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    got = np.asarray(alg.gas_sort(x))
    np.testing.assert_allclose(got, np.sort(np.asarray(xs, np.float32)),
                               atol=1e-5)


def test_gas_sort_on_pallas_impl(rng):
    x = jnp.asarray(rng.standard_normal(100).astype(np.float32))
    got = np.asarray(alg.gas_sort(x, impl="pallas"))
    np.testing.assert_allclose(got, np.sort(np.asarray(x)), atol=1e-5)


def test_bfs_pallas_impl_matches_xla():
    g = uniform_graph(64, 256, seed=5)
    a = alg.bfs(jnp.asarray(g.src), jnp.asarray(g.dst), 64, 0, impl="xla")
    b = alg.bfs(jnp.asarray(g.src), jnp.asarray(g.dst), 64, 0, impl="pallas")
    np.testing.assert_allclose(np.nan_to_num(np.asarray(a), posinf=1e9),
                               np.nan_to_num(np.asarray(b), posinf=1e9))
