"""Per-arch smoke tests (reduced configs) + prefill/decode consistency.

Every assigned architecture: instantiate the reduced same-family config, run
one forward + one train step on CPU, assert output shapes and finiteness.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.common.config import TrainConfig
from repro.common.schema import init_params
from repro.models import transformer as T
from repro.train import init_state, make_train_step


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 4)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq, cfg.d_model))
    if cfg.vision_seq:
        b["vision"] = jax.random.normal(ks[3], (B, cfg.vision_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.smoke_config(arch)
    key = jax.random.PRNGKey(0)
    tc = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-3)
    state = init_state(cfg, tc, key, max_seq=16)
    batch = _batch(cfg, key)
    step = jax.jit(make_train_step(cfg, tc))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["total_loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # a second step with different data still finite
    _, m2 = step(new_state, _batch(cfg, jax.random.PRNGKey(1)))
    assert np.isfinite(float(m2["total_loss"]))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(token S-1 | prefill of S-1) == full forward's last logits."""
    cfg = configs.smoke_config(arch)
    key = jax.random.PRNGKey(0)
    S = 17
    params = init_params(T.model_schema(cfg, max_seq=S), key)
    batch = _batch(cfg, key, B=2, S=S)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    logits_pre, caches = T.prefill(params, pre, cfg, cache_len=S)
    logits_dec, _ = T.decode_step(
        params, batch["tokens"][:, S - 1:], caches, jnp.array(S - 1, jnp.int32), cfg)
    logits_full, _ = T.prefill(params, batch, cfg, cache_len=S)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma3-12b", "mamba2-780m"])
def test_multi_step_decode_matches_full_forward(arch):
    """Decode 4 tokens autoregressively == sliced full-sequence forward."""
    cfg = configs.smoke_config(arch)
    key = jax.random.PRNGKey(1)
    S, tail = 20, 4
    params = init_params(T.model_schema(cfg, max_seq=S), key)
    batch = _batch(cfg, key, B=1, S=S)
    toks = batch["tokens"]
    pre = dict(batch)
    pre["tokens"] = toks[:, :S - tail]
    _, caches = T.prefill(params, pre, cfg, cache_len=S)
    outs = []
    for i in range(tail):
        pos = jnp.array(S - tail + i, jnp.int32)
        logits, caches = T.decode_step(params, toks[:, S - tail + i:S - tail + i + 1],
                                       caches, pos, cfg)
        outs.append(logits)
    # compare the final step against the full forward
    full, _ = T.prefill(params, batch, cfg, cache_len=S)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(full),
                               atol=5e-2, rtol=5e-2)


def test_param_counts_match_assigned_scale():
    """Full configs land near their advertised parameter scales."""
    from repro.common.schema import count_params
    expect = {"qwen1.5-0.5b": (0.3e9, 0.7e9),
              "gemma2-2b": (2.0e9, 3.3e9),
              "mamba2-780m": (0.6e9, 1.0e9),
              "phi3-medium-14b": (12e9, 16e9),
              "gemma3-12b": (10e9, 14e9),
              "deepseek-moe-16b": (14e9, 19e9),
              "llama-3.2-vision-90b": (80e9, 95e9),
              "whisper-base": (0.05e9, 0.12e9)}
    for arch, (lo, hi) in expect.items():
        cfg = configs.get_config(arch)
        n = count_params(T.model_schema(cfg, max_seq=448))
        assert lo <= n <= hi, (arch, n)


def test_layer_pattern_expansion():
    cfg = configs.get_config("gemma3-12b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 48
    assert kinds.count("attn") == 8 and kinds.count("local") == 40
    assert kinds[5] == "attn" and kinds[0] == "local"

    vis = configs.get_config("llama-3.2-vision-90b")
    kinds = vis.layer_kinds()
    assert kinds.count("cross") == 20

    rg = configs.get_config("recurrentgemma-2b")
    kinds = rg.layer_kinds()
    assert kinds.count("rglru") == 18 and kinds.count("local") == 8

    ds = configs.get_config("deepseek-moe-16b")
    kinds = ds.layer_kinds()
    assert kinds[0] == "attn" and kinds.count("moe") == 27


def test_stack_layout_block_repeat():
    cfg = configs.get_config("mamba2-780m")
    lay = T.stack_layout(cfg)
    assert lay.n_blocks * len(lay.pattern) + len(lay.prefix) + len(lay.suffix) == 48
    assert len(lay.pattern) == cfg.block_repeat  # grouped scan blocks
    # grouping is a pure layout choice: any repeat covers all 48 layers
    for rep in (1, 2, 4):
        c = dataclasses.replace(cfg, block_repeat=rep)
        l2 = T.stack_layout(c)
        assert l2.n_blocks * len(l2.pattern) + len(l2.prefix) + len(l2.suffix) == 48
