"""SSD (mamba2) and RG-LRU against brute-force sequential oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.common.config import ModelConfig
from repro.common.schema import init_params
from repro.models import griffin, ssm


def _ssd_naive(x, dt, A, Bm, Cm):
    """Sequential recurrence oracle: h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    s = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, S, H, P), np.float64)
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    A = np.asarray(A, np.float64)
    Bm = np.asarray(Bm, np.float64)
    Cm = np.asarray(Cm, np.float64)
    for t in range(S):
        da = np.exp(dt[:, t] * A[None])                       # (B,H)
        upd = np.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        s = s * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], s)
    return ys, s


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
def test_ssd_chunked_matches_naive(rng, chunk):
    B, S, H, P, N = 2, 32, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.random((B, S, H)).astype(np.float32) * 0.5)
    A = jnp.asarray(-rng.random(H).astype(np.float32) * 2)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    y, s = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, s_ref = _ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), s_ref, atol=2e-4, rtol=1e-3)


def test_ssd_chunk_size_invariance(rng):
    B, S, H, P, N = 1, 24, 2, 4, 3
    args = (jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32)),
            jnp.asarray(rng.random((B, S, H)).astype(np.float32)),
            jnp.asarray(-rng.random(H).astype(np.float32)),
            jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32)),
            jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32)))
    y1, s1 = ssm.ssd_chunked(*args, 6)   # 24 % 6 == 0
    y2, s2 = ssm.ssd_chunked(*args, 7)   # padding path
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=1e-3)


def _tiny_ssm_cfg():
    return ModelConfig(name="t", family="ssm", n_layers=2, d_model=16,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab=32,
                       pattern=("ssd",), ssm_state=4, ssm_head_dim=4,
                       ssm_chunk=4, ssm_expand=2, compute_dtype="float32",
                       remat="none")


def test_ssd_decode_matches_full(rng):
    cfg = _tiny_ssm_cfg()
    p = init_params(ssm.ssd_schema(cfg), jax.random.PRNGKey(0))
    S = 10
    x = jnp.asarray(rng.standard_normal((2, S, cfg.d_model)).astype(np.float32))
    full = ssm.ssd_apply(p, x, cfg)
    out_pre, cache = ssm.ssd_apply(p, x[:, :S - 1], cfg, return_cache=True)
    out_dec, _ = ssm.ssd_decode(p, x[:, S - 1:], cache, cfg)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-3, rtol=1e-2)


def _rglru_naive(p, xb_conv, gate, cfg):
    """Sequential RG-LRU oracle on the post-conv x-branch."""
    a, bx = griffin._gates(p, xb_conv)
    a = np.asarray(a, np.float64)
    bx = np.asarray(bx, np.float64)
    B, S, W = a.shape
    h = np.zeros((B, W), np.float64)
    hs = np.zeros((B, S, W), np.float64)
    for t in range(S):
        h = a[:, t] * h + bx[:, t]
        hs[:, t] = h
    return hs


def test_rglru_assoc_scan_matches_sequential(rng):
    cfg = ModelConfig(name="t", family="hybrid", n_layers=3, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab=32, head_dim=8,
                      pattern=("rglru",), lru_width=12,
                      compute_dtype="float32", remat="none")
    p = init_params(griffin.rglru_schema(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 9, 16)).astype(np.float32))
    # full-path output
    out = griffin.rglru_apply(p, x, cfg)
    # manual: replicate the internals with a sequential scan
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]), approximate=True)
    xb, _ = griffin._conv(xb, p["conv_w"], p["conv_b"])
    hs = _rglru_naive(p, xb, gate, cfg)
    want = np.einsum("bsw,wd->bsd", hs * np.asarray(gate, np.float64), np.asarray(p["w_out"], np.float64))
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4, rtol=1e-3)


def test_rglru_decode_matches_full(rng):
    cfg = ModelConfig(name="t", family="hybrid", n_layers=3, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab=32, head_dim=8,
                      pattern=("rglru",), lru_width=12,
                      compute_dtype="float32", remat="none")
    p = init_params(griffin.rglru_schema(cfg), jax.random.PRNGKey(0))
    S = 8
    x = jnp.asarray(rng.standard_normal((1, S, 16)).astype(np.float32))
    full = griffin.rglru_apply(p, x, cfg)
    _, cache = griffin.rglru_apply(p, x[:, :S - 1], cfg, return_cache=True)
    out, _ = griffin.rglru_decode(p, x[:, S - 1:], cache, cfg)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(3, 20))
def test_property_ssd_state_decay_bounded(seed, s):
    """With dt ≥ 0 and A < 0, the state stays bounded by the input mass."""
    rng = np.random.default_rng(seed)
    B, H, P, N = 1, 2, 3, 4
    x = jnp.asarray(rng.standard_normal((B, s, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.random((B, s, H)).astype(np.float32))
    A = jnp.asarray(-rng.random(H).astype(np.float32) - 0.1)
    Bm = jnp.asarray(rng.standard_normal((B, s, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, s, N)).astype(np.float32))
    _, state = ssm.ssd_chunked(x, dt, A, Bm, Cm, 8)
    # |state| ≤ Σ_t dt_t·max|B_t|·max|x_t| (decay factors ≤ 1)
    bound = float(jnp.sum(
        dt.max(-1) * jnp.abs(Bm).max(-1) *
        jnp.abs(x).reshape(x.shape[0], s, -1).max(-1))) + 1.0
    assert float(jnp.abs(state).max()) <= bound
    assert bool(jnp.isfinite(state).all())
