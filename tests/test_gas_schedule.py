"""Scheduler tier: the destination-binned edge schedule + fused kernel.

What the tentpole must guarantee (``scripts/ci.sh --tier sched`` runs this
file alone):

1. **Schedule invariants** — ``schedule_edges`` is a stable counting sort
   by destination row block: the permutation is a bijection, bins ascend,
   intra-bin edge order is preserved, dead (masked/out-of-range) edges sort
   last; the banded bounds and the (W, 4) work list cover every live
   (row-block × edge-tile) round exactly once and init every row block.
2. **Fused kernel ≡ oracle** — ``gas_scatter_fused`` (mask via dead-row
   convention, weights via match-line scaling, scheduled banded walk or
   unscheduled dense grid) matches ``gas_scatter_weighted_ref``.
3. **Schedule invariance, bit-exact** — scheduled ≡ unscheduled for values
   AND gradients on integer-valued data (float addition is associative on
   integers, so any dropped/duplicated/misrouted contribution is a hard
   bitwise failure, not tolerance noise): permutation invariance of the
   scatter forward, un-permutation of cotangents through the ``take``
   transpose in the backward.
4. **The idle-skip actually skips** — on a clustered graph the scheduled
   walk executes a fraction of the dense grid's rounds; the K=1 request
   path never dispatches the kernel at all (a single-sample request is a
   pure find).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import cgtrans, gas
from repro.kernels.gas_scatter import kernel as K
from repro.kernels.gas_scatter import ops as gas_ops
from repro.kernels.gas_scatter import (gas_scatter_weighted_ref,
                                       schedule_skip_stats)

OPS = ("add", "max", "min", "or")


def _nan2num(a):
    return np.nan_to_num(np.asarray(a, np.float32), posinf=9e9, neginf=-9e9)


# ---------------------------------------------------------------------------
# 1. schedule invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(1, 700),
    r=st.integers(1, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_schedule_is_stable_binned_permutation(e, r, seed):
    rng = np.random.default_rng(seed)
    dst = rng.integers(-4, r + 4, e).astype(np.int32)
    mask = rng.random(e) < 0.8
    sched = gas_ops.schedule_edges(jnp.asarray(dst), jnp.asarray(mask), r)
    perm = np.asarray(sched.perm)
    assert sorted(perm.tolist()) == list(range(e)), "perm must be a bijection"

    n_blocks = -(-r // K.ROW_BLOCK)
    live = mask & (dst >= 0) & (dst < r)
    bins = np.where(live, dst // K.ROW_BLOCK, n_blocks)
    sorted_bins = bins[perm]
    assert (np.diff(sorted_bins) >= 0).all(), "bins must ascend (binned)"
    # stability: edges of one bin keep their original relative order
    for b in np.unique(sorted_bins):
        idx = perm[sorted_bins == b]
        assert (np.diff(idx) > 0).all(), f"bin {b} reordered (unstable sort)"


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(1, 700),
    r=st.integers(1, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_work_list_covers_live_rounds_exactly(e, r, seed):
    """The banded walk must visit every live (row-block, tile) round at
    least once (a missed round silently drops aggregation work), never
    visit the same round twice (double-counts a scatter-add), and init
    every row block exactly once (uninitialized output rows are garbage)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(-4, r + 4, e).astype(np.int32)
    mask = rng.random(e) < 0.8
    sched = gas_ops.schedule_edges(jnp.asarray(dst), jnp.asarray(mask), r)
    perm = np.asarray(sched.perm)
    et = K.edge_tile("add", True)
    n_blocks = -(-r // K.ROW_BLOCK)

    live = mask & (dst >= 0) & (dst < r)
    bins = np.where(live, dst // K.ROW_BLOCK, n_blocks)[perm]
    bins = np.pad(bins, (0, (-e) % et), constant_values=n_blocks)
    tiles = bins.reshape(-1, et)
    needed = {(b, t) for t in range(tiles.shape[0])
              for b in np.unique(tiles[t][tiles[t] < n_blocks])}

    work = np.asarray(sched.work)
    visited = [(int(rb), int(t)) for rb, t, lv, _ in work if lv]
    assert len(visited) == len(set(visited)), "round visited twice"
    assert needed <= set(visited), f"missed rounds: {needed - set(visited)}"
    inits = work[work[:, 3] == 1][:, 0]
    assert sorted(inits.tolist()) == list(range(n_blocks)), (
        "every row block must be initialized exactly once")
    assert (np.diff(work[:, 0]) >= 0).all(), (
        "work must walk row blocks in order (output revisit contract)")


# ---------------------------------------------------------------------------
# 2. fused kernel ≡ oracle (scheduled and unscheduled)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(1, 400),
    r=st.integers(1, 300),
    op=st.sampled_from(("add", "max", "min")),
    scheduled=st.sampled_from((False, True)),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_fused_matches_weighted_oracle(e, r, op, scheduled, seed):
    rng = np.random.default_rng(seed)
    F = 5
    dst = jnp.asarray(rng.integers(-3, r + 3, e).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((e, F)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(e).astype(np.float32))
    m = jnp.asarray(rng.random(e) < 0.7)
    weights = w if op == "add" else None
    want = gas_scatter_weighted_ref(dst, vals, weights, m, r, op=op)
    if scheduled:
        sched = gas_ops.schedule_edges(dst, m, r)
        p = sched.perm
        got = gas_ops.gas_scatter_fused(
            dst[p], vals[p], None if weights is None else weights[p], m[p],
            r, op=op, schedule=sched)
    else:
        got = gas_ops.gas_scatter_fused(dst, vals, weights, m, r, op=op)
    np.testing.assert_allclose(_nan2num(got), _nan2num(want),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# 3. scheduled ≡ unscheduled, bit-exact (values and gradients)
# ---------------------------------------------------------------------------

def _int_edges(rng, P_, part, e, op):
    """Integer-valued inputs: exact arithmetic → bitwise assertions."""
    f = rng.integers(-8, 9, (P_, part, 4)).astype(np.float32)
    if op == "or":
        f = (f > 0).astype(np.int32)
    src = rng.integers(0, part, (P_, e)).astype(np.int32)
    dst = rng.integers(0, P_ * part, (P_, e)).astype(np.int32)
    w = rng.integers(-3, 4, (P_, e)).astype(np.float32)
    m = rng.random((P_, e)) < 0.8
    return tuple(jnp.asarray(x) for x in (f, src, dst, w, m))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("op", OPS)
def test_edges_scheduled_bit_exact_with_unscheduled(rng, impl, op):
    f, src, dst, w, m = _int_edges(rng, 2, 32, 213, op)
    outs = [cgtrans.aggregate_edges(f, src, dst, w, m, mesh=None, op=op,
                                    impl=impl, scheduled=s)
            for s in (False, True)]
    np.testing.assert_array_equal(_nan2num(outs[0]), _nan2num(outs[1]))


@pytest.mark.parametrize("op", ["add", "max"])
def test_edges_scheduled_grads_bit_exact(rng, op):
    """Cotangents must un-permute exactly through the schedule's ``take``
    transpose: d_feats AND d_weights equal scheduled vs not — bitwise for
    ``add`` (integer-valued contributions are order-exact). For ``max`` the
    per-edge cotangent is itself bitwise order-independent, but a tie's
    share g/ties can be a non-dyadic rational (g/3), so the un-permuting
    scatter-SUM of shares into d_feats is compared at float-ulp tolerance
    instead."""
    f, src, dst, w, m = _int_edges(rng, 2, 16, 147, op)
    u = jnp.asarray(rng.integers(-3, 4, (2, 16, 4)).astype(np.float32))

    def loss(feats, wts, scheduled):
        out = cgtrans.aggregate_edges(feats, src, dst, wts, m, mesh=None,
                                      op=op, impl="pallas",
                                      scheduled=scheduled)
        return jnp.sum(jnp.where(jnp.isfinite(out), out, 0.0) * u)

    g_off = jax.grad(lambda a, b: loss(a, b, False), argnums=(0, 1))(f, w)
    g_on = jax.grad(lambda a, b: loss(a, b, True), argnums=(0, 1))(f, w)
    if op == "add":
        np.testing.assert_array_equal(np.asarray(g_off[0]),
                                      np.asarray(g_on[0]))
        np.testing.assert_array_equal(np.asarray(g_off[1]),
                                      np.asarray(g_on[1]))
    else:
        np.testing.assert_allclose(np.asarray(g_off[0]), np.asarray(g_on[0]),
                                   atol=1e-6, rtol=1e-6)
        # weights are not consumed by the compare ops: exact zeros both ways
        np.testing.assert_array_equal(np.asarray(g_off[1]),
                                      np.asarray(g_on[1]))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 13),
    k=st.integers(1, 6),
    chunk=st.sampled_from((None, 1, 3)),
    op=st.sampled_from(OPS),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_sampled_scheduled_bit_exact(b, k, chunk, op, seed):
    """scheduled ∈ {on, off} × chunking on the sampled path (its schedule
    is the sort-free assume_sorted band): bit-exact on integer data."""
    rng = np.random.default_rng(seed)
    P_, part = 2, 16
    f = rng.integers(-8, 9, (P_, part, 3)).astype(np.float32)
    if op == "or":
        f = (f > 0).astype(np.int32)
    f = jnp.asarray(f)
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, b, k)).astype(np.int32))
    mk = jnp.asarray(rng.random((P_, b, k)) < 0.7)
    outs = [cgtrans.aggregate_sampled(f, nb, mk, mesh=None, op=op,
                                      impl="pallas", scheduled=s,
                                      request_chunk=chunk)
            for s in (False, True)]
    np.testing.assert_array_equal(_nan2num(outs[0]), _nan2num(outs[1]))


def test_gcn_forward_full_hoisted_schedule_matches_xla(rng):
    """The multi-layer reuse path: one ``build_edge_schedule`` serves every
    layer of ``gcn_forward_full`` and matches the xla forward."""
    from repro.common.schema import init_params
    from repro.core.gcn import GCNConfig, gcn_forward_full, gcn_schema

    P_, part, F, e = 2, 32, 8, 301
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, part, (P_, e)).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, P_ * part, (P_, e)).astype(np.int32))
    w = jnp.asarray(rng.standard_normal((P_, e)).astype(np.float32))
    m = jnp.asarray(rng.random((P_, e)) < 0.8)
    outs = {}
    for impl in ("xla", "pallas"):
        cfg = GCNConfig(n_features=F, hidden=16, n_classes=4, impl=impl)
        params = init_params(gcn_schema(cfg), jax.random.PRNGKey(0))
        outs[impl] = gcn_forward_full(params, feats, src, dst, w, m, cfg,
                                      mesh=None)
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["xla"]), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# 4. the idle-skip actually skips
# ---------------------------------------------------------------------------

def test_idle_skip_counter_on_clustered_graph():
    """Paper Fig 11(c): on a clustered graph the scheduled walk executes a
    small fraction of the dense R×T rounds, and strictly fewer than the
    unscheduled occupancy leaves live. Uniform graphs barely skip
    unscheduled — the schedule is what makes the idle-skip buffer fire."""
    from repro.graph import clustered_graph, partition_by_src, uniform_graph
    from repro.kernels.gas_scatter import dense_skip_stats

    V, E, ways = 1024, 16384, 8
    stats = {}
    for kind, g in (("clustered", clustered_graph(
                        V, E, n_clusters=V // K.ROW_BLOCK, p_intra=0.9,
                        seed=7)),
                    ("uniform", uniform_graph(V, E, seed=7))):
        # locality lives in the PARTITIONED per-shard streams (the src-owner
        # layout the dataflows actually aggregate), not generation order
        pg = partition_by_src(g, ways)
        live_s = live_u = total = 0
        for p in range(ways):
            dst = jnp.asarray(pg.dst[p])
            mask = jnp.asarray(pg.mask[p])
            ls, ts = schedule_skip_stats(
                gas_ops.schedule_edges(dst, mask, V))
            live_s += ls
            total += ts
            live_u += dense_skip_stats(dst, mask, V)[0]
        stats[kind] = (live_s, live_u, total)

    for kind, (live_s, live_u, total) in stats.items():
        assert live_s < live_u, (kind, stats)          # schedule skips MORE
        assert total - live_s > 0, (kind, stats)       # …and skips at all
    # scheduled round count is locality-driven: ≤ T + blocks - 1 ≪ total
    live_s, live_u, total = stats["clustered"]
    assert live_s <= total // 4, stats
    # without the schedule, only clustering skips anything much
    assert stats["clustered"][1] < stats["uniform"][1], stats


def test_k1_request_is_a_pure_find(rng, monkeypatch):
    """A K=1 request block (the row-lookup path) must not pay a kernel
    round-trip: the seed scatter is the identity permutation. The gather's
    VJP still scatters through the kernel — that is asserted by
    tests/test_cgtrans_grad.py; here we pin the forward."""
    calls = {"n": 0}
    real = gas_ops.gas_scatter_fused

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(gas_ops, "gas_scatter_fused", counting)
    feats = jnp.asarray(rng.standard_normal((2, 16, 4)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, 32, (2, 9, 1)).astype(np.int32))
    mk = jnp.asarray(rng.random((2, 9, 1)) < 0.8)
    out_p = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None, impl="pallas")
    assert calls["n"] == 0, "K=1 forward must not dispatch the kernel"
    out_x = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None, impl="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("op", OPS)
def test_k1_find_matches_k2_duplicate_semantics(rng, op):
    """Regression: the K=1 pure-find shortcut must keep the SCATTER path's
    op semantics — notably op="or"'s int-cast + clamp-at-0 normalization
    (an early version passed raw values through, so a row of -1.0/0.5
    leaked instead of reading 0). Duplicating the single sample to K=2
    forces the scatter path; every op must agree on every impl."""
    P_, part, F, B = 2, 16, 3, 7
    f = rng.standard_normal((P_, part, F)).astype(np.float32)
    if op == "or":
        f = f.round(1)                 # keep fractional + negative values
    f = jnp.asarray(f)
    nb1 = jnp.asarray(rng.integers(0, P_ * part, (P_, B, 1)).astype(np.int32))
    mk1 = jnp.asarray(rng.random((P_, B, 1)) < 0.7)
    nb2 = jnp.concatenate([nb1, nb1], axis=-1)       # same sample, twice
    mk2 = jnp.concatenate([mk1, mk1], axis=-1)
    for impl in ("xla", "pallas"):
        o1 = cgtrans.aggregate_sampled(f, nb1, mk1, mesh=None, op=op,
                                       impl=impl)
        o2 = cgtrans.aggregate_sampled(f, nb2, mk2, mesh=None, op=op,
                                       impl=impl)
        np.testing.assert_allclose(_nan2num(o1), _nan2num(o2),
                                   atol=1e-5, rtol=1e-5, err_msg=(op, impl))
