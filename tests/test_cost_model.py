"""Paper-claim bands for the cost model (the quantitative reproduction)."""

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.graph import rmat


def test_fig15_loading_reduction_is_fanout():
    rows = cm.fig15_table()
    for r in rows:
        assert r["load_reduction"] == pytest.approx(50.0)  # paper: 50×


def test_fig15_amazon_request_caveat():
    """Amazon/OGBN (F=32): request traffic comparable to payload — the
    paper's 'except for Amazon' caveat emerges from the model."""
    rows = {r["dataset"]: r for r in cm.fig15_table()}
    assert rows["Amazon"]["load_reduction_with_requests"] < 20
    assert rows["Reddit"]["load_reduction_with_requests"] > 35


def test_fig15_speedup_bands():
    rows = cm.fig15_table()
    vs_gcnax = np.mean([r["speedup_vs_gcnax"] for r in rows])
    vs_insider = np.mean([r["speedup_vs_insider"] for r in rows])
    assert 3.0 <= vs_gcnax <= 4.2      # paper: 3.6× average
    assert 2.0 <= vs_insider <= 2.9    # paper: 2.4× average


def test_fig16c_breakdown_band():
    bd = cm.fig16c_breakdown()
    cut = 1 - bd["graphic"]["total"] / bd["gcnax"]["total"]
    assert 0.6 <= cut <= 0.8           # paper: ~70% latency reduction
    # in-SSD aggregation is slower than the ASIC combination engine (paper)
    assert bd["graphic"]["agg"] >= 0
    assert bd["insider"]["agg"] > bd["graphic"]["agg"] * 10


def test_fig14_area_efficiency():
    area = cm.fig14_area()
    assert area["area_eff_vs_insider"] == pytest.approx(5.0)  # paper: 5×
    assert area["gas_mm2"] < area["digital_mm2"] < area["insider_mm2"]


def _bfs_levels(indptr, indices, n, src=0):
    lev = np.full(n, -1, np.int64)
    lev[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        nxt = []
        for v in frontier:
            for u in indices[indptr[v]:indptr[v + 1]]:
                if lev[u] < 0:
                    lev[u] = d + 1
                    nxt.append(u)
        frontier = nxt
        d += 1
    return lev


def test_fig16a_idle_skip_bands():
    g = rmat(12, 16, seed=3)
    indptr, indices, _ = g.to_csr()
    lev = _bfs_levels(indptr, indices, g.n_vertices)
    r = cm.simulate_gas_traversal(indptr, lev, cache_mb=1.0)
    assert 0.3 <= r["speedup_no_skip"] <= 1.3      # paper: 0.4–1×
    assert 4.0 <= r["speedup_idle_skip"] <= 25.0   # paper avg: 10.1×
    assert r["speedup_idle_skip"] > 5 * r["speedup_no_skip"]


def test_fig16b_cache_scaling_trend():
    """Speedup increases with cache size; still >1 when graph ≫ cache."""
    g = rmat(14, 16, seed=3)
    indptr, indices, _ = g.to_csr()
    lev = _bfs_levels(indptr, indices, g.n_vertices)
    speeds = [cm.simulate_gas_traversal(indptr, lev, cache_mb=mb)["speedup_idle_skip"]
              for mb in (0.5, 1.0, 2.0, 4.0)]
    assert all(a < b for a, b in zip(speeds, speeds[1:]))
    assert speeds[0] > 1.0


def test_monotonicity_properties():
    k = cm.C
    w1 = cm.SageWorkload(batch=1024, fanout=50, n_features=64)
    w2 = cm.SageWorkload(batch=1024, fanout=50, n_features=128)
    assert cm.load_bytes(w2, k, "baseline") > cm.load_bytes(w1, k, "baseline")
    assert cm.latency(w2, "graphic")["total"] > cm.latency(w1, "graphic")["total"]
    # compression never hurts loading
    for w in (w1, w2):
        assert cm.load_bytes(w, k, "cgtrans") < cm.load_bytes(w, k, "baseline")
