"""Graph substrate: COO/CSR, partitioner invariants, R-MAT, samplers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.graph import (COOGraph, device_sample, host_sample, partition_by_src,
                         rmat, table2_like, uniform_graph)


def test_csr_roundtrip():
    g = uniform_graph(40, 200, seed=0)
    indptr, indices, _ = g.to_csr()
    assert indptr[-1] == g.n_edges
    # every edge present exactly once
    pairs = set()
    for u in range(40):
        for v in indices[indptr[u]:indptr[u + 1]]:
            pairs.add((u, int(v)))
    assert len(pairs) <= g.n_edges
    orig = list(zip(g.src.tolist(), g.dst.tolist()))
    for u, v in orig:
        assert (u, v) in pairs


def test_rmat_properties():
    g = rmat(8, 4, seed=1)
    assert g.n_vertices == 256 and g.n_edges == 1024
    g2 = rmat(8, 4, seed=1)
    np.testing.assert_array_equal(g.src, g2.src)   # deterministic
    # power-lawish: max out-degree well above mean
    deg = g.degree_out()
    assert deg.max() > 4 * deg.mean()


@pytest.mark.parametrize("n_parts", [2, 4, 8])
def test_partition_invariants(n_parts):
    g = uniform_graph(100, 700, seed=2, weights=True, n_features=6)
    pg = partition_by_src(g, n_parts)
    # 1. every real edge appears exactly once, in its src owner's partition
    cnt = int(pg.mask.sum())
    assert cnt == g.n_edges
    for p in range(n_parts):
        m = pg.mask[p]
        glob_src = pg.src[p][m] + p * pg.part_size
        assert np.all(glob_src // pg.part_size == p)
    # 2. edge multiset conservation
    got = set()
    for p in range(n_parts):
        m = pg.mask[p]
        for s, d in zip(pg.src[p][m] + p * pg.part_size, pg.dst[p][m]):
            got.add((int(s), int(d)))
    want = set(zip(g.src.tolist(), g.dst.tolist()))
    assert got == want
    # 3. features land on the owner shard
    for p in range(n_parts):
        lo = p * pg.part_size
        hi = min(lo + pg.part_size, g.n_vertices)
        if lo < g.n_vertices:
            np.testing.assert_array_equal(pg.features[p, :hi - lo], g.features[lo:hi])


def test_host_sampler_neighbors_are_real(rng):
    g = uniform_graph(50, 400, seed=3)
    indptr, indices, _ = g.to_csr()
    seeds = rng.integers(0, 50, 20).astype(np.int64)
    nbrs, mask = host_sample(g, seeds, 7, seed=1)
    for i, s in enumerate(seeds):
        real = set(indices[indptr[s]:indptr[s + 1]].tolist())
        for j in range(7):
            if mask[i, j]:
                assert int(nbrs[i, j]) in real
            else:
                assert int(nbrs[i, j]) == s  # isolated → self


def test_device_sampler_matches_semantics(rng):
    g = uniform_graph(50, 400, seed=4)
    indptr, indices, _ = g.to_csr()
    seeds = jnp.asarray(rng.integers(0, 50, 16).astype(np.int32))
    nbrs, mask = device_sample(jnp.asarray(indptr.astype(np.int32)),
                               jnp.asarray(indices), seeds, 5,
                               jax.random.PRNGKey(0))
    nbrs, mask = np.asarray(nbrs), np.asarray(mask)
    for i, s in enumerate(np.asarray(seeds)):
        real = set(indices[indptr[s]:indptr[s + 1]].tolist())
        for j in range(5):
            if mask[i, j]:
                assert nbrs[i, j] in real


def test_table2_like_ratios():
    g = table2_like("Amazon", scale_down=1e5)
    assert g.features is not None and g.features.shape[1] == 32
    assert g.n_edges > 0 and g.n_vertices > 0


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 60), m=st.integers(1, 300), p=st.sampled_from([2, 4]),
       seed=st.integers(0, 1000))
def test_property_partition_conserves_edges(n, m, p, seed):
    g = uniform_graph(n, m, seed=seed)
    pg = partition_by_src(g, p)
    assert int(pg.mask.sum()) == m
