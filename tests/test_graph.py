"""Graph substrate: COO/CSR, partitioner invariants, R-MAT, samplers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.graph import (COOGraph, device_sample, host_sample, partition_by_src,
                         rmat, table2_like, uniform_graph)


def test_csr_roundtrip():
    g = uniform_graph(40, 200, seed=0)
    indptr, indices, _ = g.to_csr()
    assert indptr[-1] == g.n_edges
    # every edge present exactly once
    pairs = set()
    for u in range(40):
        for v in indices[indptr[u]:indptr[u + 1]]:
            pairs.add((u, int(v)))
    assert len(pairs) <= g.n_edges
    orig = list(zip(g.src.tolist(), g.dst.tolist()))
    for u, v in orig:
        assert (u, v) in pairs


def test_rmat_properties():
    g = rmat(8, 4, seed=1)
    assert g.n_vertices == 256 and g.n_edges == 1024
    g2 = rmat(8, 4, seed=1)
    np.testing.assert_array_equal(g.src, g2.src)   # deterministic
    # power-lawish: max out-degree well above mean
    deg = g.degree_out()
    assert deg.max() > 4 * deg.mean()


@pytest.mark.parametrize("n_parts", [2, 4, 8])
def test_partition_invariants(n_parts):
    g = uniform_graph(100, 700, seed=2, weights=True, n_features=6)
    pg = partition_by_src(g, n_parts)
    # 1. every real edge appears exactly once, in its src owner's partition
    cnt = int(pg.mask.sum())
    assert cnt == g.n_edges
    for p in range(n_parts):
        m = pg.mask[p]
        glob_src = pg.src[p][m] + p * pg.part_size
        assert np.all(glob_src // pg.part_size == p)
    # 2. edge multiset conservation
    got = set()
    for p in range(n_parts):
        m = pg.mask[p]
        for s, d in zip(pg.src[p][m] + p * pg.part_size, pg.dst[p][m]):
            got.add((int(s), int(d)))
    want = set(zip(g.src.tolist(), g.dst.tolist()))
    assert got == want
    # 3. features land on the owner shard
    for p in range(n_parts):
        lo = p * pg.part_size
        hi = min(lo + pg.part_size, g.n_vertices)
        if lo < g.n_vertices:
            np.testing.assert_array_equal(pg.features[p, :hi - lo], g.features[lo:hi])


def test_host_sampler_neighbors_are_real(rng):
    g = uniform_graph(50, 400, seed=3)
    indptr, indices, _ = g.to_csr()
    seeds = rng.integers(0, 50, 20).astype(np.int64)
    nbrs, mask = host_sample(g, seeds, 7, seed=1)
    # with-replacement samples are always valid: mask is all-True and an
    # isolated vertex self-aggregates (its own id fills the fan-out)
    assert mask.all()
    for i, s in enumerate(seeds):
        real = set(indices[indptr[s]:indptr[s + 1]].tolist())
        for j in range(7):
            if real:
                assert int(nbrs[i, j]) in real
            else:
                assert int(nbrs[i, j]) == s  # isolated → self


def test_device_sampler_matches_semantics(rng):
    g = uniform_graph(50, 400, seed=4)
    indptr, indices, _ = g.to_csr()
    seeds = jnp.asarray(rng.integers(0, 50, 16).astype(np.int32))
    nbrs, mask = device_sample(jnp.asarray(indptr.astype(np.int32)),
                               jnp.asarray(indices), seeds, 5,
                               jax.random.PRNGKey(0))
    nbrs, mask = np.asarray(nbrs), np.asarray(mask)
    assert mask.all()
    for i, s in enumerate(np.asarray(seeds)):
        real = set(indices[indptr[s]:indptr[s + 1]].tolist())
        for j in range(5):
            if real:
                assert nbrs[i, j] in real
            else:
                assert nbrs[i, j] == s  # isolated → self


def _isolated_graph():
    """5 isolated vertices (0, 3, 7, 11, 15) among 16; the rest chain."""
    isolated = {0, 3, 7, 11, 15}
    src, dst = [], []
    for u in range(16):
        if u in isolated:
            continue
        for v in range(16):
            if v not in isolated and v != u:
                src.append(u)
                dst.append(v)
    return COOGraph(16, np.asarray(src, np.int32),
                    np.asarray(dst, np.int32)), sorted(isolated)


def test_samplers_self_aggregate_isolated_vertices():
    """Both samplers give isolated vertices VALID self-samples, so a masked
    mean over the fan-out returns their own features — not the reduction
    identity 0 (the bug this pins: ``out[i] = s`` with ``mask[i] = False``
    reduced isolated seeds to zeros)."""
    g, isolated = _isolated_graph()
    indptr, indices, _ = g.to_csr()
    seeds = np.arange(16, dtype=np.int64)

    h_nbrs, h_mask = host_sample(g, seeds, 4, seed=0)
    d_nbrs, d_mask = device_sample(
        jnp.asarray(indptr.astype(np.int32)), jnp.asarray(indices),
        jnp.asarray(seeds.astype(np.int32)), 4, jax.random.PRNGKey(1))
    d_nbrs, d_mask = np.asarray(d_nbrs), np.asarray(d_mask)

    # host ≡ device on the semantic contract: all samples valid, and the
    # isolated rows are exactly the seed id repeated
    assert h_mask.all() and d_mask.all()
    for s in isolated:
        np.testing.assert_array_equal(h_nbrs[s], np.full(4, s))
        np.testing.assert_array_equal(d_nbrs[s], np.full(4, s))
    # downstream check — the masked mean of integer features returns the
    # isolated vertex's OWN row bit-exactly
    feats = np.arange(16, dtype=np.float32)[:, None] * np.ones((1, 3), np.float32)
    agg = (feats[h_nbrs] * h_mask[..., None]).sum(1) / h_mask.sum(1)[:, None]
    for s in isolated:
        np.testing.assert_array_equal(agg[s], feats[s])


def test_device_sampler_offsets_never_escape_csr_range():
    """``int(u · deg)`` rounding to ``deg`` would select the first neighbor
    of the NEXT vertex's CSR range; the ``_fanout_offsets`` clamp pins it to
    the last real neighbor. Fed adversarial uniforms (u = 1.0 and the
    largest representable f32 below 1) where the unclamped product lands
    exactly on ``deg``."""
    from repro.graph.sampling import _fanout_offsets

    degs = jnp.asarray([1, 3, 7, 50, 1 << 20, (1 << 24) + 1], jnp.int32)
    for u_val in (1.0, np.float32(1.0 - 2.0 ** -24)):
        u = jnp.full((degs.shape[0], 4), u_val, jnp.float32)
        offs = np.asarray(_fanout_offsets(u, degs))
        unclamped = np.asarray((u * jnp.maximum(degs, 1)[:, None]
                                ).astype(jnp.int32))
        assert (offs < np.asarray(degs)[:, None]).all(), (u_val, offs)
        assert (offs >= 0).all()
        if u_val == 1.0:
            # the adversarial draw really does fire the unclamped bug on
            # every f32-representable degree (2^24 + 1 rounds DOWN in f32,
            # so its product stays in range — the clamp still holds above)
            rep = np.asarray(degs) == np.asarray(degs, np.float32)
            assert rep.any()
            assert (unclamped[rep] == np.asarray(degs)[rep, None]).all()


def test_device_sampler_membership_with_sentinel_neighbors(rng):
    """CSR-membership property: vertex v's range is followed by vertex
    v+1's — a sampler that reads one slot past its range returns a sentinel
    that belongs to the NEXT vertex. Build a two-vertex graph where every
    out-neighbor of 0 is vertex 0 itself and vertex 1's single neighbor is
    the sentinel 1; no sample of seed 0 may ever be 1."""
    src = np.zeros(37, np.int32)          # deg(0) = 37 — not a power of two
    dst = np.zeros(37, np.int32)          # all of 0's neighbors are 0
    src = np.concatenate([src, np.asarray([1], np.int32)])
    dst = np.concatenate([dst, np.asarray([1], np.int32)])   # the sentinel
    g = COOGraph(2, src, dst)
    indptr, indices, _ = g.to_csr()
    for k in range(8):
        nbrs, mask = device_sample(
            jnp.asarray(indptr.astype(np.int32)), jnp.asarray(indices),
            jnp.asarray([0], jnp.int32), 64, jax.random.PRNGKey(k))
        assert np.asarray(mask).all()
        assert (np.asarray(nbrs) == 0).all(), "sampled the next row's slot"


def test_table2_like_ratios():
    g = table2_like("Amazon", scale_down=1e5)
    assert g.features is not None and g.features.shape[1] == 32
    assert g.n_edges > 0 and g.n_vertices > 0


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 60), m=st.integers(1, 300), p=st.sampled_from([2, 4]),
       seed=st.integers(0, 1000))
def test_property_partition_conserves_edges(n, m, p, seed):
    g = uniform_graph(n, m, seed=seed)
    pg = partition_by_src(g, p)
    assert int(pg.mask.sum()) == m


def test_partition_edgeless_graph():
    """A vertex set with no edges (cold-start serving tables, freshly
    allocated shards) must partition cleanly: valid padded shapes, an
    all-False edge mask, features laid out per owner — not a crash in the
    bincount/argsort plumbing."""
    feats = np.arange(32 * 4, dtype=np.float32).reshape(32, 4)
    g = COOGraph(32, np.zeros(0, np.int64), np.zeros(0, np.int64),
                 features=feats)
    pg = partition_by_src(g, 4)
    assert pg.src.shape == pg.dst.shape == pg.mask.shape
    assert pg.src.shape[0] == 4 and pg.src.shape[1] >= 1
    assert not pg.mask.any()                      # every slot is padding
    np.testing.assert_array_equal(
        pg.features.reshape(-1, 4)[:32], feats)   # owner-order layout
    # and the empty CSR round-trips too
    indptr, indices, _ = g.to_csr()
    assert indptr[-1] == 0 and indices.size == 0
