"""Shared test fixtures. NOTE: no XLA_FLAGS here — the main suite sees the
real (1-device) topology; distributed tests spawn subprocesses that set their
own fake-device count (see tests/distributed_cases.py)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
