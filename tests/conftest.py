"""Shared test fixtures. NOTE: no XLA_FLAGS here — the main suite sees the
real (1-device) topology; distributed tests spawn subprocesses that set their
own fake-device count (see tests/distributed_cases.py)."""

import numpy as np
import pytest

from _dist import run_distributed_case


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def pallas_parity_report():
    """The full pallas/xla differential matrix on the real 8-way mesh — run
    ONCE per session (it compiles ~40 shard_map programs); both
    test_distributed.py and test_cgtrans_pallas.py assert against it."""
    return run_distributed_case("cgtrans_pallas_parity", timeout=600)


@pytest.fixture(scope="session")
def coalesce_parity_report():
    """The coalesced-request matrix on the real 8-way mesh (aggregate_multi
    ≡ separate aggregate_sampled calls over dataflow × impl × chunked ×
    scheduled, plus the deterministic collectives-per-step 2 → 1 count and
    the sage_forward coalesce-flag parity) — run ONCE per session;
    test_cgtrans_coalesce.py asserts each cell against this shared stdout."""
    return run_distributed_case("cgtrans_coalesce_parity", timeout=900)


@pytest.fixture(scope="session")
def wire_parity_report():
    """The compressed-wire matrix on the real 8-way mesh (bf16 ≡ f32
    bit-exact values AND gradients on integer payloads, int8 bounded error,
    the delta-id range gate, unchanged collective counts, the serving
    engine on the bf16 wire) — run ONCE per session; tests/test_wire.py
    asserts each cell against this shared stdout."""
    return run_distributed_case("wire_parity", timeout=900)


@pytest.fixture(scope="session")
def island_parity_report():
    """The islandized-partition matrix on the real 8-way mesh (islandized ≡
    interval bit-exact values AND gradients on integer data across
    dataflow × op × impl, sage + one optimizer step, the serving engine
    with the cache on, and the counted locality reductions) — run ONCE per
    session; tests/test_partition.py asserts each cell against this shared
    stdout."""
    return run_distributed_case("islandized_parity", timeout=900)


@pytest.fixture(scope="session")
def sparse_parity_report():
    """The compressed-sparse feature matrix on the real 8-way mesh (sparse ≡
    dense bit-exact values AND gradients on integer data across dataflow ×
    impl × op, the multi/edges entrypoints, the bf16-wire composition,
    unchanged collective counts, and the serving engine on sparse features)
    — run ONCE per session; tests/test_sparse.py asserts each cell against
    this shared stdout."""
    return run_distributed_case("sparse_parity", timeout=900)


@pytest.fixture(scope="session")
def grad_parity_report():
    """The GRADIENT differential matrix on the real 8-way mesh (plus the
    3-step pallas-vs-xla train parity) — run ONCE per session (each cell is
    a jax.grad shard_map compilation); test_cgtrans_grad.py asserts each
    cell against this shared stdout."""
    return run_distributed_case("cgtrans_grad_parity", timeout=900)
