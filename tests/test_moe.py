"""MoE: routing properties, capacity semantics, CGTrans-combine equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.common.schema import init_params
from repro.models import layers, moe


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=2, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=24, vocab=64, head_dim=8, pattern=("moe",),
                n_experts=8, top_k=2, n_shared_experts=0,
                compute_dtype="float32", remat="none")
    base.update(kw)
    return ModelConfig(**base)


def test_route_topk_properties(rng):
    cfg = _cfg()
    w = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((4, 10, 16)).astype(np.float32))
    p, ids, aux = moe.route(w, x, cfg)
    assert p.shape == (4, 10, 2) and ids.shape == (4, 10, 2)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)   # renormalized
    assert np.all(np.asarray(p) >= 0)
    assert np.all(np.asarray(ids) < 8)
    # distinct experts per token
    assert np.all(np.asarray(ids[..., 0]) != np.asarray(ids[..., 1]))
    assert float(aux) >= 1.0 - 1e-5   # load-balance loss lower bound is 1


def test_moe_matches_dense_reference(rng):
    """With ample capacity, capacity-dispatch == direct per-token expert mix."""
    cfg = _cfg()
    p = init_params(moe.moe_schema(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 8, 16)).astype(np.float32))
    out, aux = moe.moe_apply(p, x, cfg, capacity_factor=8.0, group_size=16)

    w, ids, _ = moe.route(p["router"], x, cfg)
    want = np.zeros_like(np.asarray(x))
    for b in range(2):
        for s in range(8):
            for k in range(cfg.top_k):
                e = int(ids[b, s, k])
                xi = np.asarray(x[b, s])
                g = np.asarray(jax.nn.silu(xi @ np.asarray(p["w_gate"][e])))
                u = xi @ np.asarray(p["w_up"][e])
                want[b, s] += float(w[b, s, k]) * ((g * u) @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_tokens(rng):
    """With capacity factor ≪ 1, outputs shrink (dropped tokens emit 0)."""
    cfg = _cfg()
    p = init_params(moe.moe_schema(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((1, 64, 16)).astype(np.float32))
    full, _ = moe.moe_apply(p, x, cfg, capacity_factor=8.0, group_size=64)
    tight, _ = moe.moe_apply(p, x, cfg, capacity_factor=0.25, group_size=64)
    assert float(jnp.linalg.norm(tight)) < float(jnp.linalg.norm(full))


def test_shared_experts_added(rng):
    cfg = _cfg(n_shared_experts=2)
    p = init_params(moe.moe_schema(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(rng.standard_normal((1, 8, 16)).astype(np.float32))
    out, _ = moe.moe_apply(p, x, cfg, capacity_factor=8.0, group_size=8)
    shared_only = layers.mlp_apply(p["shared"], x, cfg)
    p2 = dict(p)
    p2 = {k: v for k, v in p.items() if k != "shared"}
    routed_only, _ = moe.moe_apply(p2, x, cfg, capacity_factor=8.0, group_size=8)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(shared_only + routed_only),
                               atol=1e-5)


def test_balanced_router_aux_near_one(rng):
    """Uniform routing → aux ≈ 1 (its minimum)."""
    cfg = _cfg()
    w = jnp.zeros((16, 8))   # uniform logits
    x = jnp.asarray(rng.standard_normal((2, 32, 16)).astype(np.float32))
    _, _, aux = moe.route(w, x, cfg)
    assert 0.9 <= float(aux) <= 1.2
