"""Differential tier for FAST-GAS inside the CGTrans dataflows.

Three layers of guarantees:

1. **In-process (1-device) matrix** — for every op × scheduled ∈ {on, off}
   (the destination-binned locality pass), ``impl="pallas"`` ≡
   ``impl="xla"`` on the single-shard reference path of both aggregation
   entry points, including ragged/non-tile-aligned edge counts and
   all-masked inputs. Runs on the plain pytest topology (no mesh needed:
   unsharded, impl/scheduled are the only variables). The scheduler's own
   tier (``tests/test_gas_schedule.py``) additionally asserts scheduled ≡
   unscheduled bit-exactness and the idle-skip round counts.
2. **Property tests** (``_propcheck``) — the chunked request stream is
   *bit-exact* with the unchunked path for arbitrary ``request_chunk``
   (chunking partitions seeds, never a seed's K contributions), and the
   idle-skip ``occupancy_map`` never skips a tile holding a live edge after
   the wrapper's in-shard re-padding.
3. **On-mesh matrix** (``distributed`` marker) — the full
   (dataflow × op × path × impl) grid on a REAL 8-way ``shard_map`` mesh,
   via one shared subprocess run (``case_cgtrans_pallas_parity``); each cell
   is asserted as its own test here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import cgtrans

OPS = ("add", "max", "min", "or")
FLOWS = ("cgtrans", "baseline")


def _feats(rng, n, f, op):
    x = rng.standard_normal((n, f)).astype(np.float32)
    if op == "or":
        return (np.abs(x) > 0.5).astype(np.int32)
    return x


def _close(a, b, tol=1e-4):
    a = jnp.nan_to_num(a.astype(jnp.float32), posinf=9e9, neginf=-9e9)
    b = jnp.nan_to_num(b.astype(jnp.float32), posinf=9e9, neginf=-9e9)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# 1. in-process differential matrix (single-shard reference path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduled", [False, True])
@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("e", [1, 37, 128, 517])   # ragged + tile-aligned
def test_edges_pallas_vs_xla(rng, op, e, scheduled):
    P_, part, F = 2, 32, 8
    feats = jnp.asarray(_feats(rng, P_ * part, F, op)).reshape(P_, part, F)
    src = jnp.asarray(rng.integers(0, part, (P_, e)).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, P_ * part, (P_, e)).astype(np.int32))
    w = jnp.asarray(rng.standard_normal((P_, e)).astype(np.float32))
    m = jnp.asarray(rng.random((P_, e)) < 0.8)
    outs = {impl: cgtrans.aggregate_edges(feats, src, dst, w, m, mesh=None,
                                          op=op, impl=impl,
                                          scheduled=scheduled)
            for impl in ("xla", "pallas")}
    _close(outs["pallas"], outs["xla"])


@pytest.mark.parametrize("op", OPS)
def test_edges_all_masked(rng, op):
    """mask all-False: every row holds the op identity, both backends."""
    P_, part, F, e = 2, 16, 4, 33
    feats = jnp.asarray(_feats(rng, P_ * part, F, op)).reshape(P_, part, F)
    src = jnp.asarray(rng.integers(0, part, (P_, e)).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, P_ * part, (P_, e)).astype(np.int32))
    w = jnp.ones((P_, e), jnp.float32)
    m = jnp.zeros((P_, e), bool)
    outs = {impl: cgtrans.aggregate_edges(feats, src, dst, w, m, mesh=None,
                                          op=op, impl=impl)
            for impl in ("xla", "pallas")}
    _close(outs["pallas"], outs["xla"])


@pytest.mark.parametrize("scheduled", [False, True])
@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("k", [1, 7, 16])
def test_sampled_pallas_vs_xla(rng, op, k, scheduled):
    P_, part, F, B = 2, 32, 8, 13
    feats = jnp.asarray(_feats(rng, P_ * part, F, op)).reshape(P_, part, F)
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, B, k)).astype(np.int32))
    mk = jnp.asarray(rng.random((P_, B, k)) < 0.8)
    outs = {impl: cgtrans.aggregate_sampled(feats, nb, mk, mesh=None,
                                            op=op, impl=impl,
                                            scheduled=scheduled)
            for impl in ("xla", "pallas")}
    _close(outs["pallas"], outs["xla"])


def test_sampled_all_masked(rng):
    """Seeds with zero valid samples: mean path returns 0 on both backends."""
    P_, part, F, B, k = 2, 16, 4, 5, 3
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, B, k)).astype(np.int32))
    mk = jnp.zeros((P_, B, k), bool)
    for impl in ("xla", "pallas"):
        out = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None, impl=impl)
        np.testing.assert_array_equal(np.asarray(out), 0.0, err_msg=impl)


# ---------------------------------------------------------------------------
# 2. property tests: chunked ≡ unchunked; occupancy never skips live work
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    chunk=st.integers(1, 40),       # covers 1, primes, and ≥ B_loc (=2·13)
    b=st.integers(1, 13),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_chunked_request_stream_exact(chunk, b, k, seed):
    """The chunked SSD-request stream is BIT-EXACT with the unchunked path:
    chunking partitions the seed block, never a seed's K contributions."""
    rng = np.random.default_rng(seed)
    P_, part, F = 2, 16, 4
    feats = jnp.asarray(rng.standard_normal((P_, part, F)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, P_ * part, (P_, b, k)).astype(np.int32))
    mk = jnp.asarray(rng.random((P_, b, k)) < 0.7)
    ref = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None)
    out = cgtrans.aggregate_sampled(feats, nb, mk, mesh=None,
                                    request_chunk=chunk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(1, 400),
    r=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_occupancy_never_skips_live_tile(e, r, seed):
    """Replicate the kernel wrapper's in-shard re-padding (clip-to-dead-row +
    pad-to-tile) and assert the idle-skip map marks every (row-block,
    edge-tile) pair that contains a live edge — a skipped live tile would
    silently drop aggregation work."""
    from repro.kernels.gas_scatter import kernel as K
    from repro.kernels.gas_scatter import occupancy_map

    rng = np.random.default_rng(seed)
    dst = rng.integers(-3, r + 3, e).astype(np.int32)   # incl. out-of-range
    et = K.EDGE_TILE_ADD
    R = ((r + K.ROW_BLOCK - 1) // K.ROW_BLOCK) * K.ROW_BLOCK
    ok = (dst >= 0) & (dst < r)
    dstp = np.where(ok, dst, R)
    dstp = np.pad(dstp, (0, (-len(dstp)) % et), constant_values=R)
    occ = np.asarray(occupancy_map(jnp.asarray(dstp), R // K.ROW_BLOCK, et))
    tiles = dstp.reshape(-1, et)
    for t in range(tiles.shape[0]):
        live = tiles[t][tiles[t] < R]          # dead-row padding excluded
        for blk in np.unique(live // K.ROW_BLOCK):
            assert occ[blk, t], (t, blk)


# ---------------------------------------------------------------------------
# 3. the on-mesh matrix: every cell of the shared 8-way subprocess run
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("path", ["edges", "sampled"])
def test_mesh_parity_cell(pallas_parity_report, path, op, flow):
    line = f"parity path={path} flow={flow} op={op} impl=pallas ok"
    assert line in pallas_parity_report, (
        f"missing/failed matrix cell: {line!r}")


@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("chunk", [1, 3, 64])
def test_mesh_parity_chunked(pallas_parity_report, flow, chunk):
    line = f"parity path=sampled flow={flow} chunk={chunk} ok"
    assert line in pallas_parity_report, (
        f"missing/failed chunked-request cell: {line!r}")


@pytest.mark.distributed
@pytest.mark.parametrize("flow", FLOWS)
@pytest.mark.parametrize("op", ["add", "max"])
@pytest.mark.parametrize("path", ["edges", "sampled"])
def test_mesh_parity_scheduled_off(pallas_parity_report, path, op, flow):
    """pallas defaults to scheduled on the mesh — these cells pin the
    scheduled=off pallas path (dense-occupancy grid) as a separate axis."""
    line = f"parity path={path} flow={flow} op={op} impl=pallas sched=off ok"
    assert line in pallas_parity_report, (
        f"missing/failed scheduled-off cell: {line!r}")


@pytest.mark.distributed
def test_mesh_parity_hoisted_schedule(pallas_parity_report):
    """The deployment path: build_edge_schedule + apply_edge_schedule +
    schedule_applied through shard_map, and gcn_forward_full's sharded
    auto-hoist — locked in on the real 8-way mesh, not just benchmarked."""
    assert "parity path=edges flow=cgtrans hoisted-schedule ok" in \
        pallas_parity_report
    assert "parity gcn-full sharded hoisted-schedule ok" in \
        pallas_parity_report
