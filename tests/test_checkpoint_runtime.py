"""Checkpointing (atomic/async/retention/restore) + runtime health machinery
+ fault-tolerant loop semantics (resume, preemption)."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import Heartbeat, PreemptionGuard, StepMonitor
from repro.train import train_loop


def _state(x=0.0):
    return {"params": {"w": jnp.full(4, x)}, "step": jnp.asarray(0, jnp.int32),
            "nested": {"a": jnp.arange(6).reshape(2, 3)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state(3.5)
    mgr.save(st, 10)
    restored, step = mgr.restore(st)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 3.5)
    np.testing.assert_array_equal(np.asarray(restored["nested"]["a"]),
                                  np.arange(6).reshape(2, 3))


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(_state(float(s)), s)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4
    restored, _ = mgr.restore(_state())
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 4.0)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(_state(1.0), 5)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_atomicity_tmp_dirs_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(_state(1.0), 1)
    os.makedirs(tmp_path / ".tmp_step_2")          # simulated crashed save
    (tmp_path / ".tmp_step_2" / "garbage").write_text("x")
    os.makedirs(tmp_path / "step_3")               # no manifest → incomplete
    assert mgr.steps() == [1]


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(z_threshold=4.0)
    for i in range(20):
        assert not mon.record(i, 0.100 + 0.001 * (i % 3))
    assert mon.record(20, 1.0)      # 10× outlier
    assert mon.flagged == 1
    assert not mon.record(21, 0.101)


def test_step_monitor_constant_stream_tolerates_jitter():
    """MAD = 0 degeneracy: a window of IDENTICAL step times used to floor
    sigma at 1e-6, so a nanosecond of jitter z-scored in the thousands and
    flagged a straggler. With the median-fraction floor, sub-5%-of-median
    jitter must flag nothing."""
    mon = StepMonitor(z_threshold=4.0)
    for i in range(32):
        assert not mon.record(i, 0.100)          # perfectly constant window
    # nanosecond-to-microsecond jitter: well inside 5% of the median
    for i, jit in enumerate((1e-9, 5e-8, 1e-6, 2e-4)):
        assert not mon.record(32 + i, 0.100 + jit), f"flagged jitter {jit}"
    assert mon.flagged == 0
    # a REAL straggler on the constant stream still flags: 4·(0.05·med) above
    assert mon.record(100, 0.100 + 4.5 * 0.05 * 0.100)
    assert mon.flagged == 1


def test_heartbeat(tmp_path):
    path = str(tmp_path / "hb")
    hb = Heartbeat(path, interval_s=0.05)
    hb.start()
    time.sleep(0.12)
    hb.stop()
    assert Heartbeat.is_alive(path, stale_after_s=5.0)
    assert not Heartbeat.is_alive(str(tmp_path / "missing"))


def _quadratic_step(state, batch):
    w = state["params"]["w"]
    g = 2 * (w - batch["target"])
    w = w - 0.2 * g
    loss = jnp.sum((w - batch["target"]) ** 2)
    return ({"params": {"w": w}, "step": state["step"] + 1},
            {"total_loss": loss})


class _Batches:
    def __iter__(self):
        while True:
            yield {"target": jnp.asarray([1.0, 2.0])}


def test_train_loop_resume_exactness(tmp_path):
    """Interrupted run + resumed run == uninterrupted run (restart semantics)."""
    ck1 = CheckpointManager(str(tmp_path / "a"))
    st0 = {"params": {"w": jnp.zeros(2)}, "step": jnp.asarray(0, jnp.int32)}
    # uninterrupted 20 steps
    full, _ = train_loop(step_fn=_quadratic_step, state=st0, batches=_Batches(),
                         total_steps=20, ckpt=None, log_every=0)
    # interrupted at 10 (ckpt every 5), then resumed to 20
    part, n = train_loop(step_fn=_quadratic_step, state=st0, batches=_Batches(),
                         total_steps=10, ckpt=ck1, ckpt_every=5, log_every=0)
    resumed, n2 = train_loop(step_fn=_quadratic_step, state=st0, batches=_Batches(),
                             total_steps=20, ckpt=ck1, ckpt_every=5, log_every=0)
    assert n == 10 and n2 == 20
    np.testing.assert_allclose(np.asarray(resumed["params"]["w"]),
                               np.asarray(full["params"]["w"]), atol=1e-6)


def test_train_loop_preemption_checkpoints(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    guard = PreemptionGuard(install=False)
    st0 = {"params": {"w": jnp.zeros(2)}, "step": jnp.asarray(0, jnp.int32)}

    calls = {"n": 0}
    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            guard.trigger()          # simulated SIGTERM mid-run
        return _quadratic_step(state, batch)

    _, n = train_loop(step_fn=step, state=st0, batches=_Batches(),
                      total_steps=100, ckpt=ck, ckpt_every=1000,
                      guard=guard, log_every=0)
    assert n == 3
    assert ck.latest_step() == 3     # preemption forced a final checkpoint
