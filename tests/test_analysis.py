"""Tier: lint — the static-analysis subsystem tested against itself.

Three groups:

* AST fixtures: each planted-violation file in ``tests/_lint_fixtures``
  (linted with that directory as the fake repo root, so the ``src/repro``
  vs ``tests`` role rules apply) must surface exactly its planted rule, the
  justified suppression must lint clean, and the bare (unjustified)
  ``allow()`` must NOT suppress. Plus: the REAL repo must be AST-clean.
* jaxpr fixtures: a deliberately two-collective shard_map program must
  FAIL a one-psum ``DataflowContract`` (and pass the honest two-psum one);
  ``check_dtype_flow`` must flag a planted f64 trace, a bf16
  sum-accumulation, and an unsigned id stream feeding a gather — and stay
  quiet on the healthy f32/int32 equivalents.
* meta: every public aggregate entrypoint configuration
  (dataflow × impl × coalesce × scheduled) has a registered contract, the
  ``SAGE_FETCH_*`` headline tables agree with the sage contracts they
  summarize, and ``scripts/lint.py --json`` (the CI gate) reports ok on a
  cheap contract subset.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.lint

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "_lint_fixtures"


def _lint_fixture(rel):
    from repro.analysis.source_lint import lint_file, registered_markers

    markers = registered_markers(REPO / "pyproject.toml")
    return lint_file(FIXTURES / rel, FIXTURES, markers=markers)


# ---------------------------------------------------------------------------
# AST layer: planted violations are caught; the real repo is clean
# ---------------------------------------------------------------------------

def test_compat_door_fixture_caught():
    vs = _lint_fixture("src/repro/bad_compat.py")
    assert {v.rule for v in vs} == {"compat-door"}
    # the experimental import, the AxisType import, and the jax.shard_map
    # attribute are three distinct doors around compat
    assert len(vs) >= 3, vs


def test_f64_literal_fixture_caught():
    vs = _lint_fixture("src/repro/bad_f64.py")
    assert {v.rule for v in vs} == {"f64-literal"}
    assert len(vs) == 2, vs           # the attribute form and the string form


def test_collective_site_fixture_caught():
    vs = _lint_fixture("src/repro/bad_collective.py")
    assert [v.rule for v in vs] == ["collective-site"]
    assert "DataflowContract" in vs[0].msg


def test_dispatch_fixtures_caught():
    vs = _lint_fixture("src/repro/bad_dispatch.py")
    rules = sorted(v.rule for v in vs)
    assert rules == ["pallas-call-site", "unticked-dispatch"], vs
    unticked = next(v for v in vs if v.rule == "unticked-dispatch")
    assert "scatter_rows" in unticked.msg


def test_unknown_marker_fixture_caught():
    vs = _lint_fixture("tests/bad_marker.py")
    assert [v.rule for v in vs] == ["unknown-marker"]
    assert "bogus_tier" in vs[0].msg


def test_justified_suppression_lints_clean():
    assert _lint_fixture("src/repro/allowed.py") == []


def test_bare_allow_does_not_suppress():
    vs = _lint_fixture("src/repro/bare_allow.py")
    assert [v.rule for v in vs] == ["compat-door"], vs


def test_repo_is_ast_clean():
    """The acceptance criterion the fixtures exist to protect: the lint,
    run on HEAD, finds nothing (fixture corpus excluded by lint_repo)."""
    from repro.analysis.source_lint import lint_repo

    vs = lint_repo(REPO)
    assert vs == [], "\n".join(str(v) for v in vs)


def test_lint_marker_is_registered():
    from repro.analysis.source_lint import registered_markers

    marks = registered_markers(REPO / "pyproject.toml")
    assert {"lint", "distributed"} <= marks


# ---------------------------------------------------------------------------
# jaxpr layer: contracts catch planted dataflow drift
# ---------------------------------------------------------------------------

def _double_psum():
    """A shard_map program that deliberately issues TWO psums — the 'someone
    added a collective' failure mode the contracts exist to catch."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat

    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=(compat.AxisType.Auto,))
    fn = compat.shard_map(
        lambda x: jax.lax.psum(jax.lax.psum(x, "data"), "data"),
        mesh=mesh, in_specs=P(), out_specs=P())
    return fn, (jax.ShapeDtypeStruct((4,), jnp.float32),)


def test_contract_fails_on_extra_collective():
    from repro.analysis.contracts import DataflowContract, verify_contract

    lying = DataflowContract(name="fixture/double-psum",
                             build=_double_psum, forward={"psum": 1})
    fails = verify_contract(lying)
    assert fails, "a two-psum trace passed a one-psum budget"
    assert any("psum" in f and "budget 1" in f and "traced 2" in f
               for f in fails), fails


def test_contract_passes_on_honest_budget():
    from repro.analysis.contracts import DataflowContract, verify_contract

    honest = DataflowContract(name="fixture/double-psum-honest",
                              build=_double_psum, forward={"psum": 2})
    assert verify_contract(honest) == []


def test_contract_rejects_unknown_budget_key():
    from repro.analysis.contracts import DataflowContract

    with pytest.raises(ValueError, match="unknown budget key"):
        DataflowContract(name="fixture/bogus-key",
                         build=_double_psum, forward={"bogus": 1})


def test_dtype_flow_flags_planted_f64():
    import jax

    from repro.analysis.dtype_flow import check_dtype_flow

    jax.config.update("jax_enable_x64", True)
    try:
        jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(
            jax.ShapeDtypeStruct((4,), "float64"))
    finally:
        jax.config.update("jax_enable_x64", False)
    issues = check_dtype_flow(jaxpr)
    assert any(i.rule == "f64" for i in issues), issues
    # and the waiver drops exactly that rule
    assert check_dtype_flow(jaxpr, waive=("f64",)) == []


def test_dtype_flow_flags_bf16_accumulation():
    import jax
    import jax.numpy as jnp

    from repro.analysis.dtype_flow import check_dtype_flow

    bf = jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda a, b: a @ b)(bf, bf)
    issues = check_dtype_flow(jaxpr)
    assert any(i.rule == "accum" and i.primitive == "dot_general"
               for i in issues), issues
    # jnp.sum over bf16 upcasts to an f32 accumulator on its own (JAX's
    # upcast-f16-for-computation) — healthy, and must NOT be flagged; nor an
    # f32 contraction
    clean = jax.make_jaxpr(jnp.sum)(jax.ShapeDtypeStruct((8,), jnp.bfloat16))
    assert check_dtype_flow(clean) == []
    f32 = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    assert check_dtype_flow(jax.make_jaxpr(lambda a, b: a @ b)(f32, f32)) == []


def test_dtype_flow_flags_unsigned_index_stream():
    """A raw lax.gather fed uint32 indices (jnp indexing canonicalizes to
    int32 on its own, so the raw-kernel path is where drift can hide)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.analysis.dtype_flow import check_dtype_flow

    dnums = lax.GatherDimensionNumbers(offset_dims=(1,),
                                       collapsed_slice_dims=(0,),
                                       start_index_map=(0,))

    def lookup(t, i):
        return lax.gather(t, i, dnums, (1, 4))

    table = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    bad = jax.make_jaxpr(lookup)(
        table, jax.ShapeDtypeStruct((8, 1), jnp.uint32))
    issues = check_dtype_flow(bad)
    assert any(i.rule == "unsigned-wire" and i.primitive == "gather"
               for i in issues), issues
    # the signed stream (the -1 mask encoding's home) is healthy
    good = jax.make_jaxpr(lookup)(
        table, jax.ShapeDtypeStruct((8, 1), jnp.int32))
    assert check_dtype_flow(good) == []


def test_dtype_flow_flags_unsigned_on_the_wire():
    """An unsigned aval entering a collective — some cast re-encoded the -1
    mask ids as 2³²−1 before they shipped."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.analysis.dtype_flow import check_dtype_flow

    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=(compat.AxisType.Auto,))

    def traced(dtype):
        fn = compat.shard_map(lambda x: jax.lax.psum(x, "data"),
                              mesh=mesh, in_specs=P(), out_specs=P())
        return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4,), dtype))

    issues = check_dtype_flow(traced(jnp.uint32))
    assert any(i.rule == "unsigned-wire" for i in issues), issues
    assert check_dtype_flow(traced(jnp.int32)) == []


def test_dtype_flow_flags_narrow_wire_unless_waived():
    """A sub-32-bit payload entering a collective is a lossy/re-encoded
    transport and must be DECLARED (dtype_waivers=('narrow-wire',)), never
    an accident: unwaived int8/int16/bf16 collectives flag; the waiver
    clears them; bool masks (the baseline's 1-bit ownership wire) and f32
    never flag."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.analysis.dtype_flow import check_dtype_flow

    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=(compat.AxisType.Auto,))

    def traced(dtype):
        fn = compat.shard_map(lambda x: jax.lax.pmax(x, "data"),
                              mesh=mesh, in_specs=P(), out_specs=P())
        return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4,), dtype))

    for narrow in (jnp.int8, jnp.int16, jnp.bfloat16):
        issues = check_dtype_flow(traced(narrow))
        assert any(i.rule == "narrow-wire" for i in issues), (narrow, issues)
        # the declared form is clean — extend the waiver, never the rule
        assert check_dtype_flow(traced(narrow), waive=("narrow-wire",)) == []
    # full-width and bool wires are healthy undeclared
    assert check_dtype_flow(traced(jnp.float32)) == []
    assert check_dtype_flow(traced(jnp.bool_)) == []


def test_dtype_flow_rejects_unknown_waiver():
    import jax
    import jax.numpy as jnp

    from repro.analysis.dtype_flow import check_dtype_flow

    jaxpr = jax.make_jaxpr(lambda x: x + 1)(
        jax.ShapeDtypeStruct((2,), jnp.float32))
    with pytest.raises(ValueError, match="unknown dtype rule"):
        check_dtype_flow(jaxpr, waive=("bogus",))


# ---------------------------------------------------------------------------
# meta: coverage, single-source-of-truth, and the CLI gate
# ---------------------------------------------------------------------------

def _expected_contract_grid():
    grid = set()
    for ep in ("aggregate_sampled", "aggregate_multi"):
        for flow in ("cgtrans", "baseline"):
            for impl in ("xla", "pallas"):
                grid.add(f"{ep}/{flow}/{impl}")
            grid.add(f"{ep}/{flow}/pallas/sched")
    for ep in ("sage_forward", "train_step"):
        for form in ("separate", "coalesced"):
            for impl in ("xla", "pallas"):
                grid.add(f"{ep}/{form}/{impl}")
            grid.add(f"{ep}/{form}/pallas/sched")
    for flow in ("cgtrans", "baseline"):
        for impl in ("xla", "pallas"):
            grid.add(f"separate_fetch/{flow}/{impl}")
            for op in ("add", "max"):
                grid.add(f"aggregate_edges/{flow}/{op}/{impl}")
    grid |= {"embed_lookup/cgtrans/xla", "embed_lookup/cgtrans/pallas",
             "embed_lookup/baseline/xla"}
    for form in ("fused", "naive"):
        for impl in ("xla", "pallas"):
            grid.add(f"serving_fetch/{form}/{impl}")
    # compressed wire variants (repro.core.wire): same budgets as their f32
    # twins except edges-add (psum_scatter → all_to_all), all carrying the
    # narrow-wire waiver
    for w in ("bf16", "int8"):
        grid |= {f"aggregate_sampled/cgtrans/xla/{w}",
                 f"aggregate_multi/cgtrans/xla/{w}",
                 f"aggregate_edges/cgtrans/add/xla/{w}"}
    grid |= {"aggregate_multi/cgtrans/pallas/bf16",
             "serving_fetch/fused/xla/bf16"}
    # compressed-sparse feature variants (repro.core.sparse): bytes change,
    # budgets don't — dense twins' numbers, plus the baseline × bf16-wire
    # composition that only sparse features legalize
    grid |= {"aggregate_sampled/cgtrans/xla/sparse",
             "aggregate_sampled/cgtrans/pallas/sparse",
             "aggregate_sampled/baseline/xla/sparse",
             "aggregate_multi/cgtrans/xla/sparse",
             "aggregate_edges/cgtrans/add/xla/sparse",
             "aggregate_sampled/baseline/xla/sparse-bf16"}
    return grid


def test_every_entrypoint_configuration_has_a_contract():
    """The meta-guarantee: the dataflow × impl × coalesce × scheduled grid
    of public aggregate entrypoints is FULLY covered — a new configuration
    added without a budget fails here before it ships uncounted traffic."""
    from repro.analysis.contracts import CONTRACTS

    expected = _expected_contract_grid()
    missing = expected - set(CONTRACTS)
    extra = set(CONTRACTS) - expected
    assert not missing, f"configurations without a contract: {sorted(missing)}"
    assert not extra, (f"contracts outside the declared grid (extend "
                       f"_expected_contract_grid): {sorted(extra)}")


def test_contracts_budget_backward_where_training_runs():
    """The differentiable fetch entrypoints — the ones training actually
    grads through — budget fwd+bwd (the backward of the in-SSD dataflow is
    in-SSD work). Scheduled variants never do: the scheduled axis is
    collective/dispatch-neutral, pinned by the forward budget alone.
    (train_step needs no fwd_bwd — its forward already CONTAINS the
    backward; aggregate_edges/separate_fetch are forward-only twins.)"""
    from repro.analysis.contracts import CONTRACTS

    grad_families = ("aggregate_sampled/", "aggregate_multi/",
                     "sage_forward/", "embed_lookup/cgtrans/")
    for name, c in CONTRACTS.items():
        if name.endswith("/sched"):
            assert c.fwd_bwd is None, f"{name}: sched variants pin fwd only"
        elif name.startswith(grad_families):
            assert c.fwd_bwd is not None, f"{name} has no fwd+bwd budget"


def test_sage_tables_agree_with_sage_contracts():
    """SAGE_FETCH_* are the headline tables the coalesce tier and the bench
    import — they must literally be slices of the sage_forward contracts."""
    from repro.analysis.contracts import (CONTRACTS, SAGE_FETCH_COLLECTIVES,
                                          SAGE_FETCH_DISPATCH)

    for form in ("separate", "coalesced"):
        fwd = CONTRACTS[f"sage_forward/{form}/xla"].forward
        for coll, n in SAGE_FETCH_COLLECTIVES[form].items():
            assert fwd[coll] == n, (form, coll)
        for disp, n in SAGE_FETCH_DISPATCH[form].items():
            assert fwd[disp] == n, (form, disp)


def test_lint_cli_reports_ok_on_head():
    """The CI gate end-to-end: scripts/lint.py --json exits 0 on HEAD with
    a clean AST report. Contract verification is restricted to one cheap
    entrypoint here — ci.sh --tier lint runs the full 57 separately."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "lint.py"), "--json",
         "--contracts", "embed_lookup/baseline/xla"],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["ok"] is True
    assert report["ast"] == []
    assert report["contracts"] == {"checked": 1, "failed": {}}
