"""Optimizer: AdamW convergence, schedule, clipping, int8-EF compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.common.config import TrainConfig
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_grads, cosine_lr, global_norm, quantize_int8)


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=5, total_steps=200,
                     weight_decay=0.0, grad_clip=10.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params, tc)

    @jax.jit
    def step(params, opt):
        g = {"w": 2 * (params["w"] - target)}
        return adamw_update(params, g, opt, tc)

    for _ in range(200):
        params, opt, _ = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_cosine_schedule_endpoints():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                     min_lr_ratio=0.1)
    assert float(cosine_lr(jnp.array(0), tc)) == pytest.approx(0.0, abs=1e-9)
    assert float(cosine_lr(jnp.array(10), tc)) == pytest.approx(1e-3, rel=1e-3)
    assert float(cosine_lr(jnp.array(100), tc)) == pytest.approx(1e-4, rel=1e-2)


def test_clip_by_global_norm():
    tree = {"a": jnp.full(4, 10.0), "b": jnp.full(9, 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(13 * 100), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below threshold → untouched
    small = {"a": jnp.asarray([0.1])}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.1, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=50))
def test_quantize_int8_error_bound(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, scale = quantize_int8(x)
    deq = q.astype(jnp.float32) * scale
    # error ≤ half a quantization step
    assert float(jnp.max(jnp.abs(deq - x))) <= float(scale) * 0.5 + 1e-9


def test_error_feedback_unbiased_over_time():
    """With EF, the *accumulated* transmitted grad ≈ accumulated true grad."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
    residual = {"w": jnp.zeros(64)}
    sent_sum = np.zeros(64)
    for _ in range(50):
        sent, residual = compress_grads(g_true, residual)
        sent_sum += np.asarray(sent["w"])
    np.testing.assert_allclose(sent_sum / 50, np.asarray(g_true["w"]),
                               atol=2e-3, rtol=1e-2)


def test_int8_ef_training_still_converges():
    tc = TrainConfig(learning_rate=0.05, warmup_steps=0, total_steps=300,
                     weight_decay=0.0, grad_compression="int8_ef")
    target = jnp.asarray([0.5, -1.5])
    params = {"w": jnp.zeros(2)}
    opt = adamw_init(params, tc)
    assert "ef_residual" in opt

    @jax.jit
    def step(params, opt):
        g = {"w": 2 * (params["w"] - target)}
        return adamw_update(params, g, opt, tc)

    for _ in range(300):
        params, opt, m = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=5e-2)
