"""FAST-GAS scatter kernel vs jnp oracle: shape/dtype sweeps + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels.gas_scatter import gas_scatter, gas_scatter_ref, occupancy_map
from repro.kernels.gas_scatter import kernel as K


def _cmp(dst, val, rows, op, tol=1e-4):
    got = gas_scatter(dst, val, rows, op=op)
    want = gas_scatter_ref(dst, val, rows, op=op)
    g = jnp.nan_to_num(got, posinf=9e9, neginf=-9e9)
    w = jnp.nan_to_num(want, posinf=9e9, neginf=-9e9)
    np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=tol, rtol=tol)


@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("shape", [(64, 8, 32), (500, 30, 200), (1000, 7, 50),
                                   (128, 128, 128), (64, 300, 513), (1, 1, 1)])
def test_shape_sweep(rng, op, shape):
    E, F, R = shape
    dst = jnp.asarray(rng.integers(-3, R + 3, E).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((E, F)).astype(np.float32))
    _cmp(dst, val, R, op)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(rng, dtype):
    E, F, R = 256, 64, 128
    dst = jnp.asarray(rng.integers(0, R, E).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((E, F))).astype(dtype)
    got = gas_scatter(dst, val, R, op="add")
    want = gas_scatter_ref(dst, val, R, op="add")
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=0.2 if dtype == jnp.bfloat16 else 1e-4, rtol=0.05)


def test_1d_values(rng):
    dst = jnp.asarray(rng.integers(0, 40, 200).astype(np.int32))
    val = jnp.asarray(rng.standard_normal(200).astype(np.float32))
    got = gas_scatter(dst, val, 40, op="add")
    assert got.shape == (40,)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(gas_scatter_ref(dst, val[:, None], 40, op="add")[:, 0]),
        atol=1e-4)


def test_occupancy_is_idle_skip_safe(rng):
    """Rounds marked idle by the occupancy map truly have no matches."""
    E = 4 * K.EDGE_TILE_ADD
    R = 4 * K.ROW_BLOCK
    dst = rng.integers(0, R, E).astype(np.int32)
    dst[:K.EDGE_TILE_ADD] = 0  # first tile only touches row block 0
    occ = np.asarray(occupancy_map(jnp.asarray(dst), R // K.ROW_BLOCK,
                                   K.EDGE_TILE_ADD))
    tiles = dst.reshape(-1, K.EDGE_TILE_ADD) // K.ROW_BLOCK
    for r in range(occ.shape[0]):
        for t in range(occ.shape[1]):
            if not occ[r, t]:
                assert not np.any(tiles[t] == r)


@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(1, 300),
    f=st.integers(1, 40),
    r=st.integers(1, 200),
    op=st.sampled_from(["add", "max", "min"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(e, f, r, op, seed):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(-2, r + 2, e).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((e, f)).astype(np.float32))
    _cmp(dst, val, r, op)


def test_weighted_or_ignores_weights(rng):
    """Regression: op="or" must not scale by edge weights — a zero or
    negative weight used to zero/flip the contribution before the masked
    segment-max, silently corrupting boolean-or semantics."""
    from repro.core.gas import gas_scatter_weighted

    dst = jnp.asarray(np.array([0, 0, 1, 2, 2, 3], np.int32))
    src = jnp.asarray(np.array([1, 0, 1, 1, 0, 1], np.int32))[:, None]
    w = jnp.asarray(np.array([0.0, 5.0, -2.0, 0.0, 1.0, -1.0], np.float32))
    mask = jnp.asarray(np.array([1, 1, 1, 1, 1, 0], bool))
    # row0: {1,0}→1 even with weight 0; row1: {1}→1 despite negative weight;
    # row2: {1,0}→1 with weight 0 on the set bit; row3: masked out → 0
    for impl in ("xla", "pallas"):
        out = gas_scatter_weighted(dst, src, w, mask, 4, op="or", impl=impl)
        np.testing.assert_array_equal(np.asarray(out)[:, 0], [1, 1, 1, 0],
                                      err_msg=impl)


def test_or_1d_int_values(rng):
    """Regression: op="or" on 1-D int values used to recurse to 2-D with
    op="or" still set, so the float32-max dtype rewrite ran at both
    recursion depths; the rewrite now happens exactly once, before the ndim
    dispatch. Pin the whole contract: result matches the int segment-max
    oracle, dtype is preserved, empty rows hold the or-identity 0, and 1-D
    agrees exactly with the equivalent 2-D call."""
    E, R = 200, 40
    dst = jnp.asarray(rng.integers(-2, R + 2, E).astype(np.int32))
    val = jnp.asarray(rng.integers(0, 2, E).astype(np.int32))
    got = gas_scatter(dst, val, R, op="or")
    assert got.shape == (R,) and got.dtype == jnp.int32
    ok = (np.asarray(dst) >= 0) & (np.asarray(dst) < R)
    want = np.zeros(R, np.int32)
    np.maximum.at(want, np.asarray(dst)[ok], np.asarray(val)[ok])
    np.testing.assert_array_equal(np.asarray(got), want)
    got2d = gas_scatter(dst, val[:, None], R, op="or")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2d)[:, 0])
    # rows with no incoming edge hold 0 (the or-identity), not -inf/INT_MIN
    untouched = np.setdiff1d(np.arange(R), np.asarray(dst)[ok])
    if untouched.size:
        np.testing.assert_array_equal(np.asarray(got)[untouched], 0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), e=st.integers(2, 200))
def test_property_permutation_invariance(seed, e):
    """Scatter-add is invariant to edge order (the row-parallel semantics)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, 50, e).astype(np.int32)
    val = rng.standard_normal((e, 6)).astype(np.float32)
    perm = rng.permutation(e)
    a = gas_scatter(jnp.asarray(dst), jnp.asarray(val), 50, op="add")
    b = gas_scatter(jnp.asarray(dst[perm]), jnp.asarray(val[perm]), 50, op="add")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
