"""Multi-device behaviour via subprocesses (8 fake CPU devices each).

Each case lives in tests/distributed_cases.py and sets XLA_FLAGS before
importing jax — keeping this pytest process on the real 1-device topology.
"""

import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")


def _run(case: str, timeout=480):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, os.path.join(_HERE, "distributed_cases.py"), case],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"{case} failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


def test_cgtrans_equivalence():
    assert "ok" in _run("cgtrans_equivalence")


def test_cgtrans_collective_bytes_compression():
    out = _run("cgtrans_collective_bytes")
    assert "ratio" in out


def test_embedding_cgtrans():
    assert "ok" in _run("embedding_cgtrans")


def test_elastic_checkpoint():
    assert "ok" in _run("elastic_checkpoint")


def test_distributed_sage_training():
    assert "ok" in _run("distributed_sage_training")


def test_pipeline_parallel():
    assert "ok" in _run("pipeline_parallel")
