"""Multi-device behaviour via subprocesses (8 fake CPU devices each).

Each case lives in tests/distributed_cases.py and sets XLA_FLAGS before
importing jax — keeping this pytest process on the real 1-device topology.

All cases carry the ``distributed`` marker; deselect the ~4-minute subprocess
suite with ``-m "not distributed"``.
"""

import pytest

# the runner lives in tests/_dist.py (shared with conftest.py's session
# fixture for test_cgtrans_pallas.py)
from _dist import run_distributed_case as _run

pytestmark = pytest.mark.distributed


def test_cgtrans_equivalence():
    assert "ok" in _run("cgtrans_equivalence")


def test_cgtrans_pallas_parity(pallas_parity_report):
    """impl="pallas" ≡ impl="xla" ≡ single-shard reference across the full
    (dataflow × op × path) matrix on the real 8-way mesh — see
    tests/test_cgtrans_pallas.py for the per-cell breakdown."""
    assert "cgtrans pallas parity ok" in pallas_parity_report


def test_cgtrans_grad_parity(grad_parity_report):
    """jax.grad through impl="pallas" ≡ impl="xla" ≡ single-shard reference
    across (dataflow × op × path × chunking) on the real 8-way mesh, plus
    the 3-step pallas-vs-xla train parity — see tests/test_cgtrans_grad.py
    for the per-cell breakdown."""
    assert "cgtrans grad parity ok" in grad_parity_report


def test_cgtrans_collective_bytes_compression():
    out = _run("cgtrans_collective_bytes")
    assert "ratio" in out


def test_embedding_cgtrans():
    assert "ok" in _run("embedding_cgtrans")


def test_elastic_checkpoint():
    assert "ok" in _run("elastic_checkpoint")


def test_distributed_sage_training():
    assert "ok" in _run("distributed_sage_training")


def test_pipeline_parallel():
    assert "ok" in _run("pipeline_parallel")
