"""Multi-device behaviour via subprocesses (8 fake CPU devices each).

Each case lives in tests/distributed_cases.py and sets XLA_FLAGS before
importing jax — keeping this pytest process on the real 1-device topology.

All cases carry the ``distributed`` marker; deselect the ~4-minute subprocess
suite with ``-m "not distributed"``.
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.distributed

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")


def _run(case: str, timeout=480):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    cmd = [sys.executable, os.path.join(_HERE, "distributed_cases.py"), case]
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired as e:
        pytest.fail(
            f"case {case!r} timed out after {timeout}s\n"
            f"--- captured stdout ---\n{e.stdout or ''}\n"
            f"--- captured stderr ---\n{e.stderr or ''}",
            pytrace=False)
    if proc.returncode != 0:
        # surface the child's traceback directly — an import/compat break in
        # the subprocess must read as itself, not as `assert 1 == 0` around
        # a CompletedProcess repr
        pytest.fail(
            f"case {case!r} exited {proc.returncode}\n"
            f"--- child stdout ---\n{proc.stdout}\n"
            f"--- child stderr ---\n{proc.stderr}",
            pytrace=False)
    return proc.stdout


def test_cgtrans_equivalence():
    assert "ok" in _run("cgtrans_equivalence")


def test_cgtrans_collective_bytes_compression():
    out = _run("cgtrans_collective_bytes")
    assert "ratio" in out


def test_embedding_cgtrans():
    assert "ok" in _run("embedding_cgtrans")


def test_elastic_checkpoint():
    assert "ok" in _run("elastic_checkpoint")


def test_distributed_sage_training():
    assert "ok" in _run("distributed_sage_training")


def test_pipeline_parallel():
    assert "ok" in _run("pipeline_parallel")
