"""Flash-attention kernel vs naive oracle: masks, GQA, softcap, padding."""

import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


def _run(rng, B, S, T, H, Hkv, hd, **kw):
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32)) * hd ** -0.5
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32))
    got = flash_attention(q, k, v, **kw)
    want = flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("case", [
    dict(B=1, S=256, T=256, H=4, Hkv=2, hd=32, causal=True),
    dict(B=2, S=128, T=128, H=2, Hkv=1, hd=64, causal=True, window=64),
    dict(B=1, S=200, T=200, H=4, Hkv=4, hd=16, causal=True, softcap=50.0),
    dict(B=1, S=128, T=384, H=2, Hkv=2, hd=32, causal=False),
    dict(B=1, S=130, T=130, H=2, Hkv=2, hd=8, causal=True),     # odd pad
    dict(B=1, S=256, T=256, H=8, Hkv=2, hd=16, causal=True, window=100,
         softcap=30.0),                                          # everything
])
def test_cases(rng, case):
    kw = {k: case[k] for k in ("causal", "window", "softcap") if k in case}
    _run(rng, case["B"], case["S"], case["T"], case["H"], case["Hkv"],
         case["hd"], **kw)


def test_row_softmax_property(rng):
    """Output is a convex combination of V rows: bounded by min/max of v."""
    B, S, H, hd = 1, 128, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    v = jnp.ones((B, S, H, hd), jnp.float32) * 3.0
    out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 3.0, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([64, 96, 128, 200, 256]),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    window=st.sampled_from([0, 32, 100]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(s, h, g, hd, causal, window, seed):
    if window and not causal:
        window = 0
    rng = np.random.default_rng(seed)
    _run(rng, 1, s, s, h * g, h, hd, causal=causal, window=window)
