"""Data pipelines + embedding/CE: determinism, resume, vocab-pad masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (GraphBatchStream, ShardedTokenFiles, TokenStream,
                        synthetic_node_labels)
from repro.graph import uniform_graph
from repro.models.embedding import chunked_softmax_xent, logits_matmul


def test_token_stream_determinism_and_resume():
    s1 = TokenStream(vocab=100, batch=4, seq_len=16, seed=7)
    s2 = TokenStream(vocab=100, batch=4, seq_len=16, seed=7)
    b5a = s1.batch_at(5)
    b5b = s2.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(s1.batch_at(0)["labels"][:, :-1],
                                  s1.batch_at(0)["tokens"][:, 1:])
    # host disjointness
    h0 = TokenStream(vocab=100, batch=4, seq_len=16, seed=7, host=0).batch_at(3)
    h1 = TokenStream(vocab=100, batch=4, seq_len=16, seed=7, host=1).batch_at(3)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_sharded_token_files_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 1000, 10000).astype(np.int32)
    ShardedTokenFiles.write(str(tmp_path), tokens, shard_size=2048)
    r = ShardedTokenFiles(str(tmp_path))
    it = r.reader(batch=2, seq_len=32)
    b0 = next(it)
    assert b0["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b0["tokens"][0], tokens[:32])
    # resume from step 3 == reading 4th batch fresh
    it2 = r.reader(batch=2, seq_len=32, start_step=3)
    fresh = ShardedTokenFiles(str(tmp_path)).reader(batch=2, seq_len=32)
    for _ in range(3):
        next(fresh)
    np.testing.assert_array_equal(next(it2)["tokens"], next(fresh)["tokens"])


def test_graph_batch_stream_shapes_and_determinism():
    g = uniform_graph(200, 2000, seed=1, n_features=8)
    labels = synthetic_node_labels(g.features, 5)
    st = GraphBatchStream(g, labels, n_parts=4, batch_per_part=8, k1=3, k2=2)
    b = st.batch_at(4)
    assert b["seeds"].shape == (4, 8)
    assert b["nbrs1"].shape == (4, 8, 3)
    assert b["nbrs2"].shape == (4, 8 * 4, 2)
    assert b["labels"].shape == (4, 8)
    np.testing.assert_array_equal(b["seeds"], st.batch_at(4)["seeds"])
    assert not np.array_equal(b["seeds"], st.batch_at(5)["seeds"])


def test_chunked_ce_matches_naive(rng):
    B, S, D, V = 2, 24, 8, 40
    x = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    table = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, V, (B, S)).astype(np.int32))
    labels = labels.at[0, :3].set(-1)   # padding
    loss_sum, cnt = chunked_softmax_xent(x, table, labels, max_chunk=5)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    logp = jax.nn.log_softmax(logits, -1)
    mask = np.asarray(labels) >= 0
    want = -np.asarray(logp)[np.arange(B)[:, None], np.arange(S)[None], np.maximum(np.asarray(labels), 0)]
    np.testing.assert_allclose(float(loss_sum), want[mask].sum(), rtol=1e-5)
    assert float(cnt) == mask.sum()


def test_vocab_pad_masked(rng):
    D, V, Vpad = 8, 37, 64
    x = jnp.asarray(rng.standard_normal((2, D)).astype(np.float32))
    table = jnp.asarray(rng.standard_normal((Vpad, D)).astype(np.float32))
    logits = logits_matmul(x, table, valid_vocab=V)
    assert np.all(np.asarray(logits)[:, V:] < -1e29)
    assert np.all(np.isfinite(np.asarray(logits)[:, :V]))
